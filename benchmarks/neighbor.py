"""Paper Fig 11: neighbor-search environment comparison.

BioDynaMo compares its uniform grid against kd-tree (nanoflann) and octree
(UniBN); pointer-chasing trees have no faithful XLA analogue (DESIGN.md §10.5),
so the comparison set here is: optimized sort-based uniform grid (ours),
scatter-table grid ('standard implementation'), spatial-hash grid, and exact
brute force (reference). Reported separately, as in the paper: index BUILD
time and SEARCH (force sweep) time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agents, grid as G
from repro.core.forces import ForceParams, make_force_pair_fn

from .common import emit, random_positions, time_fn

N = 30_000
RADIUS = 4.0
SIDE = 130.0


def run() -> None:
    rng = np.random.default_rng(3)
    pos = random_positions(rng, N, 2.0, SIDE - 2.0)
    pool = agents.make_pool(N, position=jnp.asarray(pos),
                            diameter=jnp.full((N,), 3.0))
    spec = G.GridSpec(dims=(33, 33, 33), max_per_box=32, query_chunk=4096)
    origin = jnp.zeros(3)
    r = jnp.asarray(RADIUS)
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    out_specs = {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)}
    all_idx = jnp.arange(N, dtype=jnp.int32)

    # --- build times ---
    build_u = jax.jit(lambda p: G.build(spec, p, origin, r))
    us_build_u = time_fn(build_u, pool)
    emit("fig11_build_uniform_grid", us_build_u, f"n={N}")
    build_s = jax.jit(lambda p: G.build_scatter_grid(spec, p, origin, r))
    us_build_s = time_fn(build_s, pool)
    emit("fig11_build_scatter_grid", us_build_s,
         f"vs_uniform={us_build_s / us_build_u:.2f}x")
    build_h = jax.jit(lambda p: G.build_hash_grid(spec, p, origin, r))
    us_build_h = time_fn(build_h, pool)
    emit("fig11_build_hash_grid", us_build_h,
         f"vs_uniform={us_build_h / us_build_u:.2f}x")

    # --- search (force sweep) times ---
    gs = build_u(pool)
    search_u = jax.jit(lambda g: G.neighbor_apply(
        spec, g, channels, all_idx, jnp.int32(N), pair, out_specs))
    us_u = time_fn(search_u, gs)
    emit("fig11_search_uniform_grid", us_u, f"n={N}")

    sg = build_s(pool)

    def search_scatter(g):
        b = spec.query_chunk
        nb = (N + b - 1) // b
        outs = {k: jnp.zeros((N, *sfx), dt) for k, (sfx, dt) in out_specs.items()}

        def body(i, outs):
            sl = i * b
            q_slot = jnp.minimum(sl + jnp.arange(b, dtype=jnp.int32), N - 1)
            lane_ok = (sl + jnp.arange(b)) < N
            q = {k: v[q_slot] for k, v in channels.items()}
            ids, valid = G.scatter_grid_candidates(spec, g, q["position"])
            valid &= lane_ok[:, None] & (ids != q_slot[:, None])
            nbr = {k: v[ids] for k, v in channels.items()}
            res = pair(q, nbr, valid, q_slot)
            new = dict(outs)
            for name, val in res.items():
                val = jnp.where(lane_ok.reshape((b,) + (1,) * (val.ndim - 1)),
                                val, 0)
                new[name] = outs[name].at[q_slot].add(
                    val.astype(outs[name].dtype), mode="drop")
            return new

        return jax.lax.fori_loop(0, nb, body, outs)

    us_s = time_fn(jax.jit(search_scatter), sg)
    emit("fig11_search_scatter_grid", us_s, f"vs_uniform={us_s / us_u:.2f}x")

    hg = build_h(pool)

    def search_hash(g):
        b = spec.query_chunk
        nb = (N + b - 1) // b
        outs = {k: jnp.zeros((N, *sfx), dt) for k, (sfx, dt) in out_specs.items()}

        def body(i, outs):
            sl = i * b
            q_slot = jnp.minimum(sl + jnp.arange(b, dtype=jnp.int32), N - 1)
            lane_ok = (sl + jnp.arange(b)) < N
            q = {k: v[q_slot] for k, v in channels.items()}
            ids, valid = G.hash_grid_candidates(spec, g, q["position"])
            valid &= lane_ok[:, None] & (ids != q_slot[:, None])
            nbr = {k: v[ids] for k, v in channels.items()}
            res = pair(q, nbr, valid, q_slot)
            new = dict(outs)
            for name, val in res.items():
                val = jnp.where(lane_ok.reshape((b,) + (1,) * (val.ndim - 1)),
                                val, 0)
                new[name] = outs[name].at[q_slot].add(
                    val.astype(outs[name].dtype), mode="drop")
            return new

        return jax.lax.fori_loop(0, nb, body, outs)

    us_h = time_fn(jax.jit(search_hash), hg)
    emit("fig11_search_hash_grid", us_h, f"vs_uniform={us_h / us_u:.2f}x")

    # brute force at reduced N (quadratic — paper's trees are its stand-in)
    nb = 3_000
    pool_b = agents.make_pool(nb, position=jnp.asarray(pos[:nb]),
                              diameter=jnp.full((nb,), 3.0))
    ch_b = {k: v for k, v in pool_b.channels().items()
            if not k.startswith("extra.")}
    bf = jax.jit(lambda p: G.brute_force_apply(ch_b, p.alive, r, pair,
                                               out_specs, chunk=1024))
    us_b = time_fn(bf, pool_b)
    emit("fig11_search_brute_force", us_b,
         f"n={nb} (quadratic reference)")
