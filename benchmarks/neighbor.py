"""Paper Fig 11: neighbor-search environment comparison.

BioDynaMo compares its uniform grid against kd-tree (nanoflann) and octree
(UniBN); pointer-chasing trees have no faithful XLA analogue (DESIGN.md §11.5),
so the comparison set here is: resident sort-based uniform grid (ours,
grid-ordered pool + run-streaming queries — DESIGN.md §3.2), scatter-table
grid ('standard implementation'), spatial-hash grid (streamed probes, plus
the pre-PR-3 wide candidate matrix as the recorded 'before'), and exact brute
force (reference). Reported separately, as in the paper: index BUILD time
(which for the resident grid *includes* applying the permutation to every
channel) and SEARCH (force sweep) time.

The uniform grid opts into a tight per-run gather capacity (``max_per_run``):
a 3-box z-run pools occupancy across 3 boxes, so its max is far below
3·max_per_box for any near-uniform density. The build-time ``max_run_count``
check keeps the setting *exact* — we assert no overflow, and validate the
force output against the O(N²) brute-force oracle.

Besides the CSV rows, emits machine-readable ``BENCH_neighbor.json``
(build/search µs per environment, N, grid dims, oracle error, and the
``history`` of headline numbers from earlier PRs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agents, grid as G
from repro.core.forces import ForceParams, make_force_pair_fn

from .common import emit, random_positions, time_fn, write_bench_json

N = 30_000
RADIUS = 4.0
SIDE = 130.0
MAX_PER_BOX = 32
MAX_PER_RUN = 32    # exactness asserted via gs.max_run_count below

# headline numbers of earlier PRs on this container (for trajectory tracking)
HISTORY = {
    "pr1_seed_uniform_total_us": 1238000.0,   # Morton-coded 27-gather seed
    "pr2_uniform_total_us": 256321.7,         # linear-key run-merged, copy-sorted
    "pr2_hash_grid_search_us": 2977592.8,     # wide (Q, 27K) candidate matrix
}


def run() -> None:
    rng = np.random.default_rng(3)
    pos = random_positions(rng, N, 2.0, SIDE - 2.0)
    pool = agents.make_pool(N, position=jnp.asarray(pos),
                            diameter=jnp.full((N,), 3.0))
    spec = G.GridSpec(dims=(33, 33, 33), max_per_box=MAX_PER_BOX,
                      max_per_run=MAX_PER_RUN, query_chunk=4096)
    origin = jnp.zeros(3)
    r = jnp.asarray(RADIUS)
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    out_specs = {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)}
    all_idx = jnp.arange(N, dtype=jnp.int32)
    results: dict = {
        "n": N, "dims": list(spec.dims), "radius": RADIUS,
        "table_size": spec.table_size,             # == prod(dims), no padding
        "max_per_box": MAX_PER_BOX, "max_per_run": MAX_PER_RUN,
        "build_us": {}, "search_us": {}, "history": HISTORY,
    }

    # --- build times ---
    # resident build = key sort + permuting every channel + index tables
    # (what the engine pays per step; the search then needs no channel copy)
    mk_u = G.make_builder(spec, method="resident")
    build_u = jax.jit(lambda p: mk_u(p, origin, r))
    us_build_u = time_fn(build_u, pool)
    emit("fig11_build_uniform_grid", us_build_u,
         f"n={N} (resident: includes channel permutation)")
    mk_s = G.make_builder(spec, method="scatter")
    build_s = jax.jit(lambda p: mk_s(p, origin, r))
    us_build_s = time_fn(build_s, pool)
    emit("fig11_build_scatter_grid", us_build_s,
         f"vs_uniform={us_build_s / us_build_u:.2f}x")
    mk_h = G.make_builder(spec, method="hash")
    build_h = jax.jit(lambda p: mk_h(p, origin, r))
    us_build_h = time_fn(build_h, pool)
    emit("fig11_build_hash_grid", us_build_h,
         f"vs_uniform={us_build_h / us_build_u:.2f}x")
    results["build_us"] = {"uniform_grid": us_build_u,
                           "scatter_grid": us_build_s,
                           "hash_grid": us_build_h}

    # --- search (force sweep) times ---
    ures = build_u(pool)
    rpool, gs, order = ures.pool, ures.grid, ures.order
    max_run = int(gs.max_run_count)
    assert max_run <= spec.run_capacity, \
        f"run overflow: {max_run} > {spec.run_capacity} — raise MAX_PER_RUN"
    results["max_run_count"] = max_run
    rch = {k: v for k, v in rpool.channels().items()
           if not k.startswith("extra.")}
    alive = rpool.alive
    search_u = jax.jit(lambda g, ch: G.resident_apply(
        spec, g, ch, alive, pair, out_specs))
    us_u = time_fn(search_u, gs, rch)
    emit("fig11_search_uniform_grid", us_u,
         f"n={N} (run-streaming, peak width R={spec.run_capacity} "
         f"vs 9R={9 * spec.run_capacity})")

    sg = build_s(pool).grid

    def env_search(cand_of_grid):
        # g must be the traced jit argument — a closed-over grid would be a
        # compile-time constant and XLA could fold the timed search away
        def go(g):
            def cf(q_pos, q_slot):
                ids, valid = cand_of_grid(g, q_pos)
                valid &= ids != q_slot[:, None]
                return ids, valid
            return G.chunk_apply(channels, channels, all_idx, jnp.int32(N),
                                 cf, pair, out_specs, spec.query_chunk)
        return go

    us_s = time_fn(jax.jit(env_search(
        lambda g, qp: G.scatter_grid_candidates(spec, g, qp))), sg)
    emit("fig11_search_scatter_grid", us_s, f"vs_uniform={us_s / us_u:.2f}x")

    hg = build_h(pool).grid
    # 'before': the wide (Q, 27·K_hash) candidate matrix (pre-PR-3 pathology)
    us_h_wide = time_fn(jax.jit(env_search(
        lambda g, qp: G.hash_grid_candidates(spec, g, qp))), hg)
    emit("fig11_search_hash_grid_wide", us_h_wide,
         f"vs_uniform={us_h_wide / us_u:.2f}x (pre-streaming baseline)")

    # 'after': the 27 probes streamed one bucket-width at a time, with the
    # probe capacity capped to the true occupancy bound (k_mult=1): at 16k
    # buckets the expected load is ~2 agents, so the default 4·K capacity was
    # pure gather waste. The cap stays exact — assert it against the build.
    k_mult = 1
    max_bucket = int(jnp.max(hg.counts))
    assert max_bucket <= spec.max_per_box * k_mult, \
        f"hash bucket overflow: {max_bucket} > {spec.max_per_box * k_mult}"
    results["max_bucket_count"] = max_bucket

    def hash_streamed(g):
        def phase(q_pos, q_slot, j):
            ids, valid = G.hash_grid_probe(spec, g, q_pos, j, k_mult=k_mult)
            valid &= ids != q_slot[:, None]
            return ids, valid
        return G.phased_chunk_apply(channels, channels, all_idx, jnp.int32(N),
                                    phase, 27, pair, out_specs,
                                    spec.query_chunk)
    us_h = time_fn(jax.jit(hash_streamed), hg)
    emit("fig11_search_hash_grid", us_h,
         f"vs_uniform={us_h / us_u:.2f}x streamed_speedup={us_h_wide / us_h:.2f}x")

    results["search_us"] = {"uniform_grid": us_u, "scatter_grid": us_s,
                            "hash_grid": us_h, "hash_grid_wide": us_h_wide}
    results["uniform_total_us"] = us_build_u + us_u

    # brute force timing at reduced N (quadratic — paper's trees are its stand-in)
    nb = 3_000
    pool_b = agents.make_pool(nb, position=jnp.asarray(pos[:nb]),
                              diameter=jnp.full((nb,), 3.0))
    ch_b = {k: v for k, v in pool_b.channels().items()
            if not k.startswith("extra.")}
    bf = jax.jit(lambda p: G.brute_force_apply(ch_b, p.alive, pair,
                                               out_specs, chunk=1024))
    us_b = time_fn(bf, pool_b)
    emit("fig11_search_brute_force", us_b,
         f"n={nb} (quadratic reference)")
    results["search_us"]["brute_force_n3000"] = us_b

    # exactness oracle: full-N brute force vs the tight-run resident grid
    # (resident output is in grid order — map back through the permutation)
    oracle = jax.jit(lambda p: G.brute_force_apply(
        channels, p.alive, pair, out_specs, chunk=1024))(pool)
    got_r = search_u(gs, rch)
    got_f = jnp.zeros((N, 3)).at[order].set(got_r["force"])
    got_nnz = jnp.zeros((N,), jnp.int32).at[order].set(got_r["force_nnz"])
    err = float(jnp.max(jnp.abs(got_f - oracle["force"])))
    nnz_match = bool(jnp.all(got_nnz == oracle["force_nnz"]))
    assert err <= 2e-6, f"resident grid force deviates from oracle: {err}"
    results["oracle_max_abs_err"] = err
    results["oracle_nnz_match"] = nnz_match
    emit("fig11_oracle_max_abs_err", err * 1e6, f"nnz_match={nnz_match}")

    write_bench_json("BENCH_neighbor.json", results)
