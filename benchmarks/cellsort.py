"""Paper Fig 7 / §6.5: the Biocellion cell-sorting model on this engine.

Two cell types with differential adhesion (same-type stickier than cross-type)
segregate from a random mixture — the classic Steinberg DAH benchmark
Biocellion §3.1 uses. We report per-iteration throughput (agents·iter/s — the
paper's cross-system comparison currency) and verify the *physics*: the
same-type neighbor fraction must increase from ~0.5 toward 1.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core import grid as G

from .common import emit, random_positions, time_fn

N = 8_000
ADHESION = ((0.30, 0.06), (0.06, 0.30))     # same-type >> cross-type


def _same_type_fraction(sim, st) -> float:
    pool = st.pool
    spec = sim.spec
    gs = G.make_builder(spec, method="sorted")(
        pool, jnp.asarray(sim.config.domain_lo, jnp.float32),
        jnp.asarray(sim.config.interaction_radius, jnp.float32)).grid
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    r = sim.config.interaction_radius

    def pair_fn(q, nbr, valid, q_slot):
        d = nbr["position"] - q["position"][:, None, :]
        ok = valid & nbr["alive"] & ((d * d).sum(-1) <= r * r)
        same = ok & (nbr["agent_type"] == q["agent_type"][:, None])
        return {"same": same.sum(-1).astype(jnp.int32),
                "tot": ok.sum(-1).astype(jnp.int32)}

    out = G.neighbor_apply(spec, gs, channels,
                           jnp.arange(pool.capacity, dtype=jnp.int32),
                           pool.n_live, pair_fn,
                           {"same": ((), jnp.int32), "tot": ((), jnp.int32)})
    tot = float(out["tot"].sum())
    return float(out["same"].sum()) / max(tot, 1.0)


def run() -> None:
    rng = np.random.default_rng(7)
    side = 60.0
    cfg = EngineConfig(capacity=N, domain_lo=(0, 0, 0), domain_hi=(side,) * 3,
                       interaction_radius=4.5, dt=0.1, sort_frequency=10,
                       adhesion=ADHESION, max_per_box=64, query_chunk=4096,
                       force=ForceParams(k_rep=1.5, adhesion_band=0.8,
                                         max_displacement=0.4))
    sim = Simulation(cfg, [])
    pos = random_positions(rng, N, 10.0, side - 10.0)
    types = rng.integers(0, 2, N).astype(np.int32)
    st = sim.init_state(pos, diameter=np.full(N, 3.2, np.float32),
                        agent_type=types)
    f0 = _same_type_fraction(sim, st)
    st = sim.step(st)
    us = time_fn(lambda s: sim.step(s), st, warmup=1, iters=3)
    st = sim.run(st, 40)
    f1 = _same_type_fraction(sim, st)
    emit("fig7_cellsort_iter", us,
         f"throughput={N / (us / 1e6):.0f} agents*iter/s")
    emit("fig7_cellsort_segregation", 0.0,
         f"same_type_frac {f0:.3f}->{f1:.3f} (must increase)")
    assert f1 > f0 + 0.02, "differential adhesion must segregate types"
