"""Capacity ladder at paper scale — the Fig 12/13 peak-population analog.

The paper's headline scale (1.72e9 agents on one server, §6/Fig 12-13) rests
on its custom pool allocator (§4.3): populations grow for the whole run
without per-agent allocation cost. Our port's analog is the capacity ladder
(engine.CapacityLadder, DESIGN.md §4.3): a geometric sequence of fixed-shape
pools crossed automatically when any overflow flag fires — *zero* manual
capacity settings.

This benchmark runs the ladder's defining scenario: an exponential-growth
population (GrowDivide + RandomWalk spread) seeded with 1k cells and left to
divide until it passes ``CAPACITY_TARGET`` live agents (default 10.5M — past
the paper-scale 10M mark, ≥2 capacity rungs beyond the previous 4.19M
record). The pool starts at the seed size; every rung (pool capacity,
max_per_run) is chosen by the ladder from the overflow provenance in
StepStats. Records ``BENCH_capacity.json``: peak live count, the rung
schedule, recompile count, and **per rung** the whole-step µs plus
standalone phase buckets timed on their own (compile excluded): ``build_us``
(the O(N) counting-sort resident build), the fused sweep over the step's
registered kernels timed both ways — ``streamed_neighbor_us`` vs
``pairlist_neighbor_us`` (Verlet pair-list fed, DESIGN.md §3.4), with
``neighbor_us`` kept as the streamed alias — ``commit_us`` (death
compaction), and a ``behavior_other_us`` residual. The standalone keys are what
benchmarks/trend.py gates, since the whole-step schedule depends on where
rungs/recompiles land.

Env overrides (CI smoke): ``CAPACITY_TARGET``, ``CAPACITY_SEED_AGENTS``,
``CAPACITY_MAX_STEPS``; ``CAPACITY_STEP_BUDGET_S`` (>0 fails the run when
the final rung's median warm step exceeds the budget — the CI paper-scale
job's step-time guard).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CapacityLadder, DtypePolicy, EngineConfig, LadderConfig,
                        make_pool)
from repro.core import compaction, engine as engine_mod, grid as grid_mod
from repro.core.behaviors import GrowDivide, RandomWalk

from .common import emit, write_bench_json

SIDE = 512.0              # 128^3 boxes at r=4: ~5 agents/box at 10.5M


def _bytes_per_agent(policy: DtypePolicy) -> float:
    pool = make_pool(8, policy=policy)
    return sum(v.nbytes for v in pool.channels().values()) / 8.0


def _measure_build_us(cfg: EngineConfig, pool) -> float:
    """Median µs of the standalone jitted resident build at this rung
    (compile excluded). This is the apples-to-apples build-time key the
    trend gate watches: unlike whole-step times it does not depend on when
    rungs/recompiles land in the growth schedule."""
    spec = cfg.grid_spec
    origin = jnp.asarray(cfg.domain_lo, jnp.float32)
    box = jnp.asarray(cfg.cell_size, jnp.float32)
    build = jax.jit(lambda p: grid_mod.make_builder(
        spec, method="resident", sort_impl=cfg.sort_impl)(p, origin, box))
    return _time_warm(build, pool)


def _time_warm(fn, *args) -> float:
    jax.block_until_ready(fn(*args))             # compile
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _measure_phases_us(cfg: EngineConfig, behaviors, pool) -> dict:
    """Standalone jit-warm phase buckets at this rung (DESIGN.md §3.2):
    the fused sweep over the step's registered kernels timed BOTH ways —
    ``streamed_neighbor_us`` (the 9-run candidate stream) and
    ``pairlist_neighbor_us`` (the same sweep fed from a Verlet pair list,
    DESIGN.md §3.4) — both 0.0 when no kernels register (this growth
    scenario runs forces-off with sweep-free behaviors), and ``commit_us``
    the death-compaction permutation. Together with ``build_us`` these
    split ``step_other_us`` into buckets that stay comparable across PRs
    regardless of the rung schedule. ``neighbor_us`` stays as an alias of
    the streamed time for continuity with pre-split baselines."""
    spec = cfg.grid_spec
    origin = jnp.asarray(cfg.domain_lo, jnp.float32)
    box = jnp.asarray(cfg.cell_size, jnp.float32)
    kernels = engine_mod.registered_kernels(cfg, behaviors)
    streamed_us = pairlist_us = 0.0
    if kernels:
        res = jax.jit(lambda p: grid_mod.make_builder(
            spec, method="resident", sort_impl=cfg.sort_impl)(
                p, origin, box))(pool)
        channels = res.pool.channels()
        sweep = jax.jit(lambda ch, m: grid_mod.resident_apply_fused(
            spec, res.grid, ch, kernels, m, cfg.query_chunk))
        streamed_us = _time_warm(sweep, channels, res.pool.alive)
        # pair table sized from the realized demand (next power of two, so a
        # rung-boundary remeasure at higher occupancy keeps the same shape)
        probe = jax.jit(lambda p, m: grid_mod.build_pairlist(
            spec, res.grid, p, m, radius=cfg.interaction_radius,
            max_pairs=8, chunk=cfg.query_chunk))(
                res.pool.position, res.pool.alive)
        max_pairs = max(8, 1 << int(np.ceil(np.log2(
            max(int(probe.demand), 1)))))
        pairs = jax.jit(lambda p, m: grid_mod.build_pairlist(
            spec, res.grid, p, m, radius=cfg.interaction_radius,
            max_pairs=max_pairs, chunk=cfg.query_chunk))(
                res.pool.position, res.pool.alive)
        pl_sweep = jax.jit(lambda ch, m, pl: grid_mod.resident_apply_fused(
            spec, res.grid, ch, kernels, m, cfg.query_chunk, pairs=pl))
        pairlist_us = _time_warm(pl_sweep, channels, res.pool.alive, pairs)
    commit_us = _time_warm(jax.jit(compaction.compact), pool)
    return {"neighbor_us": streamed_us, "streamed_neighbor_us": streamed_us,
            "pairlist_neighbor_us": pairlist_us, "commit_us": commit_us}


def run() -> None:
    target = int(os.environ.get("CAPACITY_TARGET", 10_500_000))
    n_seed = int(os.environ.get("CAPACITY_SEED_AGENTS", 1_000))
    max_steps = int(os.environ.get("CAPACITY_MAX_STEPS", 80))
    budget_s = float(os.environ.get("CAPACITY_STEP_BUDGET_S", "0") or 0.0)

    lean = DtypePolicy(aux_float="bfloat16", compact_ints=True)
    cfg = EngineConfig(
        capacity=max(1024, n_seed),          # seed-sized; the ladder does the rest
        domain_lo=(0.0, 0.0, 0.0), domain_hi=(SIDE,) * 3,
        interaction_radius=4.0, dt=1.0, use_forces=False,
        max_per_box=8, query_chunk=8192, dtypes=lean)
    behaviors = [GrowDivide(rate=0.55, threshold_diameter=6.0),
                 RandomWalk(sigma=0.6)]
    ladder = CapacityLadder(cfg, behaviors, LadderConfig(growth_factor=2.0))

    rng = np.random.default_rng(0)
    pos = rng.uniform(4.0, SIDE - 4.0, (n_seed, 3)).astype(np.float32)
    state = ladder.init_state(pos, diameter=np.full(n_seed, 5.0, np.float32))

    steps = []
    build_us_by_cap = {}
    phases_by_cap = {}
    peak = n_seed
    t_total0 = time.perf_counter()
    for i in range(max_steps):
        t0 = time.perf_counter()
        state = ladder.step(state)           # includes any grow/recompile/rewind
        n_live = int(state.stats["n_live"])  # host sync — also fences timing
        us = (time.perf_counter() - t0) * 1e6
        steps.append({"iteration": i, "n_live": n_live,
                      "capacity": ladder.config.capacity, "us": us})
        peak = max(peak, n_live)
        if ladder.config.capacity not in build_us_by_cap:
            build_us_by_cap[ladder.config.capacity] = _measure_build_us(
                ladder.config, state.pool)
            phases_by_cap[ladder.config.capacity] = _measure_phases_us(
                ladder.config, behaviors, state.pool)
        if n_live >= target:
            break
    total_s = time.perf_counter() - t_total0
    # re-measure the final rung at peak occupancy (the first measurement ran
    # right after the grow, on a half-empty pool)
    build_us_by_cap[ladder.config.capacity] = _measure_build_us(
        ladder.config, state.pool)
    phases_by_cap[ladder.config.capacity] = _measure_phases_us(
        ladder.config, behaviors, state.pool)

    # µs/step per rung: median over the steps run at each capacity, skipping
    # each rung's first step (it pays that rung's compile); build_us is the
    # standalone resident-build time at that rung, and step_other_us —
    # everything but the build — is split into the standalone phase buckets
    # (neighbor_us: the fused sweep over registered kernels, commit_us: the
    # death compaction) plus a behavior_other_us residual (behaviors +
    # integration + bookkeeping), so the rungs stay comparable across PRs
    per_rung = []
    for cap in sorted({s["capacity"] for s in steps}):
        at = [s["us"] for s in steps if s["capacity"] == cap]
        warm = at[1:] if len(at) > 1 else at
        n_at = max(s["n_live"] for s in steps if s["capacity"] == cap)
        step_us = float(np.median(warm))
        build_us = build_us_by_cap[cap]
        phases = phases_by_cap[cap]
        other_us = max(step_us - build_us, 0.0)
        per_rung.append({"capacity": cap, "steps": len(at),
                         "max_n_live": n_at,
                         "us_per_step": step_us,
                         "build_us": build_us,
                         "neighbor_us": phases["neighbor_us"],
                         "streamed_neighbor_us": phases[
                             "streamed_neighbor_us"],
                         "pairlist_neighbor_us": phases[
                             "pairlist_neighbor_us"],
                         "commit_us": phases["commit_us"],
                         "behavior_other_us": max(
                             other_us - phases["neighbor_us"]
                             - phases["commit_us"], 0.0),
                         "step_other_us": other_us})
        emit(f"capacity_rung_c{cap}", step_us, f"n_live<={n_at}")
        emit(f"capacity_build_c{cap}", build_us, f"n_live<={n_at}")
        emit(f"capacity_commit_c{cap}", phases["commit_us"],
             f"n_live<={n_at}")

    reached = peak >= target
    emit("capacity_peak", total_s * 1e6,
         f"peak_live={peak} target={target} rungs={len(ladder.rungs)} "
         f"recompiles={ladder.recompiles}")
    write_bench_json("BENCH_capacity.json", {
        "seed_agents": n_seed,
        "target_live": target,
        "peak_live": peak,
        "reached_target": reached,
        "steps_run": len(steps),
        "total_s": total_s,
        "final_capacity": ladder.config.capacity,
        "final_max_per_run": ladder.config.grid_spec.run_capacity,
        "recompiles": ladder.recompiles,
        "rung_schedule": ladder.rungs,
        "us_per_step_per_rung": per_rung,
        "final_rung_us_per_step": per_rung[-1]["us_per_step"],
        "step_budget_s": budget_s or None,
        "bytes_per_agent": {
            "float32": _bytes_per_agent(DtypePolicy()),
            "lean": _bytes_per_agent(lean),
        },
        "manual_capacity_settings": 0,       # the ladder chose every rung
    })
    if not reached:
        # RuntimeError, not SystemExit: run.py aggregates per-module failures
        # through `except Exception` and SystemExit would bypass it
        raise RuntimeError(
            f"capacity ladder stopped at {peak} live agents "
            f"(< target {target}) after {len(steps)} steps")
    if budget_s > 0 and per_rung[-1]["us_per_step"] > budget_s * 1e6:
        raise RuntimeError(
            f"final-rung step time {per_rung[-1]['us_per_step'] / 1e6:.2f}s "
            f"exceeds CAPACITY_STEP_BUDGET_S={budget_s}")
