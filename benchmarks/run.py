# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   Fig 5  breakdown.py      operation runtime shares
#   Fig 6  scaling.py        runtime vs #agents (linearity)
#   Fig 7  cellsort.py       Biocellion cell-sorting model + throughput
#   Fig 9  optimizations.py  progressive optimization speedups
#   Fig 11 neighbor.py       neighbor-search environment comparison
#   Fig 12 sorting.py        sort-frequency study
#   Fig 13 allocator.py      pool allocator vs fresh allocation
#   §4.3   capacity.py       capacity ladder to paper-scale populations
#
# The roofline tables (assignment §Roofline) come from the dry-run
# (`python -m repro.launch.dryrun --all`), not from this harness — this
# container has one CPU core; dry-run numbers are per-device analytic terms.

import sys
import time
import traceback


def main() -> None:
    from . import (allocator, breakdown, capacity, cellsort, ensemble,
                   neighbor, optimizations, scaling, sorting)

    modules = [("fig5_breakdown", breakdown), ("fig6_scaling", scaling),
               ("fig7_cellsort", cellsort), ("fig9_optimizations", optimizations),
               ("fig11_neighbor", neighbor), ("fig12_sorting", sorting),
               ("fig13_allocator", allocator), ("ladder_capacity", capacity),
               ("ensemble_service", ensemble)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
