"""Per-phase step breakdown: fused vs unfused neighbor sweep (+ paper Fig 5).

After PR 6 amortized the grid build, steady-state step cost at the top rungs
is the neighbor sweeps: forces, each neighbor-using behavior, and statics
each streamed the pool once per phase. The fused sweep
(grid.resident_apply_fused, DESIGN.md §3.2) gathers each block's 9-run
candidate set once — pruned to the union of the registered kernels' declared
channel footprints — and evaluates every kernel against that single stream.

This benchmark times each phase standalone (jitted, compile excluded,
median µs) on a forces + SIR-infection workload (two registered kernels):

  build_us               resident grid build (permutation + tables)
  gather_us              candidate streaming alone: a reduce-only kernel
                         with the union footprint (the memory floor any
                         sweep pays at least once)
  force_us               sequential single-kernel force sweep
  behavior_us            sequential single-kernel infection sweep
  statics_us             box-granular static-flag update (no sweep — the
                         PR 3 design; kept pre-sweep because the flags gate
                         the force query mask, see DESIGN.md §3.2)
  integrate_us           displacement + clamp + write-back
  commit_us              death-compaction permutation
  fused_neighbor_us      ONE resident_apply_fused over both kernels
  unfused_neighbor_us    force_us + behavior_us (the sequential schedule)
  pairlist_build_us      grid.build_pairlist: distance-filter the fused
                         candidate stream into the packed Verlet table
                         (paid once per rebuild, amortized under skin reuse)
  pairlist_neighbor_us   the same fused sweep fed from the pair list
                         (from_pairlist mode: one width-P gather + 9 masked
                         segment rounds instead of 9 width-R streamed runs)

derived.fusion_speedup = unfused_neighbor_us / fused_neighbor_us — the
acceptance bar is >= 1.5x at >= 1M agents on the dev container.
derived.pairlist_speedup = fused_neighbor_us / pairlist_neighbor_us — the
PR 9 bar, >= 1.5x at >= 1M agents, with ``pairs_per_agent`` (mean listed
in-range candidates) and ``pruning_ratio`` (listed / streamed candidates)
recording how much of the stream the filter removes. Records
``BENCH_breakdown.json``; benchmarks/trend.py gates every per-size phase key
(they are fixed-shape standalone timings — schedule-independent, unlike the
capacity ladder's whole-step times).

Env: ``BREAKDOWN_SIZES`` (comma list, default "65536,262144,1048576" — the
small size exists so CI's reduced run compares identity-keyed against the
same committed record).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core import compaction, engine as engine_mod, forces as force_mod
from repro.core import grid as G, statics as statics_mod
from repro.core.behaviors import Infection, INFECTED

from .common import emit, random_positions, time_fn, write_bench_json


def _gather_pair_fn(reads):
    """Reduce-only kernel: touches every byte of the pruned stream, computes
    nothing else (sums survive DCE; a no-op output would let XLA drop the
    gathers entirely)."""

    def pair_fn(q, nbr, valid, q_slot):
        acc = jnp.zeros(valid.shape[0], jnp.float32)
        for ch in reads:
            x = nbr[ch].astype(jnp.float32)
            m = valid if x.ndim == 2 else valid[..., None]
            acc += jnp.sum(jnp.where(m, x, 0.0),
                           axis=tuple(range(1, x.ndim)))
        return {"g": acc}

    return pair_fn


def _one_size(n: int) -> dict:
    rng = np.random.default_rng(4)
    # ~4 live agents per box at every size (domain scales with n)
    side = float(np.ceil(4.0 * (n / 4.0) ** (1.0 / 3.0)))
    cfg = EngineConfig(capacity=n, domain_lo=(0, 0, 0), domain_hi=(side,) * 3,
                       interaction_radius=4.0, dt=0.05, max_per_box=32,
                       query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    infection = Infection(radius=4.0, beta=0.3, recovery_time=40)
    sim = Simulation(cfg, [infection])
    pos = random_positions(rng, n, 2.0, side - 2.0)
    types = np.zeros(n, np.int32)
    types[: max(n // 100, 1)] = INFECTED
    st = sim.init_state(pos, diameter=np.full(n, 3.0, np.float32),
                        agent_type=types,
                        extra_init={"infect_timer": np.full(n, 40, np.int32)})
    spec = sim.spec
    origin = jnp.zeros(3)
    box = jnp.asarray(cfg.cell_size)

    # --- build (the resident permutation subsumes the paper's sorting) ---
    build_fn = G.make_builder(spec, method="resident")
    build = jax.jit(lambda p: build_fn(p, origin, box))
    us_build = time_fn(build, st.pool)
    bres = build(st.pool)
    rpool, gs = bres.pool, bres.grid
    channels = rpool.channels()
    alive = rpool.alive

    # --- the two registered kernels (what make_iteration_core registers) ---
    force_k, infect_k = engine_mod.registered_kernels(cfg, [infection])
    reads = G.fused_reads([force_k, infect_k])

    # sequential per-phase sweeps (EngineConfig.fused_sweep=False schedule)
    force_seq = jax.jit(lambda g, ch, m: G.resident_apply(
        spec, g, ch, m, force_k.pair_fn, force_k.out_specs, cfg.query_chunk))
    behav_seq = jax.jit(lambda g, ch, m: G.resident_apply(
        spec, g, ch, m, infect_k.pair_fn, infect_k.out_specs,
        cfg.query_chunk))
    seq_channels = {k: v for k, v in channels.items()
                    if not k.startswith("extra.")}
    us_force = time_fn(force_seq, gs, seq_channels, alive)
    us_behav = time_fn(behav_seq, gs, seq_channels, alive)

    # fused: ONE candidate stream for both kernels, pruned to `reads`
    fused = jax.jit(lambda g, ch, m: G.resident_apply_fused(
        spec, g, ch, [force_k, infect_k], m, cfg.query_chunk))
    us_fused = time_fn(fused, gs, channels, alive)

    # gather floor: same stream, reduce-only kernel
    gather_k = G.PairKernel("gather", _gather_pair_fn(reads),
                            {"g": ((), jnp.float32)}, reads=reads)
    gather = jax.jit(lambda g, ch, m: G.resident_apply_fused(
        spec, g, ch, [gather_k], m, cfg.query_chunk))
    us_gather = time_fn(gather, gs, channels, alive)

    # statics flags (box-granular, pre-sweep) + integration + commit
    us_statics = time_fn(
        jax.jit(lambda p, g: statics_mod.update_static_flags(
            p, spec, g, jnp.ones((), jnp.int32))), rpool, gs)
    force_out = fused(gs, channels, alive)["force"]["force"]
    dlo = jnp.asarray(cfg.domain_lo, jnp.float32)
    dhi = jnp.asarray(cfg.domain_hi, jnp.float32)
    integrate = jax.jit(lambda p, f, m: jnp.where(
        m[:, None],
        jnp.clip(p + force_mod.displacement(f, cfg.force, cfg.dt), dlo, dhi),
        p))
    us_integrate = time_fn(integrate, rpool.position, force_out, alive)
    us_commit = time_fn(jax.jit(compaction.compact), rpool)

    # --- Verlet pair list: build + the same fused sweep fed from it ---
    # max_pairs sized to the density (~17 in-range at 4/box within r=4.0);
    # demand is asserted below so an undersized table can't record a win.
    max_pairs = 64
    pl_build = jax.jit(lambda g, p, m: G.build_pairlist(
        spec, g, p, m, radius=cfg.interaction_radius, max_pairs=max_pairs,
        chunk=cfg.query_chunk))
    us_pl_build = time_fn(pl_build, gs, rpool.position, alive)
    pairs = pl_build(gs, rpool.position, alive)
    demand = int(pairs.demand)
    assert demand <= max_pairs, (
        f"pair table overflowed at n={n}: demand {demand} > {max_pairs}")
    pl_fused = jax.jit(lambda g, ch, m, pl: G.resident_apply_fused(
        spec, g, ch, [force_k, infect_k], m, cfg.query_chunk, pairs=pl))
    us_pl = time_fn(pl_fused, gs, channels, alive, pairs)

    n_live = float(jnp.sum(alive))
    pairs_per_agent = float(jnp.sum(jnp.where(alive, pairs.count, 0))) / n_live
    _, run_n = G.run_bounds(spec, gs, rpool.position)
    run_n = jnp.minimum(run_n, spec.run_capacity)
    cand = jnp.where(alive, jnp.sum(run_n, axis=-1), 0)
    cand_per_agent = float(jnp.sum(cand)) / n_live
    pruning = pairs_per_agent / max(cand_per_agent, 1e-9)

    us_unfused = us_force + us_behav
    speedup = us_unfused / max(us_fused, 1e-9)
    pl_speedup = us_fused / max(us_pl, 1e-9)
    emit(f"breakdown_n{n}_fused_neighbor", us_fused,
         f"vs unfused {us_unfused:.0f}us -> {speedup:.2f}x "
         f"(footprint {len(reads)}/{len(seq_channels)} channels)")
    emit(f"breakdown_n{n}_pairlist_neighbor", us_pl,
         f"vs streamed {us_fused:.0f}us -> {pl_speedup:.2f}x "
         f"({pairs_per_agent:.1f} pairs/agent of {cand_per_agent:.1f} "
         f"candidates, pruning {pruning:.1%}; build {us_pl_build:.0f}us)")
    emit(f"breakdown_n{n}_build", us_build, "")

    # paper Fig 5 shares (agent ops vs build vs commit), for continuity
    total = us_build + us_fused + us_integrate + us_commit
    emit(f"breakdown_n{n}_fig5_shares", total,
         f"agent_ops={(us_fused + us_integrate) / total:.1%} "
         f"(paper 76.3%) build={us_build / total:.1%} (paper 18%) "
         f"commit={us_commit / total:.1%} (paper <=2.66%)")

    return {
        "n_agents": n,
        "build_us": us_build,
        "gather_us": us_gather,
        "force_us": us_force,
        "behavior_us": us_behav,
        "statics_us": us_statics,
        "integrate_us": us_integrate,
        "commit_us": us_commit,
        "fused_neighbor_us": us_fused,
        "unfused_neighbor_us": us_unfused,
        "pairlist_build_us": us_pl_build,
        "pairlist_neighbor_us": us_pl,
        "fusion_speedup": speedup,
        "pairlist_speedup": pl_speedup,
        "pairs_per_agent": pairs_per_agent,
        "candidates_per_agent": cand_per_agent,
        "pruning_ratio": pruning,
        "pair_demand": demand,
        "max_pairs": max_pairs,
        "channels_streamed_fused": len(reads),
        "channels_streamed_unfused": len(seq_channels),
        "footprint": list(reads),
    }


def run() -> None:
    sizes = [int(s) for s in os.environ.get(
        "BREAKDOWN_SIZES", "65536,262144,1048576").split(",") if s]
    records = [_one_size(n) for n in sizes]
    write_bench_json("BENCH_breakdown.json", {
        "records": records,
        "kernels": ["force", "infection"],
        "note": "standalone jitted phase timings (compile excluded); "
                "fusion_speedup = unfused_neighbor_us / fused_neighbor_us; "
                "pairlist_speedup = fused_neighbor_us / pairlist_neighbor_us",
    })
