"""Paper Fig 5 (left): operation runtime breakdown.

The paper reports agent ops at 76.3% (median), grid rebuild ~18%, sorting
0.18–6.33%, setup/teardown ≤ 2.66%. We time the engine's phases separately
(each jitted standalone) on the clustering workload and report shares.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core import compaction, grid as G
from repro.core.forces import make_force_pair_fn

from .common import emit, random_positions, time_fn

N = 20_000


def run() -> None:
    rng = np.random.default_rng(4)
    side = 110.0
    cfg = EngineConfig(capacity=N, domain_lo=(0, 0, 0), domain_hi=(side,) * 3,
                       interaction_radius=4.0, dt=0.05, max_per_box=32,
                       query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    sim = Simulation(cfg, [])
    pos = random_positions(rng, N, 2.0, side - 2.0)
    st = sim.init_state(pos, diameter=np.full(N, 3.0, np.float32))
    st = sim.step(st)
    pool = st.pool
    spec = sim.spec
    origin = jnp.zeros(3)
    r = jnp.asarray(cfg.interaction_radius)

    # resident build = grid index + the §4.2 sort + dead compaction in one
    # permutation, so the paper's separate 'sorting' phase has no standalone
    # cost on this engine; we report it folded into the build share.
    build_fn = G.make_builder(spec, method="resident")
    build = jax.jit(lambda p: build_fn(p, origin, r))
    us_build = time_fn(build, pool)
    bres = build(pool)
    rpool, gs = bres.pool, bres.grid

    channels = {k: v for k, v in rpool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(cfg.force)
    alive = rpool.alive
    forces = jax.jit(lambda g, ch: G.resident_apply(
        spec, g, ch, alive, pair,
        {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)}))
    us_forces = time_fn(forces, gs, channels)

    us_commit = time_fn(jax.jit(compaction.compact), pool)

    total = us_build + us_forces + us_commit
    emit("fig5_breakdown_grid_build", us_build,
         f"share={us_build / total:.1%} (paper median 18.0%; includes the "
         f"resident reorder that subsumes sorting)")
    emit("fig5_breakdown_agent_ops", us_forces,
         f"share={us_forces / total:.1%} (paper median 76.3%)")
    emit("fig5_breakdown_sorting", 0.0,
         "folded into grid build (resident layout; paper 0.18-6.33%)")
    emit("fig5_breakdown_commit", us_commit,
         f"share={us_commit / total:.1%} (paper <=2.66%)")
