"""Paper Fig 13: pool allocator vs general-purpose allocation.

BioDynaMo's pool allocator beats ptmalloc2/jemalloc (1.19×/1.15× median) on
agent/behavior churn and uses *less* memory. Inside jit there is no malloc —
the costs the paged KV pool (repro.serve.kv_cache) avoids are:

  (a) **recompilation**: without a pool, each new sequence length shape
      triggers an XLA compile of the consumer (the malloc-metadata analogue,
      paid per allocation pattern); the pool keeps every shape static.
  (b) **memory**: dense per-sequence max-length buffers vs ⌈len/page⌉ pages
      (the paper's bounded-waste property: ≤ page_size−1 slots/sequence).

Reported: (a) admit+release cycle time for the pool vs per-new-shape compile
time for the dense path; (b) bytes held for a mixed-length working set.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kv_cache as kvc

from .common import emit

SPEC = kvc.PagedCacheSpec(n_layers=4, n_kv_heads=4, d_head=64, page_size=16,
                          n_pages=512, max_seqs=16, max_pages_per_seq=64,
                          dtype="float32")
MAX_LEN = 1024
CYCLES = 24


def _paged_churn(lens) -> float:
    st = kvc.init_cache(SPEC)
    admit = jax.jit(lambda s, slot, n: kvc.admit_sequence(SPEC, s, slot, n))
    release = jax.jit(lambda s, slot: kvc.release_sequence(SPEC, s, slot))
    # warm the two static-shape compiles once (amortized to zero in steady state)
    st2, _ = admit(st, jnp.int32(0), jnp.int32(8))
    st2 = release(st2, jnp.int32(0))
    jax.block_until_ready(st2.block_table)
    t0 = time.perf_counter()
    for i, ln in enumerate(lens):
        slot = jnp.int32(int(i) % SPEC.max_seqs)
        st = release(st, slot)
        st, ok = admit(st, slot, jnp.int32(int(ln)))
    jax.block_until_ready(st.block_table)
    return (time.perf_counter() - t0) / len(lens) * 1e6


def _dense_churn(lens) -> float:
    """Dense per-length buffers: every new length shape compiles its consumer
    (one attention read over the cache) — the cost the pool design removes."""
    def consumer(k):
        return jnp.sum(k * 2.0)

    seen = {}
    t0 = time.perf_counter()
    for ln in lens:
        ln = int(ln)
        shape = (SPEC.n_layers, ln, SPEC.n_kv_heads, SPEC.d_head)
        if ln not in seen:
            seen[ln] = jax.jit(consumer).lower(
                jax.ShapeDtypeStruct(shape, jnp.float32)).compile()
        buf = jnp.zeros(shape, jnp.float32)
        jax.block_until_ready(seen[ln](buf))
    return (time.perf_counter() - t0) / len(lens) * 1e6


def run() -> None:
    rng = np.random.default_rng(5)
    lens = rng.integers(16, MAX_LEN, CYCLES)
    us_pool = _paged_churn(lens)
    us_dense = _dense_churn(lens)
    emit("fig13_alloc_paged_pool", us_pool,
         "admit+release cycle, zero recompiles")
    emit("fig13_alloc_dense_fresh", us_dense,
         f"per-shape compile path; pool_speedup={us_dense / us_pool:.2f}x")

    # memory held for the mixed-length working set
    pool_pages = sum(int(np.ceil(l / SPEC.page_size)) for l in lens[-16:])
    pool_bytes = pool_pages * SPEC.page_size * SPEC.n_layers \
        * SPEC.n_kv_heads * SPEC.d_head * 4 * 2
    dense_bytes = 16 * SPEC.n_layers * MAX_LEN * SPEC.n_kv_heads \
        * SPEC.d_head * 4 * 2
    emit("fig13_alloc_memory", 0.0,
         f"paged={pool_bytes / 1e6:.1f}MB dense={dense_bytes / 1e6:.1f}MB "
         f"saving={dense_bytes / pool_bytes:.2f}x")
