"""Paper Fig 6: runtime per iteration vs #agents — the linearity claim.

The paper shows runtime flat until ~1e5 agents then linear to 1e9. The
container (1 CPU core) covers 1e3→2.56e5 and validates the *slope*: a log-log
fit of runtime vs N over the linear regime should give exponent ≈ 1 (grid
build is O(N log N) from the sort; forces O(N·k)). The 256k point exercises
the resident-layout path at scale: every step re-permutes all SoA channels
and streams the force runs from the grid-ordered pool.

Emits machine-readable ``BENCH_scaling.json`` (per-N µs/step + the fitted
log-log slope). ``SCALING_SIZES`` (comma-separated) overrides the size list —
the CI smoke runs a reduced set to stay inside the runner budget.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import GrowDivide

from .common import emit, random_positions, time_fn, write_bench_json

SIZES = (1_000, 4_000, 16_000, 64_000, 256_000)


def _sizes() -> tuple:
    env = os.environ.get("SCALING_SIZES")
    if env:
        return tuple(int(s) for s in env.split(",") if s)
    return SIZES


def run() -> None:
    rng = np.random.default_rng(0)
    sizes = _sizes()
    times = []
    for n in sizes:
        side = max(40.0, (n ** (1 / 3)) * 4.0)      # constant density
        cfg = EngineConfig(capacity=int(n * 1.3), domain_lo=(0, 0, 0),
                           domain_hi=(side,) * 3, interaction_radius=4.0,
                           dt=0.05, max_per_box=32, query_chunk=4096,
                           force=ForceParams(max_displacement=0.5))
        sim = Simulation(cfg, [GrowDivide(rate=0.01, threshold_diameter=6.0)])
        pos = random_positions(rng, n, 2.0, side - 2.0)
        st = sim.init_state(pos, diameter=np.full(n, 3.0, np.float32))
        st = sim.step(st)                            # compile + warm
        us = time_fn(lambda s: sim.step(s), st, warmup=1, iters=3)
        times.append(us)
        emit(f"fig6_scaling_n{n}", us, f"n={n}")
    # slope over the linear regime (everything past the latency-bound point);
    # None (JSON null) when too few sizes — NaN is not valid JSON
    slope = None
    if len(sizes) >= 3:
        logn = np.log(np.asarray(sizes[1:], float))
        logt = np.log(np.asarray(times[1:], float))
        slope = float(np.polyfit(logn, logt, 1)[0])
        emit("fig6_scaling_slope", 0.0, f"loglog_slope={slope:.3f} (paper: ~1)")
    write_bench_json("BENCH_scaling.json", {
        "sizes": list(sizes),
        "us_per_step": {str(n): t for n, t in zip(sizes, times)},
        "agents_iter_per_sec": {str(n): n / (t / 1e6)
                                for n, t in zip(sizes, times)},
        "loglog_slope": slope,
    })
