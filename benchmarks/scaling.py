"""Paper Fig 6: runtime per iteration vs #agents — the linearity claim.

The paper shows runtime flat until ~1e5 agents then linear to 1e9. The
container (1 CPU core) covers 1e3→1e5 and validates the *slope*: a log-log
fit of runtime vs N over the linear regime should give exponent ≈ 1
(grid build is O(N log N) from the sort; forces O(N·k)).
"""

from __future__ import annotations

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import GrowDivide

from .common import emit, random_positions, time_fn

SIZES = (1_000, 4_000, 16_000, 64_000)


def run() -> None:
    rng = np.random.default_rng(0)
    times = []
    for n in SIZES:
        side = max(40.0, (n ** (1 / 3)) * 4.0)      # constant density
        cfg = EngineConfig(capacity=int(n * 1.3), domain_lo=(0, 0, 0),
                           domain_hi=(side,) * 3, interaction_radius=4.0,
                           dt=0.05, max_per_box=32, query_chunk=4096,
                           force=ForceParams(max_displacement=0.5))
        sim = Simulation(cfg, [GrowDivide(rate=0.01, threshold_diameter=6.0)])
        pos = random_positions(rng, n, 2.0, side - 2.0)
        st = sim.init_state(pos, diameter=np.full(n, 3.0, np.float32))
        st = sim.step(st)                            # compile + warm
        us = time_fn(lambda s: sim.step(s), st, warmup=1, iters=3)
        times.append(us)
        emit(f"fig6_scaling_n{n}", us, f"n={n}")
    # slope over the linear regime (largest two decades)
    logn = np.log(np.asarray(SIZES[1:], float))
    logt = np.log(np.asarray(times[1:], float))
    slope = np.polyfit(logn, logt, 1)[0]
    emit("fig6_scaling_slope", 0.0, f"loglog_slope={slope:.3f} (paper: ~1)")
