"""Paper Fig 12: agent sorting & balancing speedup vs execution frequency.

Random-initialized clustering workload (the paper's best case: peak 4.56×);
baseline is no sorting. Frequencies {1, 5, 10, 20} as in the paper.
"""

from __future__ import annotations

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation

from .common import emit, random_positions, time_fn

N = 20_000
ITERS = 5


def _bench(sort_freq: int) -> float:
    rng = np.random.default_rng(2)
    side = 110.0
    cfg = EngineConfig(capacity=N, domain_lo=(0, 0, 0), domain_hi=(side,) * 3,
                       interaction_radius=4.0, dt=0.05,
                       sort_frequency=sort_freq, max_per_box=32,
                       query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    sim = Simulation(cfg, [])
    pos = random_positions(rng, N, 2.0, side - 2.0)
    st = sim.init_state(pos, diameter=np.full(N, 3.0, np.float32))
    st = sim.step(st)

    def run_iters(s):
        for _ in range(ITERS):
            s = sim.step(s)
        return s

    return time_fn(run_iters, st, warmup=1, iters=2) / ITERS


def run() -> None:
    base = _bench(0)
    emit("fig12_sort_freq_off", base, "baseline (no sorting)")
    for freq in (1, 5, 10, 20):
        t = _bench(freq)
        emit(f"fig12_sort_freq_{freq}", t, f"speedup={base / t:.2f}x")
