"""Shared benchmark utilities. Output convention: ``name,us_per_call,derived``."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocks on async dispatch)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def random_positions(rng, n: int, lo: float, hi: float) -> np.ndarray:
    return rng.uniform(lo, hi, (n, 3)).astype(np.float32)
