"""Shared benchmark utilities. Output convention: ``name,us_per_call,derived``.

Modules may additionally record machine-readable results via
:func:`write_bench_json` (e.g. BENCH_neighbor.json) so the perf trajectory is
tracked across PRs.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocks on async dispatch)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def random_positions(rng, n: int, lo: float, hi: float) -> np.ndarray:
    return rng.uniform(lo, hi, (n, 3)).astype(np.float32)


def write_bench_json(filename: str, payload: dict) -> str:
    """Write a machine-readable benchmark record next to the repo root.

    The target directory is overridable with $BENCH_OUT_DIR (CI artifacts).
    """
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return path
