"""Ensemble engine + simulation service throughput (DESIGN.md §8).

A sweep member is small (tens to hundreds of agents), so a solo step is
dominated by per-op dispatch and host-sync overhead — exactly the regime
where vmapping the whole iteration core over a lane axis wins. This
benchmark measures that win at the *service* level:

  * **Aggregate throughput.** K lanes of an SIR model (per-lane beta via
    ``ScenarioParams``) advanced in lockstep, vs the honest sequential
    baseline: the SAME jitted 1-lane program serving every member
    back-to-back (params are traced, so the baseline pays zero per-member
    recompiles — the speedup is batching, not compile amortization). Both
    sides run the *serving loop*: one metric readout (convergence check)
    per tick, because that host sync is what a sweep actually pays — the
    ensemble amortizes ONE readout over K lanes where the sequential run
    syncs every member-step. ``*_tick_pipelined_us`` records the readout-free
    async-dispatch tick for reference; it is informational (no real sweep
    can run open-loop — retirement needs the metric).

  * **Admit/retire latency.** Median µs of the jitted lane-indexed scatter
    (``EnsembleEngine.admit``) and mask flip (``retire``) — the per-request
    service overhead continuous batching pays at iteration granularity.

  * **Lane occupancy under churn.** A :class:`~repro.serve.SimService` run
    with 2K requests of staggered step budgets over K lanes; mean occupancy
    = lane-steps actually used / (ticks × K). The service's job is keeping
    this near 1.0 (an idle lane still rides through the vmapped compute).

The config deliberately sits in the sweep regime: a domain a few boxes
across, ``max_per_box`` sized to the actual density, and
``sort_impl="argsort"`` — the counting sort's scatter passes lower to
row-at-a-time loops under a batch axis on XLA:CPU, while the comparison
sort batches cleanly (the O(N) build wins solo at scale, the argsort build
wins vmapped at sweep scale; both orderings are identical so lane-vs-solo
parity is unaffected).

Records ``BENCH_ensemble.json``; throughput entries are identity-keyed by
``n_lanes`` × ``agents_per_lane`` so benchmarks/trend.py never compares
records measured at different sizes. Env overrides (CI smoke):
``ENSEMBLE_LANES`` (comma list, default "8,64"), ``ENSEMBLE_AGENTS``,
``ENSEMBLE_STEPS``.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ScenarioParams
from repro.core.behaviors import INFECTED, Infection, RandomWalk
from repro.core.ensemble import EnsembleEngine
from repro.serve import SimRequest, SimService

from .common import emit, write_bench_json

SIDE = 12.0


def _cfg(agents: int) -> EngineConfig:
    return EngineConfig(
        capacity=max(64, -(-agents // 64) * 64),
        domain_lo=(0.0,) * 3, domain_hi=(SIDE,) * 3,
        interaction_radius=3.0, use_forces=False, detect_static=False,
        query_chunk=2048, max_per_box=4, sort_impl="argsort")


def _behaviors():
    return [RandomWalk(sigma=0.8),
            Infection(radius=3.0, beta=lambda ctx: ctx.params["beta"],
                      recovery_time=30)]


def _sir_arrays(agents: int, seed: int):
    r = np.random.RandomState(seed)
    pos = r.uniform(0, SIDE, (agents, 3)).astype(np.float32)
    types = np.zeros(agents, np.int32)
    n0 = max(agents // 50, 2)
    types[:n0] = INFECTED
    timer = np.zeros(agents, np.int32)
    timer[:n0] = 30
    return pos, np.full(agents, 1.0, np.float32), types, timer


def _stage(engine: EnsembleEngine, agents: int, seed: int):
    pos, diam, types, timer = _sir_arrays(agents, seed)
    return engine.stage_lane(pos, diam, types, {"infect_timer": timer},
                             seed=seed)


def _fill(engine: EnsembleEngine, agents: int, betas) -> object:
    state = engine.init_state()
    for lane, beta in enumerate(betas):
        state = engine.admit(state, lane, _stage(engine, agents, 100 + lane),
                             ScenarioParams.of(beta=float(beta)))
    return state


_infected = jax.jit(jax.vmap(
    lambda pool: jnp.sum((pool.agent_type == INFECTED) & pool.alive)))


def _ticks_us(engine: EnsembleEngine, state, n: int,
              readout: bool) -> float:
    """Median µs per lockstep tick, compile excluded. ``readout=True`` runs
    the serving loop: one vmapped metric readout (host sync) per tick —
    what any convergence-checked sweep pays. ``readout=False`` is the
    open-loop async-dispatch tick (informational)."""
    jax.block_until_ready(engine.step(state))                   # compile
    np.asarray(_infected(state.pool))
    ts = []
    for _ in range(3):
        s = state
        t0 = time.perf_counter()
        for _ in range(n):
            s = engine.step(s)
            if readout:
                np.asarray(_infected(s.pool))
        jax.block_until_ready(s)
        ts.append((time.perf_counter() - t0) * 1e6 / n)
    return float(np.median(ts))


def _throughput(n_lanes: int, agents: int, steps: int) -> dict:
    cfg = _cfg(agents)
    template = ScenarioParams.of(beta=0.0)
    betas = np.linspace(0.1, 0.5, n_lanes)

    ens = EnsembleEngine(cfg, _behaviors(), n_lanes, template)
    estate = _fill(ens, agents, betas)
    ens_tick_us = _ticks_us(ens, estate, steps, readout=True)
    ens_pipe_us = _ticks_us(ens, estate, steps, readout=False)

    # sequential baseline: the SAME jitted 1-lane program serves every
    # member back-to-back (params traced, zero recompiles between members),
    # checking its convergence metric each step like any real sweep run —
    # so K sequential runs cost exactly K × (steps × solo_tick)
    solo = EnsembleEngine(cfg, _behaviors(), 1, template)
    sstate = _fill(solo, agents, betas[:1])
    solo_tick_us = _ticks_us(solo, sstate, steps, readout=True)
    solo_pipe_us = _ticks_us(solo, sstate, steps, readout=False)

    ens_per_s = n_lanes * agents / (ens_tick_us * 1e-6)
    seq_per_s = agents / (solo_tick_us * 1e-6)
    speedup = ens_per_s / seq_per_s
    emit(f"ensemble_tick_l{n_lanes}_n{agents}", ens_tick_us,
         f"speedup_vs_sequential={speedup:.2f}")
    return {"n_lanes": n_lanes, "agents_per_lane": agents, "steps": steps,
            "ensemble_tick_us": ens_tick_us, "solo_tick_us": solo_tick_us,
            "ensemble_tick_pipelined_us": ens_pipe_us,
            "solo_tick_pipelined_us": solo_pipe_us,
            "ensemble_agent_steps_per_s": ens_per_s,
            "sequential_agent_steps_per_s": seq_per_s,
            "speedup_vs_sequential": speedup}


def _admit_retire(n_lanes: int, agents: int) -> dict:
    engine = EnsembleEngine(_cfg(agents), _behaviors(), n_lanes,
                            ScenarioParams.of(beta=0.0))
    state = engine.init_state()
    staged = _stage(engine, agents, 0)
    params = ScenarioParams.of(beta=0.3)
    # warm both jitted paths (lane index is traced: one compile each)
    jax.block_until_ready(engine.admit(state, 0, staged, params))
    jax.block_until_ready(engine.retire(state, 0))
    admit_ts, retire_ts = [], []
    for lane in range(min(n_lanes, 8)):
        t0 = time.perf_counter()
        s = engine.admit(state, lane, staged, params)
        jax.block_until_ready(s)
        admit_ts.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        s = engine.retire(s, lane)
        jax.block_until_ready(s)
        retire_ts.append((time.perf_counter() - t0) * 1e6)
    admit_us = float(np.median(admit_ts))
    retire_us = float(np.median(retire_ts))
    emit(f"ensemble_admit_l{n_lanes}_n{agents}", admit_us)
    emit(f"ensemble_retire_l{n_lanes}_n{agents}", retire_us)
    return {"n_lanes": n_lanes, "agents_per_lane": agents,
            "admit_us": admit_us, "retire_us": retire_us}


def _churn(n_lanes: int, agents: int, steps: int) -> dict:
    """2K staggered-budget requests over K lanes through the SimService:
    lanes retire and re-admit mid-run, so mean occupancy measures how well
    continuous batching keeps the vmapped step full."""
    svc = SimService(_cfg(agents), _behaviors(), n_lanes=n_lanes,
                     params_template=ScenarioParams.of(beta=0.0))
    n_req = 2 * n_lanes
    budgets = np.linspace(max(steps // 3, 2), steps, n_req).astype(int)
    for uid in range(n_req):
        pos, diam, types, timer = _sir_arrays(agents, 300 + uid)
        svc.submit(SimRequest(
            uid=uid, position=pos, diameter=diam, agent_type=types,
            extra_init={"infect_timer": timer}, seed=uid,
            params=ScenarioParams.of(beta=0.3),
            max_steps=int(budgets[uid])))
    svc.step()                                   # pay the compile outside
    lane_steps = n_lanes                         # ... but count its work
    t0 = time.perf_counter()
    ticks = 1
    while svc.queue or any(i is not None for i in svc.lanes):
        lane_steps += svc.step()
        ticks += 1
    wall_s = time.perf_counter() - t0
    occupancy = lane_steps / (ticks * n_lanes)
    churn_per_s = (lane_steps - n_lanes) * agents / wall_s
    emit(f"ensemble_churn_l{n_lanes}_n{agents}", wall_s * 1e6,
         f"occupancy={occupancy:.3f} ticks={ticks}")
    return {"n_lanes": n_lanes, "agents_per_lane": agents,
            "requests": n_req, "ticks": ticks,
            "mean_occupancy": occupancy,
            "churn_agent_steps_per_s": churn_per_s}


def run() -> None:
    lanes = [int(x) for x in
             os.environ.get("ENSEMBLE_LANES", "8,64").split(",")]
    agents = int(os.environ.get("ENSEMBLE_AGENTS", 64))
    steps = int(os.environ.get("ENSEMBLE_STEPS", 50))

    throughput = [_throughput(k, agents, steps) for k in lanes]
    k_max = max(lanes)
    payload = {
        "throughput": throughput,
        "admit_retire": _admit_retire(k_max, agents),
        "churn": _churn(min(lanes), agents, steps),
    }
    write_bench_json("BENCH_ensemble.json", payload)
    for t in throughput:
        if t["n_lanes"] >= 64 and t["speedup_vs_sequential"] < 3.0:
            # RuntimeError, not SystemExit: run.py aggregates failures
            raise RuntimeError(
                f"ensemble speedup {t['speedup_vs_sequential']:.2f}× at "
                f"K={t['n_lanes']} below the 3× acceptance floor")
