"""Paper Fig 9: speedup as the BioDynaMo optimizations are switched on.

Baseline = 'standard implementation': scatter-table grid (O(#boxes) touch per
rebuild), no Morton sorting, no static-region detection. Then progressively:
  +grid     resident sort-based uniform grid (§3.1 + §4.2 — the resident
            layout sorts every step, so the separate '+sort' stage now
            measures that subsumption: it must cost ~nothing extra)
  +sort     sort_frequency=10 (a no-op for resident environments)
  +statics  static-region force omission (§5) — on the quiescent-front sim

Two workloads mirror the paper's spread: 'cluster' (random init, everything
moves) and 'front' (a static lattice with an active front — statics matter;
paper's neuroscience case).

Additionally: the **static-monolayer micro-benchmark** (paper §5's
"unchanged part of the simulation" taken to its extreme): a quiescent 2-D
sheet of ~20k cells where detect_static=True must step measurably faster
than detect_static=False — the box-granular flag update plus an empty force
trip count vs a full force sweep. Recorded in ``BENCH_statics.json``.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import RandomWalk

from .common import emit, random_positions, time_fn, write_bench_json

N = 20_000
ITERS = 5


def _mk_sim(env: str, sort_freq: int, statics: bool, workload: str):
    rng = np.random.default_rng(1)
    side = 120.0
    cfg = EngineConfig(capacity=N, domain_lo=(0, 0, 0), domain_hi=(side,) * 3,
                       interaction_radius=4.0, dt=0.05,
                       environment=env, sort_frequency=sort_freq,
                       detect_static=statics, max_per_box=32,
                       query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    behaviors = []
    if workload == "cluster":
        pos = random_positions(rng, N, 2.0, side - 2.0)
    else:  # 'front': dense static lattice + small active region
        g = int(round(N ** (1 / 3)))
        xs = np.stack(np.meshgrid(*[np.arange(g) * 5.0 + 5] * 3), -1
                      ).reshape(-1, 3)[:N].astype(np.float32)
        pos = xs
        behaviors = [RandomWalk(sigma=0.4, applies_to=1)]
    sim = Simulation(cfg, behaviors)
    types = np.zeros(len(pos), np.int32)
    if workload == "front":
        types[: len(pos) // 20] = 1                  # 5% active front
    st = sim.init_state(pos, diameter=np.full(len(pos), 3.0, np.float32),
                        agent_type=types)
    return sim, st


def _bench(env, sort_freq, statics, workload):
    sim, st = _mk_sim(env, sort_freq, statics, workload)
    st = sim.step(st)
    def run_iters(s):
        for _ in range(ITERS):
            s = sim.step(s)
        return s
    return time_fn(run_iters, st, warmup=1, iters=2) / ITERS


MONO_ITERS = 5


def _monolayer_bench(statics: bool) -> float:
    """Quiescent 2-D sheet: spacing = radius, cells just out of contact, so
    the whole layer is static from iteration 2 on."""
    g = 141                                       # ≈ 20k agents in one sheet
    spacing = 4.0
    xy = np.stack(np.meshgrid(np.arange(g), np.arange(g), indexing="ij"),
                  -1).reshape(-1, 2) * spacing + spacing
    pos = np.concatenate([xy, np.full((len(xy), 1), 4.0)], 1).astype(np.float32)
    side = (g + 1) * spacing
    cfg = EngineConfig(capacity=len(pos), domain_lo=(0, 0, 0),
                       domain_hi=(side, side, 8.0),    # thin-z box table
                       interaction_radius=spacing, dt=0.05,
                       detect_static=statics, max_per_box=32,
                       query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    sim = Simulation(cfg, [])
    st = sim.init_state(pos, diameter=np.full(len(pos), 3.5, np.float32))
    st = sim.step(st)                              # compile + warm
    st = sim.step(st)                              # flags settle: all static
    if statics:
        assert int(sim.step(st).stats["n_active"]) == 0, \
            "monolayer must quiesce fully"

    def run_iters(s):
        for _ in range(MONO_ITERS):
            s = sim.step(s)
        return s

    return time_fn(run_iters, st, warmup=1, iters=3) / MONO_ITERS


def run() -> None:
    # FIG9_MONOLAYER_ONLY=1 skips the 8-config Fig-9 sweep and runs just the
    # static-monolayer micro-benchmark — the part BENCH_statics.json records —
    # at its full 20k-agent size, so the CI regression gate (benchmarks/
    # trend.py) compares like against like without paying for the sweep.
    if not os.environ.get("FIG9_MONOLAYER_ONLY"):
        _sweeps()
    off = _monolayer_bench(False)
    on = _monolayer_bench(True)
    emit("fig9_static_monolayer_off", off, "full force sweep every step")
    emit("fig9_static_monolayer_on", on,
         f"speedup={off / on:.2f}x (block-skipped force + box-table statics)")
    assert on < off, \
        f"detect_static must win on a static monolayer: {on} >= {off}"
    write_bench_json("BENCH_statics.json", {
        "scenario": "static monolayer, ~20k agents, fully quiescent",
        "detect_static_off_us_per_step": off,
        "detect_static_on_us_per_step": on,
        "speedup": off / on,
    })


def _sweeps() -> None:
    for workload in ("cluster", "front"):
        base = _bench("scatter_grid", 0, False, workload)
        emit(f"fig9_{workload}_baseline", base, "scatter grid, no opts")
        t = _bench("uniform_grid", 0, False, workload)
        emit(f"fig9_{workload}_grid", t, f"speedup={base / t:.2f}x")
        t2 = _bench("uniform_grid", 10, False, workload)
        emit(f"fig9_{workload}_grid_sort", t2, f"speedup={base / t2:.2f}x")
        t3 = _bench("uniform_grid", 10, True, workload)
        emit(f"fig9_{workload}_grid_sort_statics", t3,
             f"speedup={base / t3:.2f}x")
