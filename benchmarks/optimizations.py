"""Paper Fig 9: speedup as the BioDynaMo optimizations are switched on.

Baseline = 'standard implementation': scatter-table grid (O(#boxes) touch per
rebuild), no Morton sorting, no static-region detection. Then progressively:
  +grid     optimized sort-based uniform grid (§3.1)
  +sort     Morton agent sorting, frequency 10 (§4.2)
  +statics  static-region force omission (§5) — on the quiescent-front sim

Two workloads mirror the paper's spread: 'cluster' (random init, everything
moves — sorting matters) and 'front' (a static lattice with an active front —
statics matter; paper's neuroscience case).
"""

from __future__ import annotations

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import RandomWalk

from .common import emit, random_positions, time_fn

N = 20_000
ITERS = 5


def _mk_sim(env: str, sort_freq: int, statics: bool, workload: str):
    rng = np.random.default_rng(1)
    side = 120.0
    cfg = EngineConfig(capacity=N, domain_lo=(0, 0, 0), domain_hi=(side,) * 3,
                       interaction_radius=4.0, dt=0.05,
                       environment=env, sort_frequency=sort_freq,
                       detect_static=statics, max_per_box=32,
                       query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    behaviors = []
    if workload == "cluster":
        pos = random_positions(rng, N, 2.0, side - 2.0)
    else:  # 'front': dense static lattice + small active region
        g = int(round(N ** (1 / 3)))
        xs = np.stack(np.meshgrid(*[np.arange(g) * 5.0 + 5] * 3), -1
                      ).reshape(-1, 3)[:N].astype(np.float32)
        pos = xs
        behaviors = [RandomWalk(sigma=0.4, applies_to=1)]
    sim = Simulation(cfg, behaviors)
    types = np.zeros(len(pos), np.int32)
    if workload == "front":
        types[: len(pos) // 20] = 1                  # 5% active front
    st = sim.init_state(pos, diameter=np.full(len(pos), 3.0, np.float32),
                        agent_type=types)
    return sim, st


def _bench(env, sort_freq, statics, workload):
    sim, st = _mk_sim(env, sort_freq, statics, workload)
    st = sim.step(st)
    def run_iters(s):
        for _ in range(ITERS):
            s = sim.step(s)
        return s
    return time_fn(run_iters, st, warmup=1, iters=2) / ITERS


def run() -> None:
    for workload in ("cluster", "front"):
        base = _bench("scatter_grid", 0, False, workload)
        emit(f"fig9_{workload}_baseline", base, "scatter grid, no opts")
        t = _bench("uniform_grid", 0, False, workload)
        emit(f"fig9_{workload}_grid", t, f"speedup={base / t:.2f}x")
        t2 = _bench("uniform_grid", 10, False, workload)
        emit(f"fig9_{workload}_grid_sort", t2, f"speedup={base / t2:.2f}x")
        t3 = _bench("uniform_grid", 10, True, workload)
        emit(f"fig9_{workload}_grid_sort_statics", t3,
             f"speedup={base / t3:.2f}x")
