"""Benchmark-regression gate: fresh BENCH_*.json vs committed baselines.

CI produces fresh records (BENCH_OUT_DIR=<fresh dir>) and this module
compares their timing leaves against the baselines committed at the repo
root, failing the job on a >``threshold``× step-time regression. The
comparison is deliberately *noise-tolerant* (single-sample timings on
shared runners swing ±30-40%):

  * only timing leaves are gated (key ends in ``_us``/``us_per_step``/
    ``ms_per_step`` or sits under a ``search_us``/``build_us``/
    ``us_per_step`` mapping), plus higher-is-better leaves (``*_per_s``
    throughput rates and ``*occupancy``, gated on the inverted ratio) —
    other derived quantities (slopes, speedups, counts) are informational;
  * entries faster than ``--floor-us`` in the baseline are reported but
    never gated (short timings on shared CI runners are dominated by
    scheduler noise);
  * a metric ratio in (threshold, 1.5·threshold] only fails when the
    file's *median* ratio has also drifted (>1.15) — a real regression in
    a code path moves its related metrics together, a lone borderline
    spike is noise; ratios beyond 1.5·threshold fail on their own;
  * committed baselines are *envelopes* (per-key max over several clean
    runs), so the threshold is measured from the slow edge of normal
    variance, not from one lucky sample;
  * missing files or keys are skipped with a note (CI smoke runs reduced
    size lists), never failed.

Baselines are tied to the hardware that measured them: a runner-class
change (or first run on new CI hardware) can shift every ratio uniformly.
If the gate fails across the board with a drifted file median, re-baseline
from the job's uploaded ``bench-records`` artifact (it contains the fresh
records) rather than chasing a phantom regression.

Writes a markdown trend table to ``$GITHUB_STEP_SUMMARY`` when set (the CI
job summary), always to stdout.

Usage:
    python -m benchmarks.trend --baseline . --fresh bench_fresh \
        [--threshold 1.3] [--floor-us 20000]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

# Files under the gate. BENCH_capacity.json joins with a key filter: its
# whole-step times depend on where rungs/recompiles land in the growth
# schedule (not apples-to-apples across runs), but the per-rung standalone
# phase timings (``build_us`` — the O(N) counting-sort build — plus the
# ``neighbor_us``/``commit_us`` buckets split out of step_other_us; the
# neighbor bucket is recorded both ``streamed_neighbor_us`` and
# ``pairlist_neighbor_us``, which the ``neighbor_us`` substring filter
# admits) are jit-warm measurements at a fixed capacity, comparable across
# PRs. BENCH_breakdown.json needs no filter: every ``*_us`` leaf is a
# standalone fixed-shape phase timing keyed by n_agents — this is where a
# fused-sweep regression (fused_neighbor_us) or a Verlet pair-list
# regression (pairlist_build_us / pairlist_neighbor_us) fails the gate.
GATED_FILES = ("BENCH_neighbor.json", "BENCH_scaling.json",
               "BENCH_statics.json", "BENCH_distributed.json",
               "BENCH_capacity.json", "BENCH_breakdown.json",
               "BENCH_ensemble.json")
_FILE_KEY_FILTER = {"BENCH_capacity.json": lambda path: any(
    k in path for k in ("build_us", "neighbor_us", "commit_us"))}

_TIMING_SUFFIXES = ("_us", "us_per_step", "ms_per_step")
_TIMING_PARENTS = ("search_us", "build_us", "us_per_step")

# Higher-is-better leaves (BENCH_ensemble.json: aggregate throughput rates
# and lane occupancy). Gated with the INVERTED ratio — baseline/fresh — so
# a throughput drop fails exactly like a timing rise. These aggregate
# whole-run measurements (tens of thousands of agent-steps), so the µs
# noise floor does not apply; their envelope convention is per-key *min*
# over clean runs (the slow edge), mirroring the per-key max for timings.
_INVERSE_SUFFIXES = ("_per_s", "occupancy")


def _flatten(obj, prefix="") -> Dict[str, float]:
    """Dotted-path → numeric leaf map (lists indexed by stable labels)."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            # label list entries by their stable identity keys (ALL present
            # tags, so a baseline measured at one size never compares against
            # a fresh record at another — mismatched keys are skipped), else
            # by index
            label = str(i)
            if isinstance(v, dict):
                tags = [f"{t}={v[t]}"
                        for t in ("n_shards", "n_agents", "n", "capacity",
                                  "n_lanes", "agents_per_lane")
                        if t in v]
                if tags:
                    label = ",".join(tags)
            out.update(_flatten(v, f"{prefix}{label}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def _is_timing(path: str) -> bool:
    if path.startswith("history."):     # archival constants, not measurements
        return False
    leaf = path.rsplit(".", 1)[-1]
    if any(leaf.endswith(s) for s in _TIMING_SUFFIXES):
        return True
    parts = path.split(".")
    return any(p in _TIMING_PARENTS for p in parts[:-1])


def _is_inverse(path: str) -> bool:
    """Higher-is-better leaf (throughput rate / occupancy)."""
    leaf = path.rsplit(".", 1)[-1]
    return any(leaf.endswith(s) for s in _INVERSE_SUFFIXES)


def compare(baseline_dir: str, fresh_dir: str, threshold: float,
            floor_us: float) -> tuple[List[dict], List[str]]:
    rows, notes = [], []
    for fname in GATED_FILES:
        bpath = os.path.join(baseline_dir, fname)
        fpath = os.path.join(fresh_dir, fname)
        if not os.path.exists(bpath):
            notes.append(f"no committed baseline for {fname} — skipped")
            continue
        if not os.path.exists(fpath):
            notes.append(f"no fresh record for {fname} — skipped")
            continue
        with open(bpath) as f:
            base = _flatten(json.load(f))
        with open(fpath) as f:
            fresh = _flatten(json.load(f))
        key_filter = _FILE_KEY_FILTER.get(fname)
        file_rows = []
        for path, bval in sorted(base.items()):
            inverse = _is_inverse(path)
            if (not inverse and not _is_timing(path)) or path not in fresh:
                continue
            if key_filter is not None and not key_filter(path):
                continue
            fval = fresh[path]
            if inverse:
                # throughput/occupancy: regression = fresh BELOW baseline,
                # so invert the ratio; whole-run aggregates, no µs floor
                ratio = bval / fval if fval > 0 else float("inf")
                gated = True
            else:
                base_us = bval * (1000.0 if "ms_per_step" in path else 1.0)
                ratio = fval / bval if bval > 0 else float("inf")
                gated = base_us >= floor_us
            file_rows.append({
                "file": fname, "metric": path, "baseline": bval,
                "fresh": fval, "ratio": ratio, "gated": gated,
            })
        gated_ratios = sorted(r["ratio"] for r in file_rows if r["gated"])
        med = (gated_ratios[len(gated_ratios) // 2] if gated_ratios else 1.0)
        for r in file_rows:
            # corroboration rule: borderline spikes need the file's median
            # to have drifted too; big spikes fail alone
            r["regressed"] = r["gated"] and r["ratio"] > threshold and (
                med > 1.15 or r["ratio"] > 1.5 * threshold)
        rows.extend(file_rows)
    return rows, notes


def markdown(rows: List[dict], notes: List[str], threshold: float) -> str:
    lines = ["## Benchmark trend (fresh vs committed baseline)", "",
             f"Gate: fail on >{threshold}× step-time regression "
             "(sub-floor entries informational).", "",
             "| file | metric | baseline | fresh | ratio | status |",
             "|---|---|---:|---:|---:|---|"]
    for r in rows:
        status = ("**REGRESSED**" if r["regressed"]
                  else "ok" if r["gated"] else "noise-floor")
        if r["gated"] and r["ratio"] < 1 / 1.1:
            status = "improved"
        lines.append(
            f"| {r['file']} | `{r['metric']}` | {r['baseline']:.1f} | "
            f"{r['fresh']:.1f} | {r['ratio']:.2f}× | {status} |")
    if notes:
        lines += [""] + [f"- {n}" for n in notes]
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=".")
    ap.add_argument("--fresh", default="bench_fresh")
    ap.add_argument("--threshold", type=float, default=1.3)
    ap.add_argument("--floor-us", type=float, default=20000.0,
                    help="baseline timings below this many µs are reported "
                         "but never gated (CI noise)")
    args = ap.parse_args()

    rows, notes = compare(args.baseline, args.fresh, args.threshold,
                          args.floor_us)
    md = markdown(rows, notes, args.threshold)
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md)

    regressed = [r for r in rows if r["regressed"]]
    if regressed:
        for r in regressed:
            print(f"REGRESSION: {r['file']} {r['metric']} "
                  f"{r['baseline']:.1f} -> {r['fresh']:.1f} "
                  f"({r['ratio']:.2f}x > {args.threshold}x)", file=sys.stderr)
        return 1
    if not rows:
        print("no comparable metrics found — check --baseline/--fresh dirs",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
