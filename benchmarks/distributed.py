"""Distributed weak/strong scaling — TeraAgent-direction record (DESIGN.md §7).

Runs the shard_map engine (every slab executing the shared iteration core)
over 1..8 host-platform devices and records per-step timing to
``BENCH_distributed.json``:

  * **weak scaling**: fixed agents/shard, shards ∈ {1, 2, 4, 8} — the default
    per-shard population makes the 8-shard point a ≥1M-agent run.
  * **strong scaling**: fixed total population across shards ∈ {2, 4, 8},
    plus the fitted log-log slope of time vs shards (−1 would be ideal; on
    this container all "devices" share one physical core, so the honest
    expectation is ≈ 0 — the record tracks the *trend* across PRs and real
    multi-core/TPU runs).

Any halo/migration/box overflow flag fails the run (exit 1) — the §4.2
never-silent-loss contract extends to benchmarks.

Must run as its own process (forces the device count before importing jax):

    PYTHONPATH=src:. python -m benchmarks.distributed

Env overrides for CI smoke: DIST_BENCH_AGENTS_PER_SHARD, DIST_BENCH_SHARDS
(comma-separated), DIST_BENCH_STEPS.
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import numpy as np  # noqa: E402

AGENTS_PER_SHARD = int(os.environ.get("DIST_BENCH_AGENTS_PER_SHARD", 131_072))
SHARD_COUNTS = tuple(int(s) for s in
                     os.environ.get("DIST_BENCH_SHARDS", "1,2,4,8").split(","))
N_STEPS = int(os.environ.get("DIST_BENCH_STEPS", 3))


def _flags(state) -> int:
    """All never-silent flags of one step (stats are per-step, not
    cumulative — every step must be inspected)."""
    return sum(state.stats.flags().values())


def _step_time(dsim, state, n_steps: int) -> tuple:
    """(median wall ms/step, overflow flag count, final state), after one
    warm (compile) step."""
    import jax
    state = dsim.step(state)
    jax.block_until_ready(state.channels["position"])
    overflow = _flags(state)
    times = []
    for _ in range(n_steps):
        t0 = time.perf_counter()
        state = dsim.step(state)
        jax.block_until_ready(state.channels["position"])
        times.append(time.perf_counter() - t0)
        overflow += _flags(state)
    return float(np.median(times) * 1e3), overflow, state


def _run_case(n_shards: int, n_total: int) -> dict:
    import jax
    from repro.core import DistConfig, DistributedSimulation, EngineConfig, ForceParams

    rng = np.random.default_rng(n_shards)
    # constant density ≈ 2 agents/box at r=4 (same regime as BENCH_scaling)
    side = float(np.ceil((n_total / 2.0) ** (1 / 3)) * 4.0)
    cfg = EngineConfig(capacity=n_total, domain_lo=(0, 0, 0),
                       domain_hi=(side,) * 3, interaction_radius=4.0,
                       dt=0.05, max_per_box=32, query_chunk=4096,
                       force=ForceParams(max_displacement=0.5))
    per = n_total // n_shards
    # ghost band ≈ (r/side)·n_total agents per face at uniform density; ×2.5
    # headroom covers quantile-slab density variation (overflow still flagged)
    band = int(n_total * cfg.interaction_radius / side * 2.5) + 256
    dcfg = DistConfig(engine=cfg, n_shards=n_shards,
                      local_capacity=int(per * 1.25) + 64,
                      halo_capacity=min(band, int(per * 1.25) + 64),
                      migrate_capacity=max(256, per // 16),
                      rebalance_frequency=4)
    dsim = DistributedSimulation(dcfg)
    pos = rng.uniform(1.0, side - 1.0, (n_total, 3)).astype(np.float32)
    state = dsim.init_state(pos, diameter=np.full(n_total, 3.0, np.float32))
    ms, overflow, state = _step_time(dsim, state, N_STEPS)
    n_live = int(np.asarray(state.stats["n_live"]).sum())
    del state, dsim
    return {"n_shards": n_shards, "n_agents": n_total, "side": side,
            "ms_per_step": ms, "agents_per_sec": n_total / (ms / 1e3),
            "n_live": n_live, "overflow": overflow}


def run() -> None:
    import jax
    n_dev = len(jax.devices())
    shard_counts = [s for s in SHARD_COUNTS if s <= n_dev]
    record = {"device_count": n_dev, "backend": jax.default_backend(),
              "agents_per_shard": AGENTS_PER_SHARD,
              "weak": [], "strong": []}
    failures = 0

    for s in shard_counts:
        case = _run_case(s, AGENTS_PER_SHARD * s)
        record["weak"].append(case)
        failures += case["overflow"]
        print(f"weak  shards={s} n={case['n_agents']:>9} "
              f"{case['ms_per_step']:9.1f} ms/step "
              f"({case['agents_per_sec']:.3g} agents/s)")

    n_strong = AGENTS_PER_SHARD * max(shard_counts)
    for s in [s for s in shard_counts if s > 1]:
        case = _run_case(s, n_strong)
        record["strong"].append(case)
        failures += case["overflow"]
        print(f"strong shards={s} n={case['n_agents']:>9} "
              f"{case['ms_per_step']:9.1f} ms/step")

    if len(record["strong"]) >= 2:
        ls = np.log([c["n_shards"] for c in record["strong"]])
        lt = np.log([c["ms_per_step"] for c in record["strong"]])
        record["strong_loglog_slope"] = float(np.polyfit(ls, lt, 1)[0])
        print(f"strong scaling log-log slope: "
              f"{record['strong_loglog_slope']:.3f} (ideal -1; "
              f"~0 expected on a single shared core)")
    if len(record["weak"]) >= 2:
        t0 = record["weak"][0]["ms_per_step"]
        record["weak_efficiency"] = {
            str(c["n_shards"]): t0 / c["ms_per_step"] for c in record["weak"]}

    from benchmarks.common import write_bench_json
    write_bench_json("BENCH_distributed.json", record)
    if failures:
        raise SystemExit(f"overflow flags raised during benchmark: {failures}")


if __name__ == "__main__":
    run()
