"""Engine force_impl='pallas' (K1 kernel path) ≡ pure-XLA engine path."""

import numpy as np
import pytest

from repro.core import EngineConfig, ForceParams, Simulation


@pytest.mark.parametrize("adhesion", [None, ((0.3, 0.05), (0.05, 0.3))])
def test_pallas_force_path_matches_xla(rng, adhesion):
    pos = rng.uniform(4, 28, (80, 3)).astype(np.float32)
    types = rng.integers(0, 2, 80).astype(np.int32)
    finals = {}
    for impl in ("xla", "pallas"):
        cfg = EngineConfig(capacity=128, domain_lo=(0, 0, 0),
                           domain_hi=(32, 32, 32), interaction_radius=4.0,
                           dt=0.1, force_impl=impl, max_per_box=64,
                           adhesion=adhesion,
                           force=ForceParams(max_displacement=0.5))
        sim = Simulation(cfg, [])
        st = sim.init_state(pos, diameter=np.full(80, 3.0, np.float32),
                            agent_type=types)
        for _ in range(3):
            st = sim.step(st)
        finals[impl] = np.asarray(st.pool.position[:80])
    np.testing.assert_allclose(finals["pallas"], finals["xla"],
                               rtol=1e-4, atol=1e-4)


def test_pallas_path_with_statics(rng):
    """Kernel path + static detection: quiescent lattice goes fully static."""
    cfg = EngineConfig(capacity=256, domain_lo=(0, 0, 0),
                       domain_hi=(40, 40, 40), interaction_radius=4.0,
                       detect_static=True, dt=0.1, force_impl="pallas",
                       force=ForceParams(max_displacement=0.5))
    sim = Simulation(cfg, [])
    xs = np.stack(np.meshgrid(*[np.arange(4) * 8.0 + 4] * 3), -1
                  ).reshape(-1, 3).astype(np.float32)
    st = sim.init_state(xs, diameter=np.full(len(xs), 2.0, np.float32))
    st = sim.step(st)
    st = sim.step(st)
    assert int(st.stats["n_active"]) == 0
