"""Uniform grid (paper §3.1): every environment must exactly match brute force."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import agents, grid as G

RADIUS = 2.0


def _mk(rng, n, c, lo=0.0, hi=20.0):
    pos = rng.uniform(lo, hi, (n, 3)).astype(np.float32)
    pool = agents.make_pool(c, position=jnp.asarray(pos),
                            diameter=jnp.full((n,), 1.0))
    return pos, pool


def _brute_counts(pos, r):
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    return ((d2 <= r * r) & ~np.eye(len(pos), dtype=bool)).sum(1)


def _count_pair_fn(q, nbr, valid, q_slot):
    d = nbr["position"] - q["position"][:, None, :]
    ok = valid & nbr["alive"] & ((d * d).sum(-1) <= RADIUS ** 2)
    return {"cnt": ok.sum(-1).astype(jnp.int32)}


@pytest.mark.parametrize("n,c,chunk", [(50, 64, 16), (200, 256, 64),
                                       (333, 512, 128)])
def test_uniform_grid_matches_brute_force(rng, n, c, chunk):
    pos, pool = _mk(rng, n, c)
    spec = G.GridSpec(dims=(10, 10, 10), max_per_box=32, query_chunk=chunk)
    gs = G.make_builder(spec, method="sorted")(pool, jnp.zeros(3),
                                               jnp.asarray(RADIUS)).grid
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    out = G.neighbor_apply(spec, gs, channels,
                           jnp.arange(c, dtype=jnp.int32), pool.n_live,
                           _count_pair_fn, {"cnt": ((), jnp.int32)})
    np.testing.assert_array_equal(np.asarray(out["cnt"][:n]), _brute_counts(pos, RADIUS))
    assert np.asarray(out["cnt"][n:]).sum() == 0   # dead slots untouched


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 120), st.integers(0, 10_000))
def test_uniform_grid_property(n, seed):
    """Property: grid neighbor counts == brute force for random configurations."""
    rng = np.random.default_rng(seed)
    pos, pool = _mk(rng, n, max(n, 8))
    spec = G.GridSpec(dims=(10, 10, 10), max_per_box=max(n, 8), query_chunk=32)
    gs = G.make_builder(spec, method="sorted")(pool, jnp.zeros(3),
                                               jnp.asarray(RADIUS)).grid
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    out = G.neighbor_apply(spec, gs, channels,
                           jnp.arange(pool.capacity, dtype=jnp.int32),
                           pool.n_live, _count_pair_fn, {"cnt": ((), jnp.int32)})
    np.testing.assert_array_equal(np.asarray(out["cnt"][:n]),
                                  _brute_counts(pos, RADIUS))


def test_overflow_flag(rng):
    # 100 agents in one box -> max_count must exceed a small K
    pos = rng.uniform(0.0, 1.0, (100, 3)).astype(np.float32)
    pool = agents.make_pool(128, position=jnp.asarray(pos))
    spec = G.GridSpec(dims=(8, 8, 8), max_per_box=8)
    gs = G.make_builder(spec, method="sorted")(pool, jnp.zeros(3),
                                               jnp.asarray(2.0)).grid
    assert int(gs.max_count) == 100


def test_dead_agents_excluded(rng):
    pos, pool = _mk(rng, 64, 64)
    alive = pool.alive.at[10:20].set(False)
    pool = dataclasses.replace(pool, alive=alive)
    spec = G.GridSpec(dims=(10, 10, 10), max_per_box=64, query_chunk=32)
    gs = G.make_builder(spec, method="sorted")(pool, jnp.zeros(3),
                                               jnp.asarray(RADIUS)).grid
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    out = G.neighbor_apply(spec, gs, channels,
                           jnp.arange(64, dtype=jnp.int32), jnp.int32(64),
                           _count_pair_fn, {"cnt": ((), jnp.int32)})
    keep = np.asarray(alive)
    sub = pos[keep]
    d2 = ((sub[:, None] - sub[None]) ** 2).sum(-1)
    exp = ((d2 <= RADIUS ** 2) & ~np.eye(len(sub), dtype=bool)).sum(1)
    np.testing.assert_array_equal(np.asarray(out["cnt"])[keep], exp)


def test_scatter_and_hash_grids_match(rng):
    pos, pool = _mk(rng, 150, 256)
    spec = G.GridSpec(dims=(10, 10, 10), max_per_box=32)
    bf = _brute_counts(pos, RADIUS)
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)

    sg = G.make_builder(spec, method="scatter")(pool, jnp.zeros(3),
                                                jnp.asarray(RADIUS)).grid
    ids, valid = G.scatter_grid_candidates(spec, sg, jnp.asarray(pos))
    for name, (idn, vl) in {"scatter": (np.asarray(ids), np.asarray(valid))}.items():
        cnt = np.zeros(150, int)
        for i in range(150):
            js = np.unique(idn[i][vl[i]])
            js = js[js != i]
            cnt[i] = (d2[i][js] <= RADIUS ** 2).sum()
        np.testing.assert_array_equal(cnt, bf, err_msg=name)

    hg = G.make_builder(spec, method="hash")(pool, jnp.zeros(3),
                                             jnp.asarray(RADIUS)).grid
    ids, valid = G.hash_grid_candidates(spec, hg, jnp.asarray(pos))
    idn, vl = np.asarray(ids), np.asarray(valid)
    cnt = np.zeros(150, int)
    for i in range(150):
        js = np.unique(idn[i][vl[i]])
        js = js[js != i]
        cnt[i] = (d2[i][js] <= RADIUS ** 2).sum()
    np.testing.assert_array_equal(cnt, bf)
