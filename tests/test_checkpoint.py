"""train/checkpoint.py corner cases — the fault-tolerance substrate.

The happy paths (roundtrip, async+gc, mismatch raises) live in
test_train_serve.py; the simulation checkpointing layer (core/simcheck.py)
leans on the corners tested here: the GC keep-window under interleaved
sync/async saves, crash debris (a stale ``step_N.tmp`` dir from a SIGKILLed
write) never corrupting later saves or discovery, the structure-mismatch
message naming the offending keys, and manifest ``extras`` round-tripping.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint


def test_gc_keep_window_exact(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path), keep=3)
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    for s in range(1, 9):
        ck.save_async(s, tree)
    ck.wait()
    assert checkpoint.list_steps(str(tmp_path)) == [6, 7, 8]
    # every survivor is restorable, not just listed
    for s in (6, 7, 8):
        out = checkpoint.restore(str(tmp_path), s, {"w": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(4, dtype=np.float32))


def test_stale_tmp_dir_is_harmless_and_collected(tmp_path):
    """A crash mid-write leaves ``step_N.tmp`` — it must not shadow real
    checkpoints, must not break discovery, and the GC must sweep it."""
    d = str(tmp_path)
    checkpoint.save(d, 1, {"a": jnp.ones(2)})
    # simulate a killed writer: partial tmp dir with a half-written file
    stale = os.path.join(d, "step_000000002.tmp")
    os.makedirs(stale)
    with open(os.path.join(stale, "arrays.npz"), "w") as f:
        f.write("partial garbage")
    assert checkpoint.list_steps(d) == [1]          # tmp is invisible
    assert checkpoint.latest_step(d) == 1
    # a later save of the SAME step must overwrite the debris atomically
    checkpoint.save(d, 2, {"a": jnp.full(2, 5.0)})
    assert checkpoint.latest_step(d) == 2
    out = checkpoint.restore(d, 2, {"a": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(2, 5.0))
    ck = checkpoint.AsyncCheckpointer(d, keep=2)
    ck.save_async(3, {"a": jnp.ones(2)})
    ck.wait()
    leftovers = [n for n in os.listdir(d) if n.endswith(".tmp")]
    assert leftovers == [], f"gc left crash debris: {leftovers}"


def test_latest_step_survives_crash_before_latest_update(tmp_path):
    """Dying between the atomic rename and the LATEST write must not roll
    the run back a save: the directory listing is authoritative."""
    d = str(tmp_path)
    checkpoint.save(d, 4, {"a": jnp.ones(2)})
    checkpoint.save(d, 9, {"a": jnp.ones(2)})
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("4")                                # stale pointer
    assert checkpoint.latest_step(d) == 9


def test_structure_mismatch_message_names_keys(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, {"present": jnp.ones(3), "both": jnp.ones(1)})
    with pytest.raises(ValueError, match="structure mismatch") as e:
        checkpoint.restore(d, 1, {"wanted": jnp.ones(3), "both": jnp.ones(1)})
    msg = str(e.value)
    assert "wanted" in msg and "present" in msg, \
        f"mismatch message must name missing AND extra keys: {msg}"
    assert "'both'" not in msg, f"matching keys are not mismatches: {msg}"


def test_restore_shape_mismatch_names_key(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, {"a": jnp.ones((2, 3))})
    with pytest.raises(ValueError, match="a"):
        checkpoint.restore(d, 1, {"a": jnp.ones((3, 2))})


def test_manifest_extras_roundtrip(tmp_path):
    d = str(tmp_path)
    extras = {"kind": "engine", "knobs": {"capacity": 128, "dt": 0.25}}
    checkpoint.save(d, 3, {"a": jnp.ones(2)}, extras=extras)
    man = checkpoint.load_manifest(d, 3)
    assert man["step"] == 3
    assert man["extras"] == json.loads(json.dumps(extras))
    # async path threads extras through too
    ck = checkpoint.AsyncCheckpointer(d, keep=2)
    ck.save_async(4, {"a": jnp.ones(2)}, extras={"kind": "dist"})
    ck.wait()
    assert checkpoint.load_manifest(d, 4)["extras"] == {"kind": "dist"}
