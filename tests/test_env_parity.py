"""Cross-environment force parity: every neighbor environment must agree.

One randomized agent cloud, five force paths: uniform grid (XLA), uniform
grid via the Pallas K1 kernel (interpret mode), scatter-table grid, hash grid,
and the exact O(N²) brute-force oracle. All five must agree within tolerance —
including on an *anisotropic* domain, which exercises the exact-size
``prod(dims)`` table (a Morton-padded table would index out of its real box
range there; DESIGN.md §3).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agents, grid as G
from repro.core.forces import ForceParams, make_force_pair_fn
from repro.kernels import ops as kops

OUT_SPECS = {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)}


def _cloud(rng, n, lo, hi):
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    pos = rng.uniform(lo + 0.5, hi - 0.5, (n, 3)).astype(np.float32)
    dia = rng.uniform(0.8, 1.4, (n,)).astype(np.float32)
    return pos, dia


def _forces_all_envs(pool, spec, radius, channels, pair):
    c = pool.capacity
    all_idx = jnp.arange(c, dtype=jnp.int32)
    n_q = jnp.int32(c)
    origin = jnp.zeros(3)
    r = jnp.asarray(radius)
    out = {}

    gs = G.build(spec, pool, origin, r)
    assert int(gs.max_run_count) <= spec.run_capacity
    out["uniform"] = G.neighbor_apply(spec, gs, channels, all_idx, n_q,
                                      pair, OUT_SPECS)
    # the cached-pipeline path the engine shares across consumers
    cand = G.build_candidates(spec, gs, channels)
    out["uniform_cached"] = G.candidates_apply(spec, cand, channels, all_idx,
                                               n_q, pair, OUT_SPECS)

    sg = G.build_scatter_grid(spec, pool, origin, r)
    hg = G.build_hash_grid(spec, pool, origin, r)
    for name, cand_fn in (
            ("scatter", lambda qp: G.scatter_grid_candidates(spec, sg, qp)),
            ("hash", lambda qp: G.hash_grid_candidates(spec, hg, qp))):
        def cf(q_pos, q_slot, cand_fn=cand_fn):
            ids, valid = cand_fn(q_pos)
            valid &= ids != q_slot[:, None]
            return ids, valid
        out[name] = G.chunk_apply(channels, channels, all_idx, n_q, cf,
                                  pair, OUT_SPECS, spec.query_chunk)

    out["brute"] = G.brute_force_apply(channels, pool.alive, pair, OUT_SPECS)
    return out


@pytest.mark.parametrize("domain,dims,n", [
    ((16.0, 16.0, 16.0), (8, 8, 8), 300),
    ((40.0, 16.0, 8.0), (20, 8, 4), 350),     # anisotropic: non-cubic table
])
def test_all_environments_agree(rng, domain, dims, n):
    radius = 2.0
    pos, dia = _cloud(rng, n, (0, 0, 0), domain)
    pool = agents.make_pool(n, position=jnp.asarray(pos),
                            diameter=jnp.asarray(dia))
    spec = G.GridSpec(dims=dims, max_per_box=n, max_per_run=n, query_chunk=128)
    assert spec.table_size == dims[0] * dims[1] * dims[2]   # no pow2 padding
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    res = _forces_all_envs(pool, spec, radius, channels, pair)

    ref = np.asarray(res["brute"]["force"])
    for name in ("uniform", "uniform_cached", "scatter", "hash"):
        np.testing.assert_allclose(np.asarray(res[name]["force"]), ref,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_array_equal(np.asarray(res[name]["force_nnz"]),
                                      np.asarray(res["brute"]["force_nnz"]),
                                      err_msg=name)


@pytest.mark.parametrize("dims,domain", [
    ((8, 8, 8), (16.0, 16.0, 16.0)),
    ((20, 8, 4), (40.0, 16.0, 8.0)),          # anisotropic linear-key table
])
def test_pallas_collision_matches_xla_grid(rng, dims, domain):
    """K1 kernel (linear-key column map, interpret mode) vs the XLA grid path."""
    n, c = 260, 384
    box = 2.0
    pos, _ = _cloud(rng, n, (0, 0, 0), domain)
    dia = rng.uniform(0.5, 1.4, (n,)).astype(np.float32)
    P = np.zeros((c, 3), np.float32); P[:n] = pos
    D = np.zeros((c,), np.float32); D[:n] = dia
    alive = np.zeros((c,), bool); alive[:n] = True
    pool = agents.make_pool(c, position=jnp.asarray(pos),
                            diameter=jnp.asarray(dia))
    pool = dataclasses.replace(pool, alive=jnp.asarray(alive))

    f_k1, nnz_k1, ovf = kops.collision_force(
        jnp.asarray(P), jnp.asarray(D), jnp.zeros((c,), jnp.int32),
        jnp.asarray(alive), jnp.asarray(alive), jnp.zeros(3),
        jnp.asarray(box), dims=dims, k_rep=2.0, adhesion=None,
        adhesion_band=0.4)
    assert not bool(ovf)

    spec = G.GridSpec(dims=dims, max_per_box=c, query_chunk=128)
    gs = G.build(spec, pool, jnp.zeros(3), jnp.asarray(box))
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    res = G.neighbor_apply(spec, gs, channels,
                           jnp.arange(c, dtype=jnp.int32), pool.n_live,
                           pair, OUT_SPECS)
    np.testing.assert_allclose(np.asarray(f_k1), np.asarray(res["force"]),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(nnz_k1),
                                  np.asarray(res["force_nnz"]))
