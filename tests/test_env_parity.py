"""Cross-environment force parity: every neighbor environment must agree.

One randomized agent cloud, six force paths: uniform grid (wide candidate
matrix), the resident run-streaming loop (grid.make_builder('resident') +
grid.resident_apply — the engine's hot path), uniform grid via the Pallas K1
kernel (interpret mode), scatter-table grid, hash grid (streamed probes), and
the exact O(N²) brute-force oracle. All must agree within tolerance —
including on an *anisotropic* domain, which exercises the exact-size
``prod(dims)`` table (a Morton-padded table would index out of its real box
range there; DESIGN.md §3).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agents, grid as G
from repro.core.forces import ForceParams, make_force_pair_fn
from repro.kernels import ops as kops

OUT_SPECS = {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)}


def _cloud(rng, n, lo, hi):
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    pos = rng.uniform(lo + 0.5, hi - 0.5, (n, 3)).astype(np.float32)
    dia = rng.uniform(0.8, 1.4, (n,)).astype(np.float32)
    return pos, dia


def _forces_all_envs(pool, spec, radius, channels, pair):
    c = pool.capacity
    all_idx = jnp.arange(c, dtype=jnp.int32)
    n_q = jnp.int32(c)
    origin = jnp.zeros(3)
    r = jnp.asarray(radius)
    out = {}

    sres = G.make_builder(spec, method="sorted")(pool, origin, r)
    gs = sres.grid
    assert int(sres.overflow) == 0
    out["uniform"] = G.neighbor_apply(spec, gs, channels, all_idx, n_q,
                                      pair, OUT_SPECS)
    # resident run-streaming path (the engine's hot path): permutes the pool
    # into grid order; map the forces back to slot order for comparison
    rres = G.make_builder(spec, method="resident")(pool, origin, r)
    rpool, rgs, order = rres.pool, rres.grid, rres.order
    rch = {k: v for k, v in rpool.channels().items()
           if not k.startswith("extra.")}
    res = G.resident_apply(spec, rgs, rch, rpool.alive, pair, OUT_SPECS,
                           spec.query_chunk)
    out["uniform_resident"] = {
        name: jnp.zeros_like(val).at[order].set(val)
        for name, val in res.items()}

    sg = G.make_builder(spec, method="scatter")(pool, origin, r).grid
    hg = G.make_builder(spec, method="hash")(pool, origin, r).grid

    def cf(q_pos, q_slot):
        ids, valid = G.scatter_grid_candidates(spec, sg, q_pos)
        valid &= ids != q_slot[:, None]
        return ids, valid
    out["scatter"] = G.chunk_apply(channels, channels, all_idx, n_q, cf,
                                   pair, OUT_SPECS, spec.query_chunk)

    def hash_phase(q_pos, q_slot, j):
        ids, valid = G.hash_grid_probe(spec, hg, q_pos, j)
        valid &= ids != q_slot[:, None]
        return ids, valid
    out["hash"] = G.phased_chunk_apply(channels, channels, all_idx, n_q,
                                       hash_phase, 27, pair, OUT_SPECS,
                                       spec.query_chunk)

    out["brute"] = G.brute_force_apply(channels, pool.alive, pair, OUT_SPECS)
    return out


@pytest.mark.parametrize("domain,dims,n", [
    ((16.0, 16.0, 16.0), (8, 8, 8), 300),
    ((40.0, 16.0, 8.0), (20, 8, 4), 350),     # anisotropic: non-cubic table
])
def test_all_environments_agree(rng, domain, dims, n):
    radius = 2.0
    pos, dia = _cloud(rng, n, (0, 0, 0), domain)
    pool = agents.make_pool(n, position=jnp.asarray(pos),
                            diameter=jnp.asarray(dia))
    spec = G.GridSpec(dims=dims, max_per_box=n, max_per_run=n, query_chunk=128)
    assert spec.table_size == dims[0] * dims[1] * dims[2]   # no pow2 padding
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    res = _forces_all_envs(pool, spec, radius, channels, pair)

    ref = np.asarray(res["brute"]["force"])
    for name in ("uniform", "uniform_resident", "scatter", "hash"):
        np.testing.assert_allclose(np.asarray(res[name]["force"]), ref,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_array_equal(np.asarray(res[name]["force_nnz"]),
                                      np.asarray(res["brute"]["force_nnz"]),
                                      err_msg=name)


def test_hash_bucket_collision_no_double_count():
    """Two stencil cells hashing to one bucket must not double-count it.

    Cells (34,129,23) and (35,128,21) collide into bucket 7476 under the
    3-prime hash with 2^14 buckets, and *both* lie in the stencil of a query
    in cell (34,128,22) — without the cell_keys re-check the neighbor's
    bucket is gathered once per colliding stencil cell, doubling its force
    and force_nnz. Needs grid coords ≥ ~130, which the 33³ parity grids
    never reach.
    """
    dims = (40, 132, 25)
    radius = 4.0
    # q at the center of cell (34,128,22); nbr in cell (34,129,23) within
    # contact distance (diameters 4 → contact at dist < 4)
    pos = np.asarray([[138.0, 514.0, 90.0],
                      [138.5, 516.5, 92.5]], np.float32)
    dia = np.full((2,), 4.0, np.float32)
    pool = agents.make_pool(2, position=jnp.asarray(pos),
                            diameter=jnp.asarray(dia))
    spec = G.GridSpec(dims=dims, max_per_box=4, max_per_run=8, query_chunk=2)
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    hg = G.make_builder(spec, method="hash")(pool, jnp.zeros(3),
                                              jnp.asarray(radius)).grid
    assert int(hg.keys[0]) != int(hg.keys[1])   # distinct buckets for agents

    def hash_phase(q_pos, q_slot, j):
        ids, valid = G.hash_grid_probe(spec, hg, q_pos, j)
        valid &= ids != q_slot[:, None]
        return ids, valid
    all_idx = jnp.arange(2, dtype=jnp.int32)
    res = G.phased_chunk_apply(channels, channels, all_idx, jnp.int32(2),
                               hash_phase, 27, pair, OUT_SPECS,
                               spec.query_chunk)
    ref = G.brute_force_apply(channels, pool.alive, pair, OUT_SPECS)
    np.testing.assert_array_equal(np.asarray(res["force_nnz"]),
                                  np.asarray(ref["force_nnz"]))
    np.testing.assert_allclose(np.asarray(res["force"]),
                               np.asarray(ref["force"]), atol=1e-4)


@pytest.mark.parametrize("dims,domain", [
    ((8, 8, 8), (16.0, 16.0, 16.0)),
    ((20, 8, 4), (40.0, 16.0, 8.0)),          # anisotropic linear-key table
])
def test_pallas_collision_matches_xla_grid(rng, dims, domain):
    """K1 kernel (linear-key column map, interpret mode) vs the XLA grid path."""
    n, c = 260, 384
    box = 2.0
    pos, _ = _cloud(rng, n, (0, 0, 0), domain)
    dia = rng.uniform(0.5, 1.4, (n,)).astype(np.float32)
    P = np.zeros((c, 3), np.float32); P[:n] = pos
    D = np.zeros((c,), np.float32); D[:n] = dia
    alive = np.zeros((c,), bool); alive[:n] = True
    pool = agents.make_pool(c, position=jnp.asarray(pos),
                            diameter=jnp.asarray(dia))
    pool = dataclasses.replace(pool, alive=jnp.asarray(alive))

    f_k1, nnz_k1, ovf = kops.collision_force(
        jnp.asarray(P), jnp.asarray(D), jnp.zeros((c,), jnp.int32),
        jnp.asarray(alive), jnp.asarray(alive), jnp.zeros(3),
        jnp.asarray(box), dims=dims, k_rep=2.0, adhesion=None,
        adhesion_band=0.4)
    assert not bool(ovf)

    spec = G.GridSpec(dims=dims, max_per_box=c, query_chunk=128)
    gs = G.make_builder(spec, method="sorted")(pool, jnp.zeros(3),
                                                jnp.asarray(box)).grid
    channels = {k: v for k, v in pool.channels().items()
                if not k.startswith("extra.")}
    pair = make_force_pair_fn(ForceParams())
    res = G.neighbor_apply(spec, gs, channels,
                           jnp.arange(c, dtype=jnp.int32), pool.n_live,
                           pair, OUT_SPECS)
    np.testing.assert_allclose(np.asarray(f_k1), np.asarray(res["force"]),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(nnz_k1),
                                  np.asarray(res["force_nnz"]))
