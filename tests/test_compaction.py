"""Parallel add/remove (paper §3.2): compaction + birth-commit invariants."""

import dataclasses

import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import agents, compaction


def _pool_with_alive(alive_np):
    c = len(alive_np)
    pool = agents.make_pool(c, position=jnp.arange(3 * c, dtype=jnp.float32
                                                   ).reshape(c, 3))
    return dataclasses.replace(pool, alive=jnp.asarray(alive_np))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=128))
def test_compaction_invariants(alive):
    """Property (paper's ResourceManager invariant): after compaction live agents
    occupy [0, n_live) in stable order and no live agent is lost."""
    alive_np = np.asarray(alive)
    pool = _pool_with_alive(alive_np)
    out = compaction.compact(pool)
    n = int(alive_np.sum())
    assert int(out.n_live) == n
    got_alive = np.asarray(out.alive)
    assert got_alive[:n].all() and not got_alive[n:].any()
    # stable order of survivors
    exp = np.asarray(pool.position)[alive_np]
    np.testing.assert_array_equal(np.asarray(out.position)[:n], exp)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 20), st.integers(0, 20), st.integers(8, 48))
def test_birth_commit(n_live, n_births, cap):
    n_live = min(n_live, cap)
    pool = agents.make_pool(cap, n_live=n_live)
    pool = dataclasses.replace(
        pool, position=pool.position.at[:].set(1.0))
    q = {"position": jnp.full((24, 3), 7.0),
         "diameter": jnp.full((24,), 3.0),
         "agent_type": jnp.full((24,), 5, jnp.int32)}
    valid = jnp.arange(24) < n_births
    out = compaction.commit_births(pool, q, valid, jnp.int32(9))
    expected = min(cap, n_live + n_births)
    assert int(out.n_live) == expected
    ov = int(compaction.birth_overflow(pool, valid))
    assert ov == max(0, n_live + n_births - cap)
    if expected > n_live:
        born = np.asarray(out.position)[n_live:expected]
        np.testing.assert_array_equal(born, np.full((expected - n_live, 3), 7.0))
        assert (np.asarray(out.born_iter)[n_live:expected] == 9).all()
        assert np.asarray(out.moved)[n_live:expected].all()   # newborns wake region


@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=128))
def test_active_index_list(active):
    a = np.asarray(active)
    idx, n = compaction.active_index_list(jnp.asarray(a))
    assert int(n) == a.sum()
    np.testing.assert_array_equal(np.asarray(idx)[:int(n)], np.nonzero(a)[0])
