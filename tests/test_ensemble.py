"""Ensemble engine (DESIGN.md §8): lane-vs-solo bit-exactness is the whole
contract.

A lane is only a valid unit of service if running a simulation inside the
vmapped ensemble is *indistinguishable* from running it solo with the same
seed and params — channels AND rng keys, bit for bit, through admit/retire
churn and shared-rung ladder growth. These tests pin that, plus the params
plumbing the ensemble rides on (per-lane ``ScenarioParams`` must be a no-op
when unused, and must be refused where the compiled program bakes the
constants in).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

import pytest

from repro.core import (EngineConfig, EnsembleCapacityLadder, EnsembleEngine,
                        LadderConfig, ScenarioParams, Simulation)
from repro.core import behaviors as bhv
from repro.core import engine as engine_mod
from repro.core.behaviors import GrowDivide, Infection, RandomWalk

N, CAP = 96, 128


def _cfg(**over):
    base = dict(capacity=CAP, domain_lo=(0.0,) * 3, domain_hi=(48.0,) * 3,
                interaction_radius=3.0, use_forces=False, detect_static=False,
                query_chunk=1024, max_per_box=32)
    base.update(over)
    return EngineConfig(**base)


def _behaviors(param=True):
    beta = (lambda ctx: ctx.params["beta"]) if param else 0.25
    return [RandomWalk(sigma=0.8),
            Infection(radius=3.0, beta=beta, recovery_time=40)]


def _arrays(seed):
    r = np.random.RandomState(seed)
    pos = r.uniform(0, 48, (N, 3)).astype(np.float32)
    at = np.zeros((N,), np.int32)
    at[:8] = bhv.INFECTED
    timer = np.zeros((N,), np.int32)
    timer[:8] = 40
    return pos, np.full((N,), 1.0, np.float32), at, timer


def _stage(engine, seed):
    pos, dia, at, timer = _arrays(seed)
    return engine.stage_lane(pos, dia, at, {"infect_timer": timer},
                             seed=seed)


def _solo_run(seed, beta, steps, param=True):
    """Solo oracle: the raw iteration core with (optional) traced params."""
    cfg, bs = _cfg(), _behaviors(param)
    sim = Simulation(cfg, bs)
    pos, dia, at, timer = _arrays(seed)
    st = sim.init_state(pos, dia, at, {"infect_timer": timer}, seed=seed)
    core = engine_mod.make_iteration_core(cfg, bs)
    step = jax.jit(lambda p, c, r, i, e, pr: core(p, c, r, i, e, pr))
    pool, conc, rng, env = st.pool, st.conc, st.rng, st.env
    params = ScenarioParams.of(beta=beta) if param else None
    it = st.iteration
    for _ in range(steps):
        pool, conc, rng, _, env = step(pool, conc, rng, it, env, params)
        it = it + 1
    return pool, rng


def _channels_equal(a, b, where=""):
    for name, av in a.channels().items():
        bv = b.channels()[name]
        assert np.array_equal(np.asarray(av), np.asarray(bv)), \
            f"{where} channel {name} diverged"


# ---------------------------------------------------------------------------
# lane-vs-solo bit-exactness
# ---------------------------------------------------------------------------

def test_lanes_bit_exact_vs_solo():
    """Every lane of a vmapped ensemble — its own seed, its own beta —
    reproduces the solo run bit for bit, rng keys included."""
    seeds, betas = [3, 7, 11], [0.15, 0.3, 0.45]
    steps = 8
    eng = EnsembleEngine(_cfg(), _behaviors(), n_lanes=3,
                         params_template=ScenarioParams.of(beta=0.0))
    st = eng.init_state()
    for lane, (sd, b) in enumerate(zip(seeds, betas)):
        st = eng.admit(st, lane, _stage(eng, sd), ScenarioParams.of(beta=b))
    for _ in range(steps):
        st = eng.step(st)
    assert np.array_equal(np.asarray(st.iteration), [steps] * 3)
    assert int(st.tick) == steps
    for lane, (sd, b) in enumerate(zip(seeds, betas)):
        spool, srng = _solo_run(sd, b, steps)
        lane_state = eng.read_lane(st, lane)
        _channels_equal(lane_state.pool, spool, f"lane {lane}")
        assert np.array_equal(np.asarray(lane_state.rng), np.asarray(srng)), \
            f"lane {lane} rng diverged"


def test_params_none_matches_static_config():
    """The params plumbing is a bit-exact no-op when unused: a solo run with
    traced beta equals one with the same beta baked into the behavior."""
    p_static, _ = _solo_run(5, 0.25, steps=6, param=False)
    p_traced, _ = _solo_run(5, 0.25, steps=6, param=True)
    _channels_equal(p_static, p_traced, "static-vs-traced")


# ---------------------------------------------------------------------------
# lane masking: retire freezes, stats zero, reuse is independent
# ---------------------------------------------------------------------------

def test_retired_lane_frozen_and_stats_zeroed():
    eng = EnsembleEngine(_cfg(), _behaviors(), n_lanes=2,
                         params_template=ScenarioParams.of(beta=0.0))
    st = eng.init_state()
    for lane, sd in enumerate([3, 7]):
        st = eng.admit(st, lane, _stage(eng, sd),
                       ScenarioParams.of(beta=0.3))
    for _ in range(4):
        st = eng.step(st)
    frozen = eng.read_lane(st, 0)
    st = eng.retire(st, 0)
    for _ in range(5):
        st = eng.step(st)
    after = eng.read_lane(st, 0)
    _channels_equal(after.pool, frozen.pool, "retired lane")
    assert np.array_equal(np.asarray(after.rng), np.asarray(frozen.rng))
    # per-lane iteration advances only while active
    assert np.array_equal(np.asarray(st.iteration), [4, 9])
    # a frozen lane contributes nothing to the stats the ladder watches
    assert int(np.asarray(st.stats["n_live"])[0]) == 0
    assert int(np.asarray(st.stats["n_live"])[1]) > 0


def test_lane_reuse_after_churn_matches_oracle():
    """Retire lane 0 mid-run, admit a NEW simulation into it while lane 1
    keeps going: the reused lane must match a fresh 1-lane run bit for bit
    (the admit overwrote rng/params/state — nothing of the previous
    occupant leaks)."""
    eng = EnsembleEngine(_cfg(), _behaviors(), n_lanes=2,
                         params_template=ScenarioParams.of(beta=0.0))
    st = eng.init_state()
    for lane, sd in enumerate([3, 7]):
        st = eng.admit(st, lane, _stage(eng, sd),
                       ScenarioParams.of(beta=0.3))
    for _ in range(6):
        st = eng.step(st)
    st = eng.retire(st, 0)
    staged = _stage(eng, 11)
    st = eng.admit(st, 0, staged, ScenarioParams.of(beta=0.4))
    for _ in range(7):
        st = eng.step(st)

    solo = EnsembleEngine(_cfg(), _behaviors(), n_lanes=1,
                          params_template=ScenarioParams.of(beta=0.0))
    s1 = solo.admit(solo.init_state(), 0, _stage(solo, 11),
                    ScenarioParams.of(beta=0.4))
    for _ in range(7):
        s1 = solo.step(s1)
    lane0, oracle = eng.read_lane(st, 0), solo.read_lane(s1, 0)
    _channels_equal(lane0.pool, oracle.pool, "reused lane")
    assert np.array_equal(np.asarray(lane0.rng), np.asarray(oracle.rng))
    assert int(np.asarray(st.iteration)[0]) == 7      # reset on admit
    assert int(np.asarray(st.iteration)[1]) == 13


# ---------------------------------------------------------------------------
# shared-rung ensemble ladder
# ---------------------------------------------------------------------------

def test_ensemble_ladder_bit_parity_vs_presized():
    """Two growing lanes under the shared-rung ladder: the rung is sized off
    worst-lane demand, the overflowing tick rewinds, and the result is
    bit-identical to an ensemble pre-sized at the final rung."""
    cfg = _cfg(capacity=64, domain_hi=(96.0,) * 3, interaction_radius=4.0,
               max_per_box=4, query_chunk=256)
    scenario = [GrowDivide(rate=0.8, threshold_diameter=6.0),
                RandomWalk(sigma=0.3)]
    steps = 7

    ladder = EnsembleCapacityLadder(cfg, scenario, n_lanes=2,
                                    ladder=LadderConfig(growth_factor=2.0,
                                                        round_to=32))

    def admit_all(engine, state):
        for lane, sd in enumerate([0, 1]):
            r = np.random.default_rng(sd)
            pos = r.uniform(4, 92, (48, 3)).astype(np.float32)
            ls = engine.stage_lane(pos, np.full(48, 5.2, np.float32),
                                   seed=sd)
            state = engine.admit(state, lane, ls)
        return state

    st = admit_all(ladder.engine, ladder.init_state())
    st = ladder.run(st, steps)
    assert any(r["field"] == "capacity" for r in ladder.rungs), ladder.rungs

    # oracle: ensemble pre-sized at the ladder's final rung
    pre = EnsembleEngine(ladder.config, scenario, n_lanes=2)
    st2 = admit_all(pre, pre.init_state())
    for _ in range(steps):
        st2 = pre.step(st2)

    for lane in range(2):
        a = ladder.engine.read_lane(st, lane)
        b = pre.read_lane(st2, lane)
        la, lb = np.asarray(a.pool.alive), np.asarray(b.pool.alive)
        assert la.sum() == lb.sum() > 48, f"lane {lane}"
        pa = np.asarray(a.pool.position)[la]
        pb = np.asarray(b.pool.position)[lb]
        oa, ob = np.lexsort(pa.T), np.lexsort(pb.T)
        assert np.array_equal(pa[oa], pb[ob]), \
            f"lane {lane} positions diverged from pre-sized oracle"


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_admit_params_must_match_template():
    eng = EnsembleEngine(_cfg(), _behaviors(), n_lanes=1,
                         params_template=ScenarioParams.of(beta=0.0))
    with pytest.raises(ValueError, match="params_template"):
        eng.admit(eng.init_state(), 0, _stage(eng, 0), None)
    eng2 = EnsembleEngine(_cfg(), _behaviors(param=False), n_lanes=1)
    with pytest.raises(ValueError, match="params_template"):
        eng2.admit(eng2.init_state(), 0, _stage(eng2, 0),
                   ScenarioParams.of(beta=0.1))


def test_scenario_force_overrides_refused_under_pallas():
    """The pallas force path bakes force constants into the kernel, so
    traced per-lane force overrides must be refused loudly, not silently
    ignored."""
    cfg = _cfg(use_forces=True, force_impl="pallas")
    core = engine_mod.make_iteration_core(cfg, [])
    sim = Simulation(cfg, [])
    pos, dia, _, _ = _arrays(0)
    st = sim.init_state(pos, dia)
    with pytest.raises(ValueError, match="Pallas"):
        core(st.pool, st.conc, st.rng, st.iteration, st.env,
             ScenarioParams.of(force={"k_rep": 2.0}))
