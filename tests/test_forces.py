"""Collision force (paper §5 / Cortex3D): physics sanity properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import forces as F

P = F.ForceParams()


def _f(p1, d1, p2, d2, adhesion=None, t1=0, t2=0):
    out = F.pair_force(jnp.asarray([p1], jnp.float32), jnp.asarray([d1], jnp.float32),
                       jnp.asarray([t1], jnp.int32),
                       jnp.asarray([[p2]], jnp.float32), jnp.asarray([[d2]], jnp.float32),
                       jnp.asarray([[t2]], jnp.int32),
                       jnp.asarray([[True]]), P, adhesion)
    return np.asarray(out[0, 0])


def test_no_force_out_of_range():
    f = _f([0, 0, 0], 2.0, [5, 0, 0], 2.0)
    np.testing.assert_allclose(f, 0.0)


def test_repulsion_pushes_apart():
    f = _f([0, 0, 0], 2.0, [1.0, 0, 0], 2.0)   # overlap delta = 1
    assert f[0] < 0 and abs(f[1]) < 1e-12 and abs(f[2]) < 1e-12


def test_adhesion_pulls_in_band():
    adh = jnp.asarray([[1.0]])
    # gap 0.2 < adhesion band 0.4 -> net attraction
    f = _f([0, 0, 0], 2.0, [2.2, 0, 0], 2.0, adhesion=adh)
    assert f[0] > 0


@settings(max_examples=50, deadline=None)
@given(st.floats(0.5, 4.0), st.floats(0.5, 4.0),
       st.floats(-3, 3), st.floats(-3, 3), st.floats(-3, 3))
def test_newton_third_law(d1, d2, x, y, z):
    """F_ij == -F_ji (pairwise symmetry of the Cortex3D force)."""
    if abs(x) + abs(y) + abs(z) < 1e-3:
        return
    f12 = _f([0, 0, 0], d1, [x, y, z], d2)
    f21 = _f([x, y, z], d2, [0, 0, 0], d1)
    np.testing.assert_allclose(f12, -f21, rtol=1e-4, atol=1e-5)


def test_displacement_cap():
    f = jnp.asarray([[1e6, 0.0, 0.0]])
    dx = F.displacement(f, P, dt=1.0)
    assert abs(float(jnp.linalg.norm(dx)) - P.max_displacement) < 1e-4


def test_monotone_in_overlap():
    mags = []
    for gap in (1.5, 1.0, 0.5, 0.1):
        f = _f([0, 0, 0], 2.0, [gap, 0, 0], 2.0)
        mags.append(np.linalg.norm(f))
    assert all(b > a for a, b in zip(mags, mags[1:]))
