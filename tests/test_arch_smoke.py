"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated at a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
no NaNs. Full configs are exercised only via the dry-run (ShapeDtypeStruct).
Also: decode-vs-prefill consistency per cache type, and SSD/MoE math oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model, reduced_config

ALL_ARCHS = sorted(ARCHS.keys())


def _batch(rng, r, b=2, s=32):
    batch = {"tokens": jnp.asarray(rng.integers(0, r.vocab_size, (b, s))),
             "labels": jnp.asarray(rng.integers(0, r.vocab_size, (b, s)))}
    if r.frontend != "none":
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, r.frontend_tokens, r.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_forward_smoke(rng, arch):
    r = reduced_config(ARCHS[arch])
    m = build_model(r)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(rng, r)
    loss, metrics = m.train_loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one gradient step must stay finite (a real train step on CPU)
    g = jax.grad(lambda p: m.train_loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_decode_matches_prefill(rng, arch):
    r = reduced_config(ARCHS[arch])
    m = build_model(r)
    params = m.init_params(jax.random.PRNGKey(1))
    b, s = 2, 24
    toks = jnp.asarray(rng.integers(0, r.vocab_size, (b, s)))
    fe = None
    if r.frontend != "none":
        fe = jnp.asarray(rng.standard_normal((b, r.frontend_tokens, r.d_model)),
                         jnp.float32)
    is_encdec = r.encoder_layers > 0
    gt, _ = m.prefill(params, toks, fe) if fe is not None else m.prefill(params, toks)
    assert gt.shape == (b, r.vocab_size)
    t0 = s - 4
    _, caches = (m.prefill(params, toks[:, :t0], fe) if fe is not None
                 else m.prefill(params, toks[:, :t0]))
    off = 0 if (fe is None or is_encdec) else fe.shape[1]
    smax = s + off
    specs = (m.decode_cache_specs(b, smax, fe.shape[1]) if is_encdec
             else m.decode_cache_specs(b, smax))

    def pad_to(spec, val):
        out = jnp.zeros(spec.shape, spec.dtype)
        return out.at[tuple(slice(0, d) for d in val.shape)].set(
            val.astype(spec.dtype))

    caches_p = jax.tree.map(pad_to, specs, caches)
    cur = t0 + off
    lg = None
    for t in range(t0, s):
        lg, caches_p = m.decode_step(params, toks[:, t], caches_p,
                                     jnp.int32(cur))
        cur += 1
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(gt, np.float32), atol=5e-4)


def test_ssd_matches_naive_recurrence(rng):
    """Chunked SSD == step-by-step linear recurrence (mamba2 math oracle)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 48, 3, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, (b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 1.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y, hfin = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    # naive recurrence
    hstate = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a))      # (b,h)
        hstate = hstate * decay[..., None, None] + np.einsum(
            "bh,bhp,bn->bhpn", np.asarray(dt[:, t]), np.asarray(x[:, t]),
            np.asarray(bm[:, t]))
        ys[:, t] = np.einsum("bhpn,bn->bhp", hstate, np.asarray(cm[:, t]))
    np.testing.assert_allclose(np.asarray(y), ys, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hfin), hstate, atol=2e-4)


def test_moe_single_expert_equals_dense(rng):
    """top-1 over 1 expert (no drops) == plain SwiGLU MLP (MoE math oracle)."""
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.models.layers import ParamSet, rms_norm, swiglu
    cfg = dataclasses.replace(
        ARCHS["kimi-k2-1t-a32b"], n_experts=1, top_k=1, n_shared_experts=0,
        moe_d_ff=32, d_model=16, capacity_factor=2.0, router_aux_coef=0.0)
    ps = ParamSet(dtype=jnp.float32)
    moe_mod.register_moe(ps, "moe", cfg, ())
    params = ps.init_params(jax.random.PRNGKey(0))["moe"]
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    out, aux = moe_mod.moe_layer(params, x, cfg)
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    expect = x + swiglu(xn, params["w_gate"][0], params["w_up"][0],
                        params["w_down"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_moe_capacity_drops_bounded(rng):
    """With capacity factor 1.0, each expert processes ≤ capacity tokens and
    dropped tokens fall back to the residual path (finite output)."""
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.models.layers import ParamSet
    cfg = dataclasses.replace(
        ARCHS["kimi-k2-1t-a32b"], n_experts=4, top_k=2, n_shared_experts=0,
        moe_d_ff=32, d_model=16, capacity_factor=1.0)
    ps = ParamSet(dtype=jnp.float32)
    moe_mod.register_moe(ps, "moe", cfg, ())
    params = ps.init_params(jax.random.PRNGKey(0))["moe"]
    x = jnp.asarray(rng.standard_normal((4, 16, 16)), jnp.float32)
    out, aux = moe_mod.moe_layer(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark (no alloc)."""
    expected = {"qwen2-1.5b": (1.2e9, 2.2e9),
                "qwen3-14b": (13e9, 16e9),
                "yi-6b": (5.5e9, 7e9),
                "yi-9b": (8e9, 10e9),
                "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
                "deepseek-v2-lite-16b": (14e9, 18e9),
                "jamba-v0.1-52b": (45e9, 58e9),
                "mamba2-370m": (0.3e9, 0.5e9),
                "phi-3-vision-4.2b": (3.5e9, 4.5e9),
                "seamless-m4t-large-v2": (1.2e9, 2.8e9)}
    for name, (lo, hi) in expected.items():
        m = build_model(ARCHS[name])
        n = m.n_params()
        assert lo <= n <= hi, f"{name}: {n:,} not in [{lo:,.0f}, {hi:,.0f}]"


def test_moe_gather_dispatch_equals_scatter(rng):
    """§Perf optimization safety: gather-based dispatch is bit-identical to
    the scatter baseline (same slot assignment, same drops)."""
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.models.layers import ParamSet
    base = dataclasses.replace(
        ARCHS["kimi-k2-1t-a32b"], n_experts=8, top_k=2, n_shared_experts=1,
        moe_d_ff=32, d_model=16, capacity_factor=1.0)   # cf=1: drops occur
    ps = ParamSet(dtype=jnp.float32)
    moe_mod.register_moe(ps, "moe", base, ())
    params = ps.init_params(jax.random.PRNGKey(0))["moe"]
    x = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    out_s, _ = moe_mod.moe_layer(
        params, x, dataclasses.replace(base, moe_dispatch="scatter"))
    out_g, _ = moe_mod.moe_layer(
        params, x, dataclasses.replace(base, moe_dispatch="gather"))
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_g), atol=1e-6)


def test_bf16_grad_sync_close_to_f32():
    """§Perf: bf16 gradient compression stays numerically close for a step."""
    from repro.data import DataConfig, batch_at
    from repro.train import AdamWConfig, init_state, make_train_step
    cfg = reduced_config(ARCHS["yi-6b"])
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3)
    batch = batch_at(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=4), 0)
    p32, _, _ = jax.jit(make_train_step(m, ocfg))(
        params, init_state(ocfg, params), batch)
    p16, _, _ = jax.jit(make_train_step(m, ocfg, grad_sync_dtype="bfloat16"))(
        params, init_state(ocfg, params), batch)
    rel = max(float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
              for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p16)))
    assert rel < 0.05, rel
