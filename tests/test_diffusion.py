"""Diffusion grid: conservation, stability, gradient correctness."""

import jax.numpy as jnp
import numpy as np

from repro.core import diffusion as D


def test_mass_conservation_neumann():
    spec = D.DiffusionSpec(dims=(12, 12, 12), coefficient=0.2, decay=0.0)
    c = jnp.zeros(spec.dims).at[6, 6, 6].set(100.0)
    m0 = float(c.sum())
    dt = D.stable_dt(spec)
    for _ in range(50):
        c = D.step(spec, c, dt)
    np.testing.assert_allclose(float(c.sum()), m0, rtol=1e-5)
    assert float(c.max()) < 100.0          # it spread
    assert float(c.min()) >= -1e-9         # no negative concentration


def test_decay():
    spec = D.DiffusionSpec(dims=(8, 8, 8), coefficient=0.0, decay=0.1)
    c = jnp.full(spec.dims, 1.0)
    c = D.step(spec, c, 1.0)
    np.testing.assert_allclose(np.asarray(c), 0.9, rtol=1e-6)


def test_sources_and_sample():
    spec = D.DiffusionSpec(dims=(8, 8, 8))
    c = jnp.zeros(spec.dims)
    pos = jnp.asarray([[3.5, 3.5, 3.5], [3.6, 3.4, 3.5]])
    c = D.add_sources(spec, c, pos, jnp.asarray([2.0, 3.0]), jnp.zeros(3))
    assert float(c[3, 3, 3]) == 5.0        # both agents share the voxel
    got = D.sample(spec, c, pos, jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(got), [5.0, 5.0])


def test_gradient_points_uphill():
    spec = D.DiffusionSpec(dims=(16, 8, 8))
    x = jnp.arange(16, dtype=jnp.float32)
    c = jnp.broadcast_to(x[:, None, None], spec.dims)   # increases along +x
    g = D.gradient(spec, c, jnp.asarray([[8.0, 4.0, 4.0]]), jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(g[0]), [1.0, 0.0, 0.0], atol=1e-6)
