"""Import ``given``/``settings``/``st`` from hypothesis, or a tiny fallback.

The CI image installs hypothesis (requirements-dev.txt); minimal containers may
not have it. The fallback keeps the property tests *runnable* as seeded random
sampling: each ``@given`` test runs a fixed number of examples drawn from a
deterministic RNG. It covers only the strategy subset this suite uses
(integers, floats, booleans, tuples, lists, sampled_from) — install real
hypothesis for shrinking and edge-case generation.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _FALLBACK_MAX_EXAMPLES = 8   # keep the no-hypothesis suite fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.example(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _St()

    def given(*strategies):
        def deco(test):
            def wrapper():
                n = min(getattr(wrapper, "_max_examples",
                                _FALLBACK_MAX_EXAMPLES),
                        _FALLBACK_MAX_EXAMPLES)
                for i in range(n):
                    rng = _np.random.default_rng(1000 + i)
                    args = [s.example(rng) for s in strategies]
                    try:
                        test(*args)
                    except Exception as e:  # noqa: BLE001
                        raise AssertionError(
                            f"falsifying example (fallback draw {i}): "
                            f"{args!r}") from e
            wrapper.__name__ = test.__name__
            wrapper.__doc__ = test.__doc__
            return wrapper
        return deco

    def settings(**kwargs):
        def deco(fn):
            if "max_examples" in kwargs:
                fn._max_examples = kwargs["max_examples"]
            return fn
        return deco
