"""Resident grid layout + fused run-streaming force path (DESIGN.md §3.2).

Covers the PR-3 tentpole end to end:
  * run-streaming XLA forces (grid.resident_apply) vs the wide candidate
    matrix path and the O(N²) oracle, to the acceptance tolerance 2e-6;
  * the resident Pallas kernel (interpret mode, no sort/unsort) vs both;
  * block-granular query masking, including a capacity that is not a
    multiple of the chunk (the clamped trailing window);
  * box-granular static flags (conservative neighborhood wake-up);
  * the permutation–compaction composition under deaths and births mid-run;
  * engine-level detect_static on/off equivalence, XLA and Pallas, on a
    quiescent lattice with a churning (birth/death) active corner.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, ForceParams, Simulation, agents
from repro.core import compaction, grid as G, morton, statics
from repro.core.behaviors import GrowDivide, RandomDeath
from repro.core.forces import make_force_pair_fn
from repro.kernels import ops as kops

OUT_SPECS = {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)}


def _resident_setup(rng, n, c, dims, box, chunk):
    pos = rng.uniform(0.3, dims[0] * box - 0.3, (n, 3)).astype(np.float32)
    dia = rng.uniform(0.8, 1.6, (n,)).astype(np.float32)
    pool = agents.make_pool(c, position=jnp.asarray(pos),
                            diameter=jnp.asarray(dia))
    spec = G.GridSpec(dims=dims, max_per_box=c, max_per_run=c,
                      query_chunk=chunk)
    res = G.make_builder(spec, method="resident")(pool, jnp.zeros(3),
                                                   jnp.asarray(box))
    rpool, grid, order = res.pool, res.grid, res.order
    ch = {k: v for k, v in rpool.channels().items()
          if not k.startswith("extra.")}
    return pool, rpool, spec, grid, order, ch


@pytest.mark.parametrize("n,c,chunk", [(300, 384, 128), (333, 420, 128),
                                       (100, 100, 256)])
def test_resident_streaming_matches_oracle(rng, n, c, chunk):
    """Run-streaming forces == wide-matrix path == O(N²) oracle (≤2e-6)."""
    pool, rpool, spec, grid, order, ch = _resident_setup(
        rng, n, c, (8, 8, 8), 2.0, chunk)
    pair = make_force_pair_fn(ForceParams())

    res = G.resident_apply(spec, grid, ch, rpool.alive, pair, OUT_SPECS)
    # wide candidate-matrix path over the same resident pool
    wide = G.neighbor_apply(spec, grid, ch,
                            jnp.arange(c, dtype=jnp.int32), rpool.n_live,
                            pair, OUT_SPECS)
    oracle = G.brute_force_apply(ch, rpool.alive, pair, OUT_SPECS)

    assert float(jnp.max(jnp.abs(res["force"] - oracle["force"]))) <= 2e-6
    np.testing.assert_array_equal(np.asarray(res["force_nnz"]),
                                  np.asarray(oracle["force_nnz"]))
    assert float(jnp.max(jnp.abs(res["force"] - wide["force"]))) <= 2e-6


def test_resident_pallas_matches_streaming(rng):
    """Pallas resident core (no sort/unsort, interpret) vs run-streaming XLA,
    with a static fraction excluded at block granularity in both."""
    n, c = 320, 384
    pool, rpool, spec, grid, order, ch = _resident_setup(
        rng, n, c, (8, 8, 8), 2.5, 128)
    active = rpool.alive & jnp.asarray(rng.random(c) < 0.6)
    pair = make_force_pair_fn(ForceParams())

    f_k1, nnz_k1, ovf = kops.collision_force_resident(
        rpool.position, rpool.diameter, rpool.agent_type, rpool.alive,
        active, grid.starts, grid.counts, jnp.zeros(3), jnp.asarray(2.5),
        dims=spec.dims, k_rep=2.0, adhesion=None, adhesion_band=0.4)
    assert not bool(ovf)

    res = G.resident_apply(spec, grid, ch, active, pair, OUT_SPECS)
    np.testing.assert_allclose(np.asarray(f_k1), np.asarray(res["force"]),
                               atol=2e-6)
    np.testing.assert_array_equal(np.asarray(nnz_k1),
                                  np.asarray(res["force_nnz"]))


def test_resident_query_mask_blocks(rng):
    """Masked resident_apply == full result restricted to the mask — even when
    the mask leaves whole blocks empty and capacity % chunk != 0."""
    n, c, chunk = 333, 333, 128
    pool, rpool, spec, grid, order, ch = _resident_setup(
        rng, n, c, (8, 8, 8), 2.0, chunk)
    pair = make_force_pair_fn(ForceParams())
    full = G.resident_apply(spec, grid, ch, rpool.alive, pair, OUT_SPECS)
    mask = rpool.alive & jnp.asarray(rng.random(c) < 0.3)
    # zero out whole blocks so the dynamic trip count actually shrinks
    mask = mask & (jnp.arange(c) // chunk != 1)
    part = G.resident_apply(spec, grid, ch, mask, pair, OUT_SPECS)
    for name in OUT_SPECS:
        want = jnp.where(mask.reshape((c,) + (1,) * (full[name].ndim - 1)),
                         full[name], 0)
        np.testing.assert_allclose(np.asarray(part[name]), np.asarray(want),
                                   atol=1e-6, err_msg=name)


def test_box_granular_statics_wake(rng):
    """A single disturbed agent wakes exactly its 3×3×3 box neighborhood."""
    # 4³ lattice of agents, one per box center, box size 2
    g = 4
    xs = np.stack(np.meshgrid(*[np.arange(g) * 2.0 + 1.0] * 3,
                              indexing="ij"), -1).reshape(-1, 3)
    n = len(xs)
    pool = agents.make_pool(n, position=jnp.asarray(xs, jnp.float32),
                            diameter=jnp.full((n,), 0.5))
    spec = G.GridSpec(dims=(g, g, g), max_per_box=n)
    res = G.make_builder(spec, method="resident")(pool, jnp.zeros(3),
                                                   jnp.asarray(2.0))
    rpool, grid, order = res.pool, res.grid, res.order
    # quiescent except one agent (in resident order, pick the slot in the
    # box at cell (2,2,2))
    moved = jnp.zeros((n,), bool)
    key_t = morton.linear_encode3(jnp.uint32(2), jnp.uint32(2), jnp.uint32(2),
                                  spec.dims)
    target = int(jnp.argmax(grid.keys == key_t))
    moved = moved.at[target].set(True)
    rpool = dataclasses.replace(rpool, moved=moved,
                                grew=jnp.zeros((n,), bool),
                                force_nnz=jnp.zeros((n,), jnp.int32))
    static = statics.update_static_flags(rpool, spec, grid, jnp.int32(5))
    cells = morton.cell_of(rpool.position, jnp.zeros(3), jnp.asarray(2.0),
                           spec.dims)
    dist = np.abs(np.asarray(cells) - np.asarray([2, 2, 2])).max(axis=1)
    awake = ~np.asarray(static)
    # inside the 3×3×3 neighborhood: awake; outside: static
    np.testing.assert_array_equal(awake, dist <= 1)


def test_permutation_composes_with_death_compaction(rng):
    """Deaths mid-run: one step later the live prefix is still in key order
    (the resident permutation subsumes compaction, stably)."""
    n = 400
    cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0),
                       domain_hi=(30, 30, 30), interaction_radius=3.0,
                       use_forces=False)
    sim = Simulation(cfg, [RandomDeath(rate=0.15)])
    pos = rng.uniform(0, 30, (n, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(n, 1.0, np.float32))
    st = sim.run(st, 6)
    nl = int(st.stats["n_live"])
    alive = np.asarray(st.pool.alive)
    assert 0 < nl < n
    assert alive[:nl].all() and not alive[nl:].any()
    keys = np.asarray(morton.linear_keys(
        st.pool.position, jnp.zeros(3),
        jnp.asarray(cfg.interaction_radius), sim.spec.dims))
    assert (np.diff(keys[:nl].astype(np.int64)) >= 0).all(), \
        "live prefix must stay grid-key sorted"


def test_permutation_composes_with_births(rng):
    """Births land at the tail; the live prefix before them stays key-sorted
    (positions are static in this config, so survivor keys are unchanged)."""
    cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0),
                       domain_hi=(60, 60, 60), interaction_radius=6.0,
                       use_forces=False, dt=0.5)
    sim = Simulation(cfg, [GrowDivide(rate=1.0, threshold_diameter=10.0)])
    pos = rng.uniform(5, 55, (200, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(200, 8.0, np.float32))
    st = sim.run(st, 4)
    nl = int(st.stats["n_live"])
    births_last = int(st.stats["births"])
    assert nl > 200 and births_last > 0
    alive = np.asarray(st.pool.alive)
    assert alive[:nl].all() and not alive[nl:].any()
    keys = np.asarray(morton.linear_keys(
        st.pool.position, jnp.zeros(3),
        jnp.asarray(cfg.interaction_radius), sim.spec.dims))
    sorted_upto = nl - births_last
    assert (np.diff(keys[:sorted_upto].astype(np.int64)) >= 0).all()


def _churn_sim(detect_static, force_impl):
    cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0),
                       domain_hi=(48, 48, 48), interaction_radius=6.0,
                       dt=0.1, detect_static=detect_static,
                       force_impl=force_impl, max_per_box=64,
                       query_chunk=128,
                       force=ForceParams(max_displacement=0.5))
    # quiescent lattice (spacing 6 > max interaction distance 2.4): zero
    # force either way, so skipping it is exact
    xs = np.stack(np.meshgrid(*[np.arange(6) * 6.0 + 6.0] * 3,
                              indexing="ij"), -1).reshape(-1, 3)
    types = np.zeros(len(xs), np.int32)
    # churning corner: tight cluster that divides and dies
    m = 24
    rng = np.random.default_rng(11)
    corner = rng.uniform(2.0, 8.0, (m, 3))
    pos = np.concatenate([xs, corner]).astype(np.float32)
    types = np.concatenate([types, np.ones(m, np.int32)])
    dia = np.concatenate([np.full(len(xs), 2.0), np.full(m, 4.8)]
                         ).astype(np.float32)
    sim = Simulation(cfg, [GrowDivide(rate=2.0, threshold_diameter=5.0,
                                      applies_to=1),
                           RandomDeath(rate=0.05, applies_to=1)])
    st = sim.init_state(pos, diameter=dia, agent_type=types)
    return sim, st


@pytest.mark.parametrize("force_impl", ["xla", "pallas"])
def test_detect_static_equivalent_under_churn(force_impl):
    """detect_static on/off must not change the dynamics — including through
    births and deaths that exercise the permutation–compaction composition —
    while actually skipping work (n_active < n_live)."""
    finals = {}
    for ds in (False, True):
        sim, st = _churn_sim(ds, force_impl)
        saw_birth = saw_death = False
        for _ in range(8):
            st = sim.step(st)
            saw_birth |= int(st.stats["births"]) > 0
            saw_death |= int(st.stats["deaths"]) > 0
        finals[ds] = st
        assert saw_birth and saw_death, "churn must actually churn"
    n_live = int(finals[True].stats["n_live"])
    assert n_live == int(finals[False].stats["n_live"])
    # identical dynamics → identical resident layouts → per-slot comparable
    np.testing.assert_allclose(
        np.asarray(finals[True].pool.position[:n_live]),
        np.asarray(finals[False].pool.position[:n_live]), atol=1e-5)
    # and the static machinery did skip something: lattice ≫ corner
    assert int(finals[True].stats["n_active"]) < n_live
