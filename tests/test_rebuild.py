"""Counting-sort build + RebuildPolicy(every_k): the unified builder surface.

Three contracts from DESIGN.md §2/§4:

  * the O(N) counting-sort permutation (host callback and in-graph radix) is
    **bit-exact** with the stable ``jnp.argsort`` it replaces — the stable
    (key, slot) order is unique, so every impl must produce the same int32
    permutation on every key distribution, including all-dead and
    single-box degenerate ones;
  * ``make_builder`` is the one grid-build entry point: every method shares
    the BuildResult overflow/demand surface (§4.2 never-silent), and the
    legacy ``build_*`` zoo warns ``GridBuilderDeprecationWarning`` for one
    release;
  * ``RebuildPolicy(mode='every_k')`` may *skip* builds only when the skip
    is invisible: forces-only runs must match the every-step schedule to
    float tolerance while actually skipping, structural churn (births)
    must force a rebuild on the next step, the capacity ladder's rewind
    must stay bit-exact while a cached build is live, and the 4-shard
    distributed engine must skip (ghost/migration-clean slabs only) with
    exact parity.

Parity runs use forces-only dynamics with identities stored in
``agent_type``: behaviors draw per-slot randomness, so any schedule that
changes the resident permutation re-deals their noise — only deterministic,
slot-independent dynamics isolate the rebuild schedule under test. Configs
keep ``interaction_radius ≥ max diameter + adhesion_band`` so the grid
stencil covers every interacting pair (the §3.1 exactness contract).
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agents, engine, grid as G
from repro.core.behaviors import GrowDivide


# ---------------------------------------------------------------------------
# counting sort: every impl bit-exact with the stable-argsort oracle
# ---------------------------------------------------------------------------

TABLE = 9 * 9 * 9               # non-power-of-two linear key domain
_DEAD = np.uint32(0xFFFFFFFF)   # morton.DEAD_KEY


def _oracle(keys):
    return np.argsort(keys, kind="stable").astype(np.int32)


def _key_cases(rng, c):
    uniform = rng.integers(0, TABLE, c).astype(np.uint32)
    mixed = uniform.copy()
    mixed[rng.random(c) < 0.3] = _DEAD
    clustered = rng.choice(
        np.asarray([0, 5, TABLE - 1], np.uint32), c).astype(np.uint32)
    return {"uniform": uniform,
            "uniform_with_dead": mixed,
            "clustered": clustered,
            "all_dead": np.full(c, _DEAD, np.uint32),
            "single_box": np.zeros(c, np.uint32)}


@pytest.mark.parametrize("impl", ["host", "xla", "auto", "argsort"])
def test_counting_sort_bit_exact(rng, impl):
    # sizes below / far below / at / just past the radix block (1024)
    for c in (1, 7, 1024, 1359):
        for name, keys in _key_cases(rng, c).items():
            order = np.asarray(G.counting_sort_order(
                jnp.asarray(keys), TABLE, impl=impl))
            assert order.dtype == np.int32, (impl, name, c)
            assert np.array_equal(order, _oracle(keys)), (impl, name, c)


def test_counting_sort_rejects_unknown_impl():
    with pytest.raises(ValueError, match="sort_impl"):
        G.counting_sort_order(jnp.zeros(4, jnp.uint32), TABLE, impl="quick")


# ---------------------------------------------------------------------------
# make_builder: one entry point, common overflow surface, deprecation shims
# ---------------------------------------------------------------------------

def _one_box_pool(rng, n=100, c=128):
    # every agent in grid box (0,0,0) → demand == n for every structure
    pos = rng.uniform(0.0, 0.9, (n, 3)).astype(np.float32)
    return agents.make_pool(c, position=jnp.asarray(pos))


@pytest.mark.parametrize("method", sorted(G.BUILD_METHODS))
def test_make_builder_common_overflow_surface(rng, method):
    pool = _one_box_pool(rng)
    spec = G.GridSpec(dims=(8, 8, 8), max_per_box=8)
    res = G.make_builder(spec, method=method)(pool, jnp.zeros(3),
                                              jnp.asarray(2.0))
    assert isinstance(res, G.BuildResult)
    assert int(res.demand) == 100
    cap = {"resident": spec.run_capacity, "sorted": spec.run_capacity,
           "scatter": spec.max_per_box,
           "hash": G.HASH_K_MULT * spec.max_per_box}[method]
    assert int(res.overflow) == max(100 - cap, 0), method
    order = np.asarray(res.order)
    assert np.array_equal(np.sort(order), np.arange(pool.capacity)), method
    if method != "resident":
        # only the resident build permutes the pool itself
        assert np.array_equal(order, np.arange(pool.capacity))
        assert res.pool is pool


def test_make_builder_rejects_unknown_knobs():
    spec = G.GridSpec(dims=(4, 4, 4))
    with pytest.raises(ValueError, match="method"):
        G.make_builder(spec, method="voxel")
    with pytest.raises(ValueError, match="sort_impl"):
        G.make_builder(spec, sort_impl="quick")


def test_deprecated_builders_warn_and_match(rng):
    pos = rng.uniform(0.0, 15.9, (60, 3)).astype(np.float32)
    pool = agents.make_pool(64, position=jnp.asarray(pos))
    spec = G.GridSpec(dims=(8, 8, 8), max_per_box=64)
    origin, bs = jnp.zeros(3), jnp.asarray(2.0)

    def same(a, b):
        fa, fb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
        assert len(fa) == len(fb)
        for x, y in zip(fa, fb):
            assert np.array_equal(np.asarray(x), np.asarray(y))

    with pytest.warns(G.GridBuilderDeprecationWarning, match="make_builder"):
        legacy = G.build(spec, pool, origin, bs)
    same(legacy, G.make_builder(spec, method="sorted")(pool, origin, bs).grid)

    with pytest.warns(G.GridBuilderDeprecationWarning, match="make_builder"):
        rpool, rgrid, rorder = G.build_resident(spec, pool, origin, bs)
    res = G.make_builder(spec, method="resident")(pool, origin, bs)
    same((rpool.channels(), rgrid, rorder),
         (res.pool.channels(), res.grid, res.order))

    with pytest.warns(G.GridBuilderDeprecationWarning, match="make_builder"):
        sg = G.build_scatter_grid(spec, pool, origin, bs)
    same(sg, G.make_builder(spec, method="scatter")(pool, origin, bs).grid)

    with pytest.warns(G.GridBuilderDeprecationWarning, match="make_builder"):
        hg = G.build_hash_grid(spec, pool, origin, bs)
    same(hg, G.make_builder(spec, method="hash")(pool, origin, bs).grid)


# ---------------------------------------------------------------------------
# RebuildPolicy / EngineConfig validation: knob-named errors
# ---------------------------------------------------------------------------

def test_rebuild_policy_validation():
    with pytest.raises(ValueError, match="rebuild.mode"):
        G.RebuildPolicy(mode="sometimes")
    with pytest.raises(ValueError, match="rebuild.k"):
        G.RebuildPolicy(mode="every_k", k=0, displacement_bound=1.0)
    with pytest.raises(ValueError, match="rebuild.displacement_bound"):
        G.RebuildPolicy(mode="every_k", k=2, displacement_bound=-1.0)
    with pytest.raises(ValueError, match="every_step"):
        G.RebuildPolicy(k=3)                 # knobs without opting in
    assert G.RebuildPolicy().cell_slack == 0.0
    pol = G.RebuildPolicy(mode="every_k", k=4, displacement_bound=1.5)
    assert pol.cell_slack == 1.5


_BASE = dict(capacity=64, domain_lo=(0., 0., 0.), domain_hi=(16.,) * 3,
             interaction_radius=2.0)
_POL = G.RebuildPolicy(mode="every_k", k=4, displacement_bound=1.0)


def test_engine_config_rebuild_validation():
    with pytest.raises(ValueError, match="uniform_grid"):
        engine.EngineConfig(**_BASE, environment="hash_grid", rebuild=_POL)
    with pytest.raises(ValueError, match="detect_static"):
        engine.EngineConfig(**_BASE, detect_static=True, rebuild=_POL)
    with pytest.raises(ValueError, match="sort_impl"):
        engine.EngineConfig(**_BASE, sort_impl="quick")
    # the displacement bound widens the grid cells (coverage argument)
    cfg = engine.EngineConfig(**_BASE, rebuild=_POL)
    assert cfg.cell_size == 3.0
    assert engine.EngineConfig(**_BASE).cell_size == 2.0


def test_dist_config_surfaces_rebuild_identically():
    from repro.core import distributed
    # same knob-named error through the DistConfig path ...
    with pytest.raises(ValueError, match="detect_static"):
        distributed.DistConfig(
            engine=engine.EngineConfig(**_BASE, detect_static=True,
                                       rebuild=_POL),
            n_shards=2, local_capacity=64, halo_capacity=16,
            migrate_capacity=16)
    # ... and the halo widens by the same cell slack the grid uses
    mk = lambda cfg: distributed.DistConfig(
        engine=cfg, n_shards=2, local_capacity=64, halo_capacity=16,
        migrate_capacity=16)
    plain = mk(engine.EngineConfig(**_BASE))
    cached = mk(engine.EngineConfig(**_BASE, rebuild=_POL))
    assert cached.halo_width == plain.halo_width + _POL.displacement_bound


# ---------------------------------------------------------------------------
# every_k skip parity: single device
# ---------------------------------------------------------------------------

def _forces_cfg(side, rebuild=None, capacity=512):
    kw = dict(capacity=capacity, domain_lo=(0., 0., 0.),
              domain_hi=(side,) * 3, interaction_radius=3.0,
              use_forces=True, max_per_box=32)
    if rebuild is not None:
        kw["rebuild"] = rebuild
    return engine.EngineConfig(**kw)


def _live_by_id(st):
    a = np.asarray(st.pool.alive)
    p = np.asarray(st.pool.position)[a]
    return p[np.argsort(np.asarray(st.pool.agent_type)[a])]


def test_every_k_skips_and_matches_every_step(rng):
    SIDE, N = 24.0, 400
    pos = rng.uniform(1.0, SIDE - 1.0, (N, 3)).astype(np.float32)
    dia = np.full((N,), 2.2, np.float32)
    ids = np.arange(N, dtype=np.int32)          # persistent identity

    pol = G.RebuildPolicy(mode="every_k", k=4, displacement_bound=1.0)
    sim_a = engine.Simulation(_forces_cfg(SIDE), behaviors=[])
    sim_b = engine.Simulation(_forces_cfg(SIDE, pol), behaviors=[])
    sa = sim_a.init_state(jnp.asarray(pos), jnp.asarray(dia), jnp.asarray(ids))
    sb = sim_b.init_state(jnp.asarray(pos), jnp.asarray(dia), jnp.asarray(ids))

    steps, rebuilds, skips = 20, 0, 0
    for _ in range(steps):
        sa, sb = sim_a.step(sa), sim_b.step(sb)
        assert int(sa.stats["rebuilds"]) == 1    # every_step never skips
        rebuilds += int(sb.stats["rebuilds"])
        skips += int(sb.stats["rebuild_skips"])
    assert rebuilds + skips == steps
    assert skips > 0, "quiescent forces-only run produced zero skips"
    assert int(sa.stats["n_live"]) == int(sb.stats["n_live"]) == N
    d = float(np.abs(_live_by_id(sa) - _live_by_id(sb)).max())
    # stale-superset candidates contribute exactly zero force; the residue
    # is float summation-order noise only
    assert d < 1e-3, d


def test_births_force_rebuild_next_step(rng):
    SIDE, N = 24.0, 64
    pos = rng.uniform(2.0, SIDE - 2.0, (N, 3)).astype(np.float32)
    dia = np.full((N,), 2.8, np.float32)         # near division threshold
    # generous budget: only structural dirt may force a rebuild
    pol = G.RebuildPolicy(mode="every_k", k=64, displacement_bound=100.0)
    sim = engine.Simulation(
        _forces_cfg(SIDE, pol, capacity=1024),
        behaviors=[GrowDivide(rate=0.5, threshold_diameter=3.0)])
    st = sim.init_state(jnp.asarray(pos), jnp.asarray(dia))
    births, rebuilds = [], []
    for _ in range(8):
        st = sim.step(st)
        births.append(int(st.stats["births"]))
        rebuilds.append(int(st.stats["rebuilds"]))
    assert rebuilds[0] == 1                      # fresh state builds
    for t in range(len(births) - 1):
        if births[t] > 0:
            assert rebuilds[t + 1] == 1, (t, births, rebuilds)
    assert sum(births) > 0, "scenario produced no births"


# ---------------------------------------------------------------------------
# capacity ladder under every_k: rewind stays bit-exact with a live cache
# ---------------------------------------------------------------------------

def test_ladder_every_k_bit_exact(rng):
    SIDE, N = 24.0, 48
    pos = rng.uniform(2.0, SIDE - 2.0, (N, 3)).astype(np.float32)
    dia = np.full((N,), 2.6, np.float32)
    beh = lambda: [GrowDivide(rate=0.35, threshold_diameter=3.2)]
    pol = G.RebuildPolicy(mode="every_k", k=4, displacement_bound=1.0)
    small = _forces_cfg(SIDE, pol, capacity=N)

    ladder = engine.CapacityLadder(small, beh())
    st = ladder.run(ladder.init_state(jnp.asarray(pos), diameter=dia), 10)
    assert ladder.config.capacity > N, "population never outgrew the seed"

    big = dataclasses.replace(small, capacity=ladder.config.capacity)
    sim = engine.Simulation(big, beh())
    st2 = sim.run(sim.init_state(jnp.asarray(pos), diameter=dia), 10)

    a1, a2 = np.asarray(st.pool.alive), np.asarray(st2.pool.alive)
    assert int(a1.sum()) == int(a2.sum())
    p1 = np.asarray(st.pool.position)[a1]
    p2 = np.asarray(st2.pool.position)[a2]
    o1, o2 = np.lexsort(p1.T), np.lexsort(p2.T)
    assert np.array_equal(p1[o1], p2[o2]), "ladder rewind broke bit-exactness"


# ---------------------------------------------------------------------------
# distributed every_k: ghost/migration-clean slabs skip with exact parity
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import json
    import numpy as np
    import jax.numpy as jnp
    from repro.core import distributed, engine, grid

    SIDE, R = 64.0, 3.0
    # per slab: an inert 3x3x3 lattice (spacing 2.4 > dia + band) plus one
    # overlapping agent -> local relaxation, no cross-slab traffic
    lat = np.stack(np.meshgrid(*[np.arange(3) * 2.4 - 2.4] * 3),
                   -1).reshape(-1, 3)
    pos = []
    for cx in (8.0, 24.0, 40.0, 56.0):
        c = np.array([cx, SIDE / 2, SIDE / 2])
        pos.append(c + lat)
        pos.append((c + np.array([1.0, 0.55, 0.3]))[None])
    pos = np.concatenate(pos).astype(np.float32)
    n = pos.shape[0]
    dia = np.full((n,), 2.2, np.float32)
    ids = np.arange(n, dtype=np.int32)
    # fixed mid-gap boundaries: halo bands stay empty -> skips must occur
    # (the quantile boundaries would glue to each cluster's edge instead)
    fixed_b = jnp.asarray([0.0, 16.0, 32.0, 48.0, 64.0], jnp.float32)

    base = dict(capacity=512, domain_lo=(0., 0., 0.),
                domain_hi=(SIDE, SIDE, SIDE), interaction_radius=R,
                use_forces=True, max_per_box=32)
    mk = lambda cfg: distributed.DistConfig(
        engine=cfg, n_shards=4, local_capacity=128, halo_capacity=32,
        migrate_capacity=32)
    cfg_a = engine.EngineConfig(**base)
    cfg_b = engine.EngineConfig(**base, rebuild=grid.RebuildPolicy(
        mode="every_k", k=4, displacement_bound=1.0))

    out, counts = {}, {}
    for name, cfg in (("every_step", cfg_a), ("every_k", cfg_b)):
        sim = distributed.DistributedSimulation(mk(cfg))
        st = sim.init_state(jnp.asarray(pos), jnp.asarray(dia),
                            jnp.asarray(ids))
        st = dataclasses.replace(st, boundaries=fixed_b)
        rebuilds = skips = 0
        for _ in range(24):
            st = sim.step(st)
            rebuilds += int(np.sum(np.asarray(st.stats["rebuilds"])))
            skips += int(np.sum(np.asarray(st.stats["rebuild_skips"])))
        ch = sim.gather_channels(st)
        a = ch["alive"]
        out[name] = ch["position"][a][np.argsort(ch["agent_type"][a])]
        counts[name] = {"n": int(a.sum()), "rebuilds": rebuilds,
                        "skips": skips}

    d = float(np.abs(out["every_step"] - out["every_k"]).max())
    print("RESULT " + json.dumps({"max_d": d, **{
        f"{k}_{f}": v[f] for k, v in counts.items()
        for f in ("n", "rebuilds", "skips")}}))
""")


def test_distributed_every_k_skip_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["every_step_n"] == res["every_k_n"]
    assert res["every_step_skips"] == 0
    assert res["every_step_rebuilds"] == 4 * 24
    assert res["every_k_skips"] > 0, res
    assert res["every_k_rebuilds"] + res["every_k_skips"] == 4 * 24, res
    # isolated slabs, deterministic dynamics: parity is exact
    assert res["max_d"] < 1e-5, res
