"""SimService (DESIGN.md §8): continuous batching over ensemble lanes.

The serving corner cases the batching loop must get right, mirroring the
token-serving batcher's contract (serve/batching.py): admission into a full
pool queues (never drops), retirement frees the lane at iteration
granularity and the next request reuses it with a fresh RNG stream, an
all-idle service never launches the jitted step, and a checkpoint taken
mid-churn resumes bit-exact.
"""

import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import EngineConfig, ScenarioParams
from repro.core import behaviors as bhv
from repro.serve import SimRequest, SimService

N = 96


def _cfg():
    return EngineConfig(
        capacity=128, domain_lo=(0.0,) * 3, domain_hi=(48.0,) * 3,
        interaction_radius=3.0, use_forces=False, detect_static=False,
        query_chunk=1024, max_per_box=32)


def _behaviors():
    return [bhv.RandomWalk(sigma=0.8),
            bhv.Infection(radius=3.0, beta=lambda ctx: ctx.params["beta"],
                          recovery_time=30)]


def _req(uid, seed, beta, max_steps=40):
    r = np.random.RandomState(seed)
    pos = r.uniform(0, 48, (N, 3)).astype(np.float32)
    at = np.zeros((N,), np.int32)
    at[:8] = bhv.INFECTED
    timer = np.zeros((N,), np.int32)
    timer[:8] = 30
    return SimRequest(uid=uid, position=pos,
                      diameter=np.full((N,), 1.0, np.float32), agent_type=at,
                      extra_init={"infect_timer": timer}, seed=seed,
                      params=ScenarioParams.of(beta=beta),
                      max_steps=max_steps)


def _metrics(pool, params):
    return jnp.sum((pool.agent_type == bhv.INFECTED) & pool.alive)


def _service(n_lanes=3):
    return SimService(_cfg(), _behaviors(), n_lanes=n_lanes,
                      params_template=ScenarioParams.of(beta=0.0),
                      metrics_fn=_metrics,
                      converged_fn=lambda m: int(m) == 0)


def test_full_pool_queues_never_drops():
    svc = _service(n_lanes=3)
    for u in range(6):
        svc.submit(_req(u, seed=100 + u, beta=0.2, max_steps=12))
    assert len(svc.queue) == 6
    # first tick admits exactly n_lanes; the overflow stays queued
    assert svc.step() == 3
    assert len(svc.queue) == 3
    assert svc.occupancy() == 1.0
    ticks = svc.run_until_drained()
    # every request ran to completion — none dropped, all retired
    assert len(svc.finished) == 6
    assert sorted(f.uid for f in svc.finished) == list(range(6))
    assert all(f.reason in ("converged", "max_steps") for f in svc.finished)
    assert all(len(f.trajectory) == f.steps for f in svc.finished)
    # 6 budget-12 sims over 3 lanes cannot drain faster than two waves
    # (ticks counts from after the one manual step above)
    assert 1 + ticks >= 24


def test_all_idle_early_exit_skips_jit():
    svc = _service(n_lanes=2)
    assert svc.step() == 0                       # nothing queued, all idle
    assert int(svc.state.tick) == 0              # jitted step never launched
    svc.submit(_req(0, seed=5, beta=0.2, max_steps=3))
    svc.run_until_drained()
    tick_after = int(svc.state.tick)
    assert svc.step() == 0                       # drained → idle again
    assert int(svc.state.tick) == tick_after


def test_lane_reuse_has_independent_rng_stream():
    """A request admitted into a recycled lane must produce exactly what it
    would have produced in a fresh service — the previous occupant's rng
    stream, params, and state leave nothing behind."""
    churned = _service(n_lanes=1)
    churned.submit(_req(0, seed=7, beta=0.3, max_steps=9))    # occupant 1
    churned.submit(_req(1, seed=21, beta=0.45, max_steps=11))  # reuses lane 0
    churned.run_until_drained()
    assert [f.uid for f in churned.finished] == [0, 1]
    reused = next(f for f in churned.finished if f.uid == 1)

    fresh = _service(n_lanes=1)
    fresh.submit(_req(1, seed=21, beta=0.45, max_steps=11))
    fresh.run_until_drained()
    alone = fresh.finished[0]

    assert reused.steps == alone.steps and reused.reason == alone.reason
    for name, av in reused.final.pool.channels().items():
        assert np.array_equal(np.asarray(av),
                              np.asarray(alone.final.pool.channels()[name])), \
            f"reused-lane channel {name} diverged from fresh-service run"
    assert np.array_equal(np.asarray(reused.final.rng),
                          np.asarray(alone.final.rng))
    assert [int(np.asarray(m)) for m in reused.trajectory] == \
           [int(np.asarray(m)) for m in alone.trajectory]


def test_checkpoint_resume_bit_exact_mid_churn():
    svc = _service(n_lanes=3)
    for u in range(5):
        svc.submit(_req(10 + u, seed=200 + u, beta=0.2 + 0.05 * u,
                        max_steps=8))
    for _ in range(10):
        svc.step()          # mid-churn: some retired, lanes reused
    assert svc.finished and any(i is not None for i in svc.lanes)

    with tempfile.TemporaryDirectory() as d:
        finished_at_ckpt = sorted(f.uid for f in svc.finished)
        svc.checkpoint(d, extras={"finished_uids": finished_at_ckpt})
        table_at_ckpt = [None if i is None else i["req"].uid
                         for i in svc.lanes]
        for _ in range(6):
            svc.step()      # original continues

        svc2 = _service(n_lanes=3)
        tick = svc2.restore(d)
        assert tick == int(svc2.state.tick)
        assert svc2.restored_meta["finished_uids"] == finished_at_ckpt
        # lane table restored: same uids busy as at checkpoint time
        busy = [None if i is None else i["req"].uid for i in svc2.lanes]
        assert busy == table_at_ckpt
        for _ in range(6):
            svc2.step()     # replay the same 6 ticks

        eq = jax.tree_util.tree_map(
            lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
            svc.state.pool.channels(), svc2.state.pool.channels())
        assert all(eq.values()), \
            [k for k, v in eq.items() if not v]
        assert np.array_equal(np.asarray(svc.state.rng),
                              np.asarray(svc2.state.rng))
        assert np.array_equal(np.asarray(svc.state.active),
                              np.asarray(svc2.state.active))
        assert np.array_equal(np.asarray(svc.state.iteration),
                              np.asarray(svc2.state.iteration))
