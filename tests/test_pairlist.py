"""Verlet pair-list cache (grid.PairList, DESIGN.md §3.4) — coverage + parity.

Contracts tested:

  * the skin-coverage property: no pair within ``r`` at *current* positions
    is ever absent from a list built at radius ``r + skin`` while per-agent
    euclidean displacement stays ≤ ``skin/2`` (uniform, clustered and
    anisotropic populations — hypothesis property test);
  * the build itself is exact: with generous capacities, each row's listed
    set equals the brute-force in-range(+skin) neighbor set;
  * per-kernel outputs are BIT-EXACT vs the fused streamed sweep when
    ``skin=0`` + every-step rebuilds (XLA and Pallas force paths);
  * under ``every_k`` skin reuse, a reused list serves a step identically
    to a fresh streamed build from the same pool state (the extra stale
    candidates contribute exact zeros);
  * ``max_pairs`` rung overflow → ladder rewind is bit-identical to a
    pre-sized run, with ``pair_overflow``/``pair_demand`` provenance in
    ``StepStats.flags()`` (single-device here; 4-shard in the subprocess
    test alongside streamed-vs-pairlist distributed parity).
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from hypothesis_compat import given, settings, st

from repro.core import EngineConfig, Simulation, engine, grid
from repro.core.behaviors import Infection, INFECTED

SIDE = 48.0


def _cfg(n, **kw):
    base = dict(capacity=n, domain_lo=(0, 0, 0), domain_hi=(SIDE,) * 3,
                interaction_radius=3.0, max_per_box=32, query_chunk=256)
    base.update(kw)
    return EngineConfig(**base)


def _sir_state(sim, n, pos):
    types = np.zeros(n, np.int32)
    types[: n // 20] = INFECTED
    return sim.init_state(pos, diameter=np.full(n, 2.5, np.float32),
                          agent_type=types,
                          extra_init={"infect_timer":
                                      np.full(n, 8, np.int32)})


def _uniform(n, rng):
    return rng.uniform(2, SIDE - 2, (n, 3)).astype(np.float32)


def _clustered(n, rng):
    centers = rng.uniform(8, SIDE - 8, (4, 3))
    which = rng.integers(0, 4, n)
    p = centers[which] + rng.normal(0, 2.0, (n, 3))
    return np.clip(p, 1.0, SIDE - 1.0).astype(np.float32)


def _anisotropic(n, rng):
    p = rng.uniform(2, SIDE - 2, (n, 3))
    p[:, 2] = rng.uniform(20, 28, n)            # thin slab in z
    return p.astype(np.float32)


_DOMAINS = {"uniform": _uniform, "clustered": _clustered,
            "anisotropic": _anisotropic}


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_pairlist_config_validation():
    with pytest.raises(ValueError):
        grid.PairListConfig(skin=-0.1)
    with pytest.raises(ValueError):
        grid.PairListConfig(max_pairs=0)
    # skin > 0 without every_k reuse is pointless and rejected loudly
    with pytest.raises(ValueError):
        _cfg(64, pairlist=grid.PairListConfig(skin=0.5, max_pairs=8))
    # the pair list serves the fused sweep only
    with pytest.raises(ValueError):
        _cfg(64, fused_sweep=False,
             pairlist=grid.PairListConfig(skin=0.0, max_pairs=8))
    with pytest.raises(ValueError):
        _cfg(64, detect_static=True,
             pairlist=grid.PairListConfig(skin=0.0, max_pairs=8))
    # cell width covers the pair-list filter radius
    cfg = _cfg(64, rebuild=grid.RebuildPolicy(mode="every_k", k=4,
                                              displacement_bound=0.2),
               pairlist=grid.PairListConfig(skin=0.9, max_pairs=8))
    assert cfg.cell_size == pytest.approx(3.0 + 0.9)


def test_grow_pairlist_padding():
    p = grid.initial_pairlist(4, 3)
    p = dataclasses.replace(
        p, idx=jnp.arange(12, dtype=jnp.int32).reshape(4, 3),
        count=jnp.array([3, 1, 0, 2], jnp.int32))
    g = grid.grow_pairlist(p, 6, 5)
    assert g.idx.shape == (6, 5) and g.run_off.shape == (6, 10)
    assert np.array_equal(np.asarray(g.idx[:4, :3]),
                          np.arange(12).reshape(4, 3))
    assert np.asarray(g.idx)[:, 3:].max() == 0 and np.asarray(g.idx)[4:].max() == 0
    assert np.array_equal(np.asarray(g.count), [3, 1, 0, 2, 0, 0])
    with pytest.raises(ValueError):
        grid.grow_pairlist(p, 2, 5)


# ---------------------------------------------------------------------------
# build exactness + the skin-coverage property
# ---------------------------------------------------------------------------

def _build_list(pos, r, skin, max_pairs=192):
    """Resident build + pair list at radius r+skin; returns (sorted positions,
    alive mask, PairList)."""
    n = pos.shape[0]
    cfg = _cfg(n, interaction_radius=r,
               rebuild=grid.RebuildPolicy(mode="every_k", k=8,
                                          displacement_bound=0.25),
               pairlist=grid.PairListConfig(skin=skin, max_pairs=max_pairs))
    spec = cfg.grid_spec
    pool = engine.stage_pool(n, [], pos)
    res = engine.build_env(cfg, spec, pool,
                           jnp.asarray(cfg.domain_lo, jnp.float32),
                           jnp.asarray(cfg.cell_size, jnp.float32))
    pairs = grid.build_pairlist(spec, res.grid, res.pool.position,
                                res.pool.alive, radius=r + skin,
                                max_pairs=max_pairs)
    return (np.asarray(res.pool.position), np.asarray(res.pool.alive), pairs)


def _listed_sets(pairs):
    idx = np.asarray(pairs.idx)
    stored = np.asarray(pairs.run_off)[:, -1]
    return [set(idx[i, :stored[i]].tolist()) for i in range(idx.shape[0])]


def test_build_matches_bruteforce_inrange_sets():
    rng = np.random.default_rng(0)
    pos = _uniform(500, rng)
    r, skin = 3.0, 0.8
    spos, alive, pairs = _build_list(pos, r, skin)
    listed = _listed_sets(pairs)
    live = np.where(alive)[0]
    d2 = np.sum((spos[live, None] - spos[None, live]) ** 2, -1)
    rad2 = (r + skin) ** 2
    for a, i in enumerate(live):
        want = {int(live[b]) for b in np.where(d2[a] <= rad2)[0] if live[b] != i}
        assert listed[i] == want, f"row {i}"
    assert int(np.asarray(pairs.demand)) == max(len(s) for s in listed)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.2, 1.2),
       st.sampled_from(("uniform", "clustered", "anisotropic")))
def test_skin_coverage_property(seed, skin, domain):
    """No current-position pair within r is missing from a list built at
    r + skin, as long as per-agent euclidean displacement ≤ skin/2."""
    rng = np.random.default_rng(seed)
    n, r = 300, 3.0
    pos0 = _DOMAINS[domain](n, rng)
    spos, alive, pairs = _build_list(pos0, r, skin)
    listed = _listed_sets(pairs)
    # displace every agent by at most skin/2 (euclidean)
    step = rng.normal(size=(n, 3))
    step *= (rng.uniform(0, skin / 2, (n, 1))
             / np.maximum(np.linalg.norm(step, axis=1, keepdims=True), 1e-9))
    pos1 = spos + step.astype(np.float32)
    live = np.where(alive)[0]
    d2 = np.sum((pos1[live, None] - pos1[None, live]) ** 2, -1)
    for a, i in enumerate(live):
        for b in np.where(d2[a] <= r * r)[0]:
            j = int(live[b])
            if j == i:
                continue
            assert j in listed[i], (
                f"pair ({i},{j}) within r after bounded motion but unlisted "
                f"(skin={skin}, domain={domain})")


# ---------------------------------------------------------------------------
# skin=0 + every-step rebuilds: bit-exact vs the streamed sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pairlist_bit_exact_vs_streamed(impl):
    """Cross-mode equality holds bit-for-bit here because every pruned
    candidate contributes an exact +0.0 per lane.  The one caveat (see
    DESIGN.md §3.4): XLA:CPU's lane-axis reduction is lane-position
    sensitive, so a near-cancelling row can differ by ~1 ulp when packing
    shifts the nonzero lanes.  This seed/geometry has no such row — the
    assertions below are exact and deterministic; geometry with the
    cancellation is exercised tolerance-checked in the 4-shard test."""
    n, rng = 1000, np.random.default_rng(1)
    pos = _uniform(n, rng)
    states = {}
    for pl in (None, grid.PairListConfig(skin=0.0, max_pairs=96)):
        sim = Simulation(_cfg(n, force_impl=impl, pairlist=pl),
                         [Infection(radius=3.0, beta=0.4, recovery_time=8)])
        states[pl is None] = sim.run(_sir_state(sim, n, pos), 6,
                                     check_overflow=True)
    a, b = states[True], states[False]
    for ch in ("position", "agent_type", "force_nnz"):
        assert np.array_equal(np.asarray(getattr(a.pool, ch)),
                              np.asarray(getattr(b.pool, ch))), ch
    assert np.array_equal(np.asarray(a.pool.extra["infect_timer"]),
                          np.asarray(b.pool.extra["infect_timer"]))


# ---------------------------------------------------------------------------
# every_k skin reuse: a reused list serves the step exactly
# ---------------------------------------------------------------------------

def test_skin_reuse_step_matches_fresh_streamed():
    """After several reuse steps, one further step served by the cached list
    equals a step served by a fresh every-step streamed build from the SAME
    pool state — the stale extra candidates are exact zeros (compare
    order-invariantly: the two configs sort the pool differently)."""
    n, rng = 900, np.random.default_rng(2)
    pos = _uniform(n, rng)
    rb = grid.RebuildPolicy(mode="every_k", k=8, displacement_bound=0.45)
    d = Simulation(_cfg(n, rebuild=rb,
                        pairlist=grid.PairListConfig(skin=0.9, max_pairs=128)),
                   [Infection(radius=3.0, beta=0.4, recovery_time=8)])
    st = _sir_state(d, n, pos)
    skips = 0
    for _ in range(6):
        st = d.step(st)
        skips += int(st.stats.rebuild_skips)
    assert skips > 0, "skin budget should allow at least one reuse step"
    e = Simulation(_cfg(n), [Infection(radius=3.0, beta=0.4, recovery_time=8)])
    st_e = engine.EngineState(pool=st.pool, conc=st.conc, rng=st.rng,
                              iteration=st.iteration, stats=st.stats,
                              env=None)
    n1, n2 = d.step(st), e.step(st_e)

    def canon(p):
        P = np.asarray(p.position)[np.asarray(p.alive)]
        return P[np.lexsort(P.T)]

    assert np.array_equal(canon(n1.pool), canon(n2.pool))
    assert np.array_equal(np.sort(np.asarray(n1.pool.force_nnz)),
                          np.sort(np.asarray(n2.pool.force_nnz)))


# ---------------------------------------------------------------------------
# max_pairs ladder rung: overflow provenance + bit-identical rewind
# ---------------------------------------------------------------------------

def test_pair_overflow_provenance_and_raise():
    n, rng = 600, np.random.default_rng(3)
    pos = _clustered(n, rng)
    sim = Simulation(_cfg(n, pairlist=grid.PairListConfig(skin=0.0,
                                                          max_pairs=1)),
                     [Infection(radius=3.0, beta=0.4, recovery_time=8)])
    st = sim.step(_sir_state(sim, n, pos))
    flags = st.stats.flags()
    assert "pair_overflow" in flags
    assert int(st.stats.pair_demand) > 1
    with pytest.raises(RuntimeError, match="max_pairs"):
        sim.run(_sir_state(sim, n, pos), 1, check_overflow=True)


def test_max_pairs_rung_rewind_bit_parity():
    n, rng = 900, np.random.default_rng(4)
    pos = _uniform(n, rng)
    beh = lambda: [Infection(radius=3.0, beta=0.4, recovery_time=8)]
    lad = engine.CapacityLadder(
        _cfg(n, pairlist=grid.PairListConfig(skin=0.0, max_pairs=2)), beh())
    st = _sir_state(lad, n, pos)
    for _ in range(4):
        st = lad.step(st)
    assert any(r["field"] == "max_pairs" for r in lad.rungs), lad.rungs
    grown = lad.config.pairlist.max_pairs
    pre = Simulation(_cfg(n, pairlist=grid.PairListConfig(skin=0.0,
                                                          max_pairs=grown)),
                     beh())
    sp = pre.run(_sir_state(pre, n, pos), 4, check_overflow=True)
    for ch in ("position", "agent_type", "force_nnz"):
        assert np.array_equal(np.asarray(getattr(st.pool, ch)),
                              np.asarray(getattr(sp.pool, ch))), ch


def test_max_pairs_rung_with_cached_env_bit_parity():
    """The rewind under every_k: growing a cached (overflowed) list via
    grow_pairlist zero-padding must reproduce what a pre-sized run holds —
    the overflowing step's output is discarded, so a capped table never
    survives into a kept step."""
    n, rng = 900, np.random.default_rng(5)
    pos = _uniform(n, rng)
    rb = grid.RebuildPolicy(mode="every_k", k=8, displacement_bound=0.45)
    beh = lambda: [Infection(radius=3.0, beta=0.4, recovery_time=8)]
    lad = engine.CapacityLadder(
        _cfg(n, rebuild=rb,
             pairlist=grid.PairListConfig(skin=0.9, max_pairs=2)), beh())
    st = _sir_state(lad, n, pos)
    for _ in range(6):
        st = lad.step(st)
    assert any(r["field"] == "max_pairs" for r in lad.rungs), lad.rungs
    grown = lad.config.pairlist.max_pairs
    pre = Simulation(
        _cfg(n, rebuild=rb,
             pairlist=grid.PairListConfig(skin=0.9, max_pairs=grown)), beh())
    sp = _sir_state(pre, n, pos)
    for _ in range(6):
        sp = pre.step(sp)
    for ch in ("position", "agent_type", "force_nnz"):
        assert np.array_equal(np.asarray(getattr(st.pool, ch)),
                              np.asarray(getattr(sp.pool, ch))), ch


# ---------------------------------------------------------------------------
# distributed: 4-shard parity + distributed max_pairs rung (subprocess)
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax.numpy as jnp
    from repro.core import distributed, engine, grid
    from repro.core.behaviors import Infection, INFECTED, RandomWalk

    SIDE, n = 48.0, 1024
    rng = np.random.default_rng(7)
    pos = rng.uniform(2, SIDE - 2, (n, 3)).astype(np.float32)
    dia = np.full(n, 2.5, np.float32)
    types = np.zeros(n, np.int32)
    types[:32] = INFECTED

    def cfg(pairlist=None):
        return engine.EngineConfig(
            capacity=n, domain_lo=(0., 0., 0.), domain_hi=(SIDE,) * 3,
            interaction_radius=3.0, max_per_box=32, query_chunk=256,
            pairlist=pairlist)

    def beh():
        # RandomWalk drives agents across slab boundaries -> mid-run
        # migration exercises the dirty-on-structural-change conditions
        return [RandomWalk(sigma=0.35),
                Infection(radius=3.0, beta=0.4, recovery_time=8)]

    def dist(c):
        return distributed.DistConfig(engine=c, n_shards=4,
                                      local_capacity=2 * n // 4,
                                      halo_capacity=256, migrate_capacity=256)

    def init(sim):
        return sim.init_state(jnp.asarray(pos), jnp.asarray(dia),
                              jnp.asarray(types),
                              extra_init={"infect_timer":
                                          np.full(n, 8, np.int32)})

    def canon(ch):
        a = ch["alive"]
        o = np.lexsort(ch["position"][a].T)
        return ch["position"][a][o], ch["agent_type"][a][o]

    # (a) streamed vs pairlist(skin=0): parity through 8 steps with
    #     migration underway (ints exact, floats up to reduce-order ulps)
    out, migrated = {}, 0
    for pl in (None, grid.PairListConfig(skin=0.0, max_pairs=96)):
        sim = distributed.DistributedSimulation(dist(cfg(pl)), beh())
        st = init(sim)
        for _ in range(8):
            st = sim.step(st)
        out[pl is None] = canon(sim.gather_channels(st))
    dp = float(np.abs(out[True][0] - out[False][0]).max())
    dt = int(np.abs(out[True][1].astype(np.int64)
                    - out[False][1].astype(np.int64)).max())

    # (b) distributed max_pairs rung: ladder from a too-small table vs a
    #     pre-sized run — bit-identical after the rewind
    lad = distributed.DistributedCapacityLadder(
        dist(cfg(grid.PairListConfig(skin=0.0, max_pairs=2))), beh())
    st = init(lad)
    for _ in range(4):
        st = lad.step(st)
    grown = lad.dcfg.engine.pairlist.max_pairs
    rung_hit = any(r["field"] == "max_pairs" for r in lad.rungs)
    pre = distributed.DistributedSimulation(
        dist(cfg(grid.PairListConfig(skin=0.0, max_pairs=grown))), beh())
    sp = init(pre)
    for _ in range(4):
        sp = pre.step(sp)
    la, pa = canon(lad.sim.gather_channels(st)), canon(pre.gather_channels(sp))
    ladder_dp = float(np.abs(la[0] - pa[0]).max())

    print("RESULT " + json.dumps({
        "n_true": int(out[True][0].shape[0]),
        "n_false": int(out[False][0].shape[0]),
        "max_dpos": dp, "max_dtype": dt,
        "rung_hit": rung_hit, "grown": int(grown),
        "ladder_dpos": ladder_dp,
        "ladder_n": [int(la[0].shape[0]), int(pa[0].shape[0])]}))
""")


def test_pairlist_4shard_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["n_true"] == res["n_false"]
    # Cross-mode float channels are exact up to reduction-order ulps: the
    # pruned candidates contribute exact +0.0 per lane, but XLA:CPU's
    # lane-axis sum is lane-POSITION sensitive (verified: bit-equal per-lane
    # addends summed at packed vs streamed lane offsets differ by 1-2 ulp in
    # near-cancelling rows), so an occasional last-bit wiggle survives.
    # Integer channels and same-mode comparisons stay bit-exact.
    assert res["max_dpos"] <= 1e-5, res
    assert res["max_dtype"] == 0, res
    assert res["rung_hit"], res
    assert res["ladder_n"][0] == res["ladder_n"][1]
    assert res["ladder_dpos"] == 0.0, res
