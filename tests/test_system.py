"""End-to-end behaviour tests for the paper's system.

The headline claims, executed small: (1) a full simulation with every paper
optimization enabled runs, conserves invariants, and skips static work;
(2) fault tolerance round-trips a training run through a checkpoint with
identical results (bitwise resume)."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import GrowDivide, RandomDeath
from repro.data import DataConfig, batch_at
from repro.models import build_model, reduced_config
from repro.train import AdamWConfig, checkpoint, init_state, make_train_step


def test_full_engine_all_optimizations(rng):
    """Paper Fig 9 configuration: optimized grid + Morton sorting + static
    detection + parallel add/remove, all at once, on a churning population."""
    cfg = EngineConfig(capacity=2048, domain_lo=(0, 0, 0),
                       domain_hi=(120, 120, 120), interaction_radius=12.0,
                       dt=0.2, sort_frequency=5, detect_static=True,
                       max_per_box=128,
                       force=ForceParams(max_displacement=1.0))
    sim = Simulation(cfg, [GrowDivide(rate=0.8, threshold_diameter=12.0),
                           RandomDeath(rate=0.01)])
    pos = rng.uniform(40, 80, (128, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(128, 8.0, np.float32))
    st = sim.run(st, 40, check_overflow=True)
    n = int(st.stats["n_live"])
    alive = np.asarray(st.pool.alive)
    assert n > 0
    assert alive[:n].all() and not alive[n:].any()       # compaction invariant
    assert not np.isnan(np.asarray(st.pool.position)).any()
    assert int(st.stats["n_active"]) <= n                # statics never exceed


def test_train_checkpoint_resume_bitwise(tmp_path):
    """Kill-and-resume yields the same parameters as an uninterrupted run."""
    arch = reduced_config(ARCHS["qwen2-1.5b"])
    model = build_model(arch)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    dcfg = DataConfig(vocab_size=arch.vocab_size, seq_len=32, global_batch=4)
    step = jax.jit(make_train_step(model, ocfg))

    params = model.init_params(jax.random.PRNGKey(0))
    opt = init_state(ocfg, params)
    # uninterrupted: 6 steps
    p_ref, o_ref = params, opt
    for s in range(6):
        p_ref, o_ref, _ = step(p_ref, o_ref, batch_at(dcfg, s))

    # interrupted at step 3 + resume (stateless-by-step data pipeline)
    p, o = params, opt
    for s in range(3):
        p, o, _ = step(p, o, batch_at(dcfg, s))
    checkpoint.save(str(tmp_path), 3, {"params": p, "opt": o})
    restored = checkpoint.restore(str(tmp_path), 3, {"params": p, "opt": o})
    p, o = restored["params"], restored["opt"]
    for s in range(3, 6):
        p, o, _ = step(p, o, batch_at(dcfg, s))

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
