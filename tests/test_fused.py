"""Fused single-gather neighbor sweep (DESIGN.md §3.2) — parity + footprint.

The fused sweep (grid.resident_apply_fused) evaluates the force kernel and
every behavior-declared pair kernel against ONE candidate stream per block,
pruned to the union of their declared channel footprints. Contracts tested:

  * forces are BIT-EXACT vs the sequential per-phase path (the union block
    list visits a superset of blocks, but common blocks see identical slice
    offsets, gathers and run accumulation order; extra blocks write zeros
    under the force kernel's own mask);
  * SIR behaviors + statics match the sequential path (bit-exact on one
    device — the documented float-summation tolerance budget only pays when
    comparing across backends, e.g. the Pallas force kernel);
  * channel pruning never drops a declared channel, including behavior
    extras (``extra.*`` timers), and an UNdeclared read fails loudly at
    trace time instead of silently streaming the whole SoA;
  * the distributed engine inherits fusion through the shared core
    (4-shard subprocess, fused vs sequential bit-parity).
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineConfig, Simulation, engine, grid
from repro.core.behaviors import (Behavior, BehaviorEffects, Infection,
                                  RandomWalk, INFECTED, SUSCEPTIBLE)
from repro.core.forces import FORCE_READS


SIDE = 48.0


def _cluster(n, rng, side=SIDE):
    return rng.uniform(2, side - 2, (n, 3)).astype(np.float32)


def _cfg(n, **kw):
    base = dict(capacity=n, domain_lo=(0, 0, 0), domain_hi=(SIDE,) * 3,
                interaction_radius=3.0, max_per_box=32, query_chunk=256)
    base.update(kw)
    return EngineConfig(**base)


def _sir_state(sim, n, rng, recovery=12):
    pos = _cluster(n, rng)
    types = np.zeros(n, np.int32)
    types[: n // 20] = INFECTED
    return sim.init_state(pos, diameter=np.full(n, 2.5, np.float32),
                          agent_type=types,
                          extra_init={"infect_timer":
                                      np.full(n, recovery, np.int32)})


# ---------------------------------------------------------------------------
# forces: fused vs sequential is bit-exact
# ---------------------------------------------------------------------------

def test_forces_bit_exact_fused_vs_sequential():
    n, rng = 1500, np.random.default_rng(0)
    pos = _cluster(n, rng)
    states = {}
    for fused in (True, False):
        sim = Simulation(_cfg(n, fused_sweep=fused))
        st = sim.init_state(pos, diameter=np.full(n, 2.5, np.float32))
        st = sim.run(st, 6, check_overflow=True)
        states[fused] = st
    a, b = states[True], states[False]
    assert np.array_equal(np.asarray(a.pool.position),
                          np.asarray(b.pool.position))
    assert np.array_equal(np.asarray(a.pool.force_nnz),
                          np.asarray(b.pool.force_nnz))
    assert int(a.stats["n_live"]) == int(b.stats["n_live"]) == n


def test_fused_is_the_default():
    assert EngineConfig(capacity=8, domain_lo=(0, 0, 0),
                        domain_hi=(8, 8, 8),
                        interaction_radius=2.0).fused_sweep is True


# ---------------------------------------------------------------------------
# SIR behaviors + statics: fused vs sequential
# ---------------------------------------------------------------------------

def test_sir_statics_fused_vs_sequential():
    """Forces + Infection + detect_static: one fused sweep vs three-phase
    sequential. Single-device runs share accumulation order, so parity is
    bit-exact (the float-summation tolerance is budgeted for cross-backend
    comparisons only)."""
    n, rng = 1200, np.random.default_rng(1)
    states = {}
    for fused in (True, False):
        sim = Simulation(_cfg(n, fused_sweep=fused, detect_static=True),
                         [Infection(radius=3.0, beta=0.4, recovery_time=8)])
        st = _sir_state(sim, n, np.random.default_rng(1), recovery=8)
        st = sim.run(st, 10, check_overflow=True)
        states[fused] = st
    a, b = states[True], states[False]
    for ch in ("position", "agent_type", "static", "force_nnz"):
        assert np.array_equal(np.asarray(getattr(a.pool, ch)),
                              np.asarray(getattr(b.pool, ch))), ch
    assert np.array_equal(np.asarray(a.pool.extra["infect_timer"]),
                          np.asarray(b.pool.extra["infect_timer"]))
    assert int(a.stats["n_active"]) == int(b.stats["n_active"])
    t = np.asarray(a.pool.agent_type)[np.asarray(a.pool.alive)]
    assert (t != SUSCEPTIBLE).sum() > n // 20, "epidemic should spread"


def test_pallas_fused_vs_xla_fused():
    """force_impl='pallas' under the fused registry: K1 computes the force
    in-kernel, the behavior kernels share one pruned XLA sweep. Parity vs
    the all-XLA fused sweep is within float-order tolerance (different
    backend, different summation schedule)."""
    n, rng = 900, np.random.default_rng(2)
    states = {}
    for impl in ("pallas", "xla"):
        sim = Simulation(_cfg(n, force_impl=impl),
                         [Infection(radius=3.0, beta=0.4, recovery_time=8)])
        st = _sir_state(sim, n, np.random.default_rng(2), recovery=8)
        st = sim.run(st, 4, check_overflow=True)
        states[impl] = st
    a, b = states["pallas"], states["xla"]
    np.testing.assert_allclose(np.asarray(a.pool.position),
                               np.asarray(b.pool.position),
                               rtol=1e-5, atol=1e-4)
    assert np.array_equal(np.asarray(a.pool.agent_type),
                          np.asarray(b.pool.agent_type))


# ---------------------------------------------------------------------------
# footprint pruning
# ---------------------------------------------------------------------------

def test_realized_footprint_is_spec_driven():
    cfg = _cfg(64)
    # forces-only: exactly the force footprint, never infection timers
    assert engine.realized_footprint(cfg, []) == FORCE_READS
    # SIR-only: never streams diameter
    fp = engine.realized_footprint(
        dataclasses.replace(cfg, use_forces=False),
        [RandomWalk(), Infection()])
    assert "diameter" not in fp
    assert set(fp) == {"position", "alive", "agent_type"}


class TimerCount(Behavior):
    """Counts in-radius neighbors whose extra.timer exceeds a threshold —
    exercises an ``extra.*`` channel in a declared footprint."""

    name = "timer_count"

    def __init__(self, radius=3.0, thr=5):
        self.radius, self.thr = radius, thr

    def extra_specs(self):
        return {"timer": ((), jnp.int32, 0), "tcount": ((), jnp.int32, 0)}

    def neighbor_kernels(self):
        r, thr = self.radius, self.thr

        def pair_fn(q, nbr, valid, q_slot):
            d = nbr["position"] - q["position"][:, None, :]
            hit = valid & nbr["alive"] \
                & (jnp.sum(d * d, -1) <= r * r) \
                & (nbr["extra.timer"] > thr)
            return {"cnt": jnp.sum(hit, -1).astype(jnp.int32)}

        return (grid.PairKernel(
            name=self.name, pair_fn=pair_fn,
            out_specs={"cnt": ((), jnp.int32)},
            reads=("position", "alive", "extra.timer")),)

    def __call__(self, ctx, pool, rng):
        res = ctx.neighbor_results[self.name]   # fused path only (uniform)
        return BehaviorEffects(set_channels={"extra.tcount": res["cnt"]})


def test_extra_channel_footprint_gathers_and_matches_oracle():
    n, rng = 400, np.random.default_rng(3)
    pos = _cluster(n, rng)
    timers = rng.integers(0, 12, n).astype(np.int32)
    uid = np.arange(n, dtype=np.int32)
    beh = TimerCount(radius=3.0, thr=5)
    cfg = _cfg(n, use_forces=False)
    assert "extra.timer" in engine.realized_footprint(cfg, [beh])
    sim = Simulation(cfg, [beh])
    st = sim.init_state(pos, diameter=np.full(n, 1.0, np.float32),
                        agent_type=uid, extra_init={"timer": timers})
    st = sim.step(st)
    # O(N^2) oracle keyed by the uid channel (the resident build permutes)
    d2 = ((pos[:, None] - pos[None]) ** 2).sum(-1)
    hit = (d2 <= 9.0) & (timers[None] > 5)
    np.fill_diagonal(hit, False)
    ref = hit.sum(1).astype(np.int32)
    got_uid = np.asarray(st.pool.agent_type)
    got = np.asarray(st.pool.extra["tcount"])
    alive = np.asarray(st.pool.alive)
    assert np.array_equal(got[alive], ref[got_uid[alive]])


class UndeclaredRead(Behavior):
    """pair_fn reads nbr['diameter'] but declares only position/alive."""

    name = "undeclared"

    def neighbor_kernels(self):
        def pair_fn(q, nbr, valid, q_slot):
            near = valid & (nbr["diameter"] > 0)
            return {"n": jnp.sum(near, -1).astype(jnp.int32)}

        return (grid.PairKernel(name=self.name, pair_fn=pair_fn,
                                out_specs={"n": ((), jnp.int32)},
                                reads=("position", "alive")),)

    def __call__(self, ctx, pool, rng):
        return BehaviorEffects()


def test_undeclared_read_fails_loud_at_trace_time():
    n = 64
    cfg = _cfg(n, use_forces=False)   # nothing else declares 'diameter'
    sim = Simulation(cfg, [UndeclaredRead()])
    st = sim.init_state(np.zeros((8, 3), np.float32))
    with pytest.raises(KeyError):
        sim.step(st)


def test_check_kernel_footprints_catches_masked_underdeclaration():
    # with forces ON the fused union DOES contain 'diameter', so the sweep
    # itself would not catch the lie — the isolated per-kernel trace must
    cfg = _cfg(64, use_forces=True)
    with pytest.raises(KeyError, match="undeclared"):
        engine.check_kernel_footprints(cfg, [UndeclaredRead()])
    # and the catalogue behaviors pass
    engine.check_kernel_footprints(cfg, [RandomWalk(), Infection()])


def test_duplicate_kernel_names_rejected():
    cfg = _cfg(64, use_forces=False)
    with pytest.raises(ValueError, match="unique"):
        Simulation(cfg, [Infection(), Infection()])


# ---------------------------------------------------------------------------
# distributed: the shared core inherits fusion (4-shard subprocess)
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import json
    import numpy as np
    import jax.numpy as jnp
    from repro.core import distributed, engine
    from repro.core.behaviors import Infection, INFECTED

    SIDE, n = 64.0, 1024
    rng = np.random.default_rng(7)
    pos = rng.uniform(2, SIDE - 2, (n, 3)).astype(np.float32)
    dia = np.full(n, 2.5, np.float32)
    types = np.zeros(n, np.int32)
    types[:32] = INFECTED

    out = {}
    for fused in (True, False):
        cfg = engine.EngineConfig(
            capacity=n, domain_lo=(0., 0., 0.), domain_hi=(SIDE,) * 3,
            interaction_radius=3.0, use_forces=True, max_per_box=32,
            fused_sweep=fused)
        dcfg = distributed.DistConfig(engine=cfg, n_shards=4,
                                      local_capacity=2 * n // 4,
                                      halo_capacity=256,
                                      migrate_capacity=256)
        sim = distributed.DistributedSimulation(
            dcfg, [Infection(radius=3.0, beta=0.4, recovery_time=8)])
        st = sim.init_state(jnp.asarray(pos), jnp.asarray(dia),
                            jnp.asarray(types),
                            extra_init={"infect_timer":
                                        np.full(n, 8, np.int32)})
        for _ in range(8):
            st = sim.step(st)
        ch = sim.gather_channels(st)
        a = ch["alive"]
        o = np.lexsort(ch["position"][a].T)
        out[fused] = (ch["position"][a][o], ch["agent_type"][a][o])

    dp = float(np.abs(out[True][0] - out[False][0]).max())
    dt = int(np.abs(out[True][1].astype(np.int64)
                    - out[False][1].astype(np.int64)).max())
    print("RESULT " + json.dumps({
        "n_true": int(out[True][0].shape[0]),
        "n_false": int(out[False][0].shape[0]),
        "max_dpos": dp, "max_dtype": dt}))
""")


def test_fused_4shard_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["n_true"] == res["n_false"]
    # same slabs, same per-shard accumulation order: fused vs sequential is
    # bit-exact shard-by-shard, so the gathered trajectories agree exactly
    assert res["max_dpos"] == 0.0, res
    assert res["max_dtype"] == 0, res
