"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# K1: collision force
# ---------------------------------------------------------------------------

ADH = ((0.5, 0.1), (0.1, 0.7))


def _k1_case(rng, n, c, dims, box, adhesion, active_frac=1.0):
    # keep diameters small enough that box >= max interaction distance
    max_dia = box - 0.45
    pos = rng.uniform(0, dims[0] * box * 0.99, (n, 3)).astype(np.float32)
    dia = rng.uniform(0.4, max_dia, (n,)).astype(np.float32)
    typ = rng.integers(0, 2, (n,)).astype(np.int32)
    P = np.zeros((c, 3), np.float32); P[:n] = pos
    D = np.zeros((c,), np.float32); D[:n] = dia
    T = np.zeros((c,), np.int32); T[:n] = typ
    alive = np.zeros((c,), bool); alive[:n] = True
    active = alive.copy()
    if active_frac < 1.0:
        active[:n] = rng.random(n) < active_frac
    f, nnz, ovf = ops.collision_force(
        jnp.asarray(P), jnp.asarray(D), jnp.asarray(T), jnp.asarray(alive),
        jnp.asarray(active), jnp.zeros(3), jnp.asarray(box),
        dims=dims, k_rep=2.0, adhesion=adhesion, adhesion_band=0.4)
    assert not bool(ovf)
    fr, nr = ref.collision_force_ref(
        jnp.asarray(P), jnp.asarray(D), jnp.asarray(T), jnp.asarray(alive),
        2.0, adhesion, 0.4)
    # reference restricted to active rows (inactive rows are not computed)
    fr = jnp.where(jnp.asarray(active)[:, None], fr, 0.0)
    nr = jnp.where(jnp.asarray(active), nr, 0)
    return f, nnz, fr, nr


@pytest.mark.parametrize("n,c,dims,box,adhesion", [
    (60, 128, (8, 8, 8), 2.0, None),
    (200, 256, (10, 10, 10), 2.0, ADH),
    (500, 512, (12, 12, 12), 1.5, ADH),
    (128, 128, (6, 6, 6), 3.0, None),     # capacity == n (no padding slots)
    (1, 128, (8, 8, 8), 2.0, None),       # single agent: zero force
])
def test_collision_force_matches_ref(rng, n, c, dims, box, adhesion):
    f, nnz, fr, nr = _k1_case(rng, n, c, dims, box, adhesion)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(nr))


def test_collision_force_static_rows_skipped(rng):
    """Inactive (static) rows get zero output but still push active neighbors."""
    f, nnz, fr, nr = _k1_case(rng, 300, 384, (10, 10, 10), 2.0, ADH,
                              active_frac=0.5)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(nr))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 150), st.integers(0, 10_000))
def test_collision_force_property(n, seed):
    rng = np.random.default_rng(seed)
    f, nnz, fr, nr = _k1_case(rng, n, ((n + 127) // 128) * 128, (8, 8, 8), 2.5,
                              None)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=1e-4)


def test_collision_force_newton(rng):
    """Σ forces = 0 (momentum conservation) when all agents are active."""
    f, nnz, fr, nr = _k1_case(rng, 256, 256, (8, 8, 8), 2.5, ADH)
    np.testing.assert_allclose(np.asarray(f).sum(0), np.zeros(3), atol=1e-3)


# ---------------------------------------------------------------------------
# K2: flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,causal,dtype", [
    (2, 4, 2, 128, 128, 64, True, jnp.float32),
    (1, 8, 8, 256, 256, 32, True, jnp.float32),
    (1, 4, 1, 100, 100, 64, True, jnp.float32),     # non-aligned seq
    (2, 2, 2, 64, 192, 32, True, jnp.float32),      # chunked decode (Sq < Sk)
    (1, 4, 2, 128, 128, 64, False, jnp.float32),    # non-causal (encoder)
    (1, 2, 2, 128, 128, 128, True, jnp.bfloat16),   # bf16 inputs
    (1, 2, 1, 384, 384, 64, True, jnp.float32),     # multi-block both axes
])
def test_flash_attention_matches_ref(rng, b, hq, hkv, sq, sk, d, causal, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.sampled_from([1, 2, 4]), st.integers(1, 3),
       st.sampled_from([32, 64]), st.integers(0, 10_000))
def test_flash_attention_property(b, group, hkv, d, seed):
    rng = np.random.default_rng(seed)
    sq = int(rng.integers(2, 200))
    hq = group * hkv
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, sq, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, sq, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-5)


def test_flash_attention_rows_sum_to_one_property(rng):
    """softmax sanity: attending to identical V returns V."""
    b, h, s, d = 1, 2, 130, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.broadcast_to(jnp.asarray(rng.standard_normal((1, 1, 1, d)),
                                     jnp.float32), (b, h, s, d))
    out = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-5)
