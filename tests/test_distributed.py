"""Distributed ABM engine: multi-shard == single-device (subprocess test).

The main pytest process must keep the default 1-CPU view (conftest contract),
so the 8-device shard_map run executes in a subprocess with
--xla_force_host_platform_device_count=8.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import EngineConfig, ForceParams, Simulation
    from repro.core import distributed as D

    rng = np.random.default_rng(0)
    N = 400
    SIDE = 64.0
    cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0),
                       domain_hi=(SIDE,) * 3, interaction_radius=4.0,
                       dt=0.1, max_per_box=64, query_chunk=128,
                       force=ForceParams(max_displacement=0.5))
    pos = rng.uniform(2, SIDE - 2, (N, 3)).astype(np.float32)
    dia = np.full(N, 3.0, np.float32)

    # ---- single-device reference (forces only) ----
    sim = Simulation(cfg, [])
    st = sim.init_state(pos, diameter=dia)
    for _ in range(5):
        st = sim.step(st)
    ref_pos = np.asarray(st.pool.position)[np.asarray(st.pool.alive)]
    ref_sorted = ref_pos[np.lexsort(ref_pos.T)]

    # ---- distributed (8 slabs) ----
    n_shards = 8
    dcfg = D.DistConfig(engine=cfg, n_shards=n_shards, local_capacity=256,
                        halo_capacity=128, migrate_capacity=64)
    channels = {
        "position": jnp.asarray(np.pad(pos, ((0, 112), (0, 0)))),
        "diameter": jnp.asarray(np.pad(dia, (0, 112))),
        "agent_type": jnp.zeros(512, jnp.int32),
        "alive": jnp.asarray(np.arange(512) < N),
    }
    bounds = D.quantile_boundaries(channels["position"][:, 0],
                                   channels["alive"], n_shards, 0.0, SIDE)
    sharded = D.partition_global(channels, bounds, dcfg)
    mesh_kw = {}
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.6
        mesh_kw["axis_types"] = (jax.sharding.AxisType.Auto,)
    mesh = jax.make_mesh((n_shards,), ("data",), **mesh_kw)
    step = D.make_distributed_step(dcfg, mesh)
    stats = None
    for _ in range(5):
        sharded, stats = step(sharded, bounds)
    out_alive = np.asarray(sharded["alive"])
    out_pos = np.asarray(sharded["position"])[out_alive]
    out_sorted = out_pos[np.lexsort(out_pos.T)]

    result = {
        "n_ref": int(len(ref_sorted)), "n_dist": int(len(out_sorted)),
        "max_err": float(np.abs(ref_sorted - out_sorted).max())
                   if len(ref_sorted) == len(out_sorted) else -1.0,
        "halo_overflow": int(np.asarray(stats["halo_overflow"]).sum()),
        "migrate_overflow": int(np.asarray(stats["migrate_overflow"]).sum()),
        "n_live_per_shard": np.asarray(stats["n_live"]).ravel().tolist(),
    }
    print("RESULT " + json.dumps(result))
""")


def test_distributed_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["halo_overflow"] == 0
    assert res["migrate_overflow"] == 0
    assert res["n_ref"] == res["n_dist"], res
    assert 0 <= res["max_err"] < 1e-3, res
    # population balance: quantile slabs hold comparable counts
    counts = res["n_live_per_shard"]
    assert max(counts) - min(counts) <= 0.5 * max(counts), counts
