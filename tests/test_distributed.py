"""Distributed ABM engine: multi-shard == single-device (subprocess tests).

The distributed engine contains no force/query/behavior logic of its own —
every slab runs engine.make_iteration_core, the same Algorithm-1 body as
`Simulation`. These tests hold it to that claim: a forces-only run and a full
SIR epidemiology scenario (behaviors + deterministic births/deaths + agents
migrating across slabs mid-run + in-loop quantile rebalance) must match the
single-device oracle, and the sharded-diffusion path (face halos + collective
agent coupling) must reproduce the full-grid substance field.

The main pytest process must keep the default 1-CPU view (conftest contract),
so the 4-shard shard_map runs execute in one subprocess with
--xla_force_host_platform_device_count=4. Pure-host helpers
(quantile_boundaries hardening) are tested in-process.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (DistConfig, DistributedSimulation, EngineConfig,
                            ForceParams, Simulation)
    from repro.core.behaviors import (Behavior, BehaviorEffects, Chemotaxis,
                                      Infection, Secretion, INFECTED,
                                      RECOVERED, SUSCEPTIBLE)
    from repro.core.diffusion import DiffusionSpec

    results = {}
    rng = np.random.default_rng(0)
    SIDE = 48.0


    def live_summary(pos, *extras):
        o = np.lexsort(pos.T)
        return (pos[o],) + tuple(e[o] for e in extras)


    # ---------------- case 1: forces only, 4 slabs ----------------
    N = 400
    cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0),
                       domain_hi=(SIDE,) * 3, interaction_radius=4.0,
                       dt=0.1, max_per_box=64, query_chunk=128,
                       force=ForceParams(max_displacement=0.5))
    pos = rng.uniform(2, SIDE - 2, (N, 3)).astype(np.float32)
    dia = np.full(N, 3.0, np.float32)
    sim = Simulation(cfg, [])
    st = sim.init_state(pos, diameter=dia)
    for _ in range(5):
        st = sim.step(st)
    a = np.asarray(st.pool.alive)
    (ref_pos,) = live_summary(np.asarray(st.pool.position)[a])

    dcfg = DistConfig(engine=cfg, n_shards=4, local_capacity=256,
                      halo_capacity=128, migrate_capacity=64)
    dsim = DistributedSimulation(dcfg)
    dst = dsim.init_state(pos, diameter=dia)
    dst = dsim.run(dst, 5, check_overflow=True)
    da = np.asarray(dst.channels["alive"])
    (out_pos,) = live_summary(np.asarray(dst.channels["position"])[da])
    counts = np.asarray(dst.stats["n_live"]).ravel()
    results["forces"] = {
        "n_ref": int(a.sum()), "n_dist": int(da.sum()),
        "max_err": float(np.abs(ref_pos - out_pos).max())
                   if a.sum() == da.sum() else -1.0,
        "n_live_per_shard": counts.tolist(),
        "owned_committed": bool(np.all(
            np.asarray(dst.channels["extra.owned"])[da])),
    }


    # ---------------- case 2: SIR + births/deaths + migration ----------------
    class Drift(Behavior):
        '''Deterministic +x drift: every agent crosses slab boundaries.'''
        def __init__(self, vx):
            self.vx = vx

        def __call__(self, ctx, pool, rng):
            step = jnp.asarray([self.vx, 0.0, 0.0]) * ctx.dt
            new_pos = jnp.where(ctx.owned[:, None], pool.position + step,
                                pool.position)
            new_pos = jnp.clip(new_pos, ctx.domain_lo, ctx.domain_hi)
            return BehaviorEffects(set_channels={"position": new_pos})


    class RecoveredFate(Behavior):
        '''Deterministic births+deaths: a recovered agent seeds one
        susceptible child 3 steps after recovery and dies after 6.'''
        def extra_specs(self):
            return {"post": ((), jnp.int32, 0)}

        def __call__(self, ctx, pool, rng):
            rec = ctx.owned & (pool.agent_type == RECOVERED)
            post = jnp.where(rec, pool.extra["post"] + 1, pool.extra["post"])
            bp = jnp.clip(pool.position + jnp.asarray([0.0, 1.5, 0.0]),
                          ctx.domain_lo, ctx.domain_hi)
            return BehaviorEffects(
                set_channels={"extra.post": post},
                birth_channels={"position": bp, "diameter": pool.diameter,
                                "agent_type": jnp.zeros_like(pool.agent_type)},
                birth_valid=rec & (post == 3),
                death_mask=rec & (post >= 6))


    def sir_behaviors():
        # beta=1.0 makes Infection deterministic (u < 1.0 always); drift,
        # recovery, births and deaths are deterministic by construction, so
        # the 4-shard run must match the oracle exactly (up to fp tolerance)
        return [Drift(1.2), Infection(radius=4.0, beta=1.0, recovery_time=4),
                RecoveredFate()]


    N = 500
    cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0),
                       domain_hi=(SIDE,) * 3, interaction_radius=4.0,
                       dt=0.5, use_forces=True, max_per_box=64,
                       query_chunk=128, force=ForceParams(max_displacement=0.5))
    pos = rng.uniform(1, SIDE - 1, (N, 3)).astype(np.float32)
    dia = np.full(N, 2.0, np.float32)
    types = np.zeros(N, np.int32)
    types[:10] = INFECTED
    timers = {"infect_timer": np.full(N, 4, np.int32)}

    sim = Simulation(cfg, sir_behaviors())
    st = sim.init_state(pos, diameter=dia, agent_type=types, extra_init=timers)
    births = deaths = 0
    for _ in range(20):
        st = sim.step(st)
        births += int(st.stats["births"])
        deaths += int(st.stats["deaths"])
    a = np.asarray(st.pool.alive)
    ref_pos, ref_type, ref_post = live_summary(
        np.asarray(st.pool.position)[a],
        np.asarray(st.pool.agent_type)[a],
        np.asarray(st.pool.extra["post"])[a])

    dcfg = DistConfig(engine=cfg, n_shards=4, local_capacity=512,
                      halo_capacity=256, migrate_capacity=128,
                      rebalance_frequency=3)
    dsim = DistributedSimulation(dcfg, sir_behaviors())
    dst = dsim.init_state(pos, diameter=dia, agent_type=types,
                          extra_init=timers)
    bounds0 = np.asarray(dst.boundaries).copy()
    d_births = halo_ovf = mig_ovf = in_flight = 0
    for _ in range(20):
        dst = dsim.step(dst)
        d_births += int(np.asarray(dst.stats["births"]).sum())
        # stats are per-step: accumulate so a mid-run overflow can't hide
        halo_ovf += int(np.asarray(dst.stats["halo_overflow"]).sum())
        mig_ovf += int(np.asarray(dst.stats["migrate_overflow"]).sum())
        in_flight += int(np.asarray(dst.stats["in_flight"]).sum())
    da = np.asarray(dst.channels["alive"])
    out_pos, out_type, out_post = live_summary(
        np.asarray(dst.channels["position"])[da],
        np.asarray(dst.channels["agent_type"])[da],
        np.asarray(dst.channels["extra.post"])[da])
    same_n = int(a.sum()) == int(da.sum())
    results["sir"] = {
        "n_ref": int(a.sum()), "n_dist": int(da.sum()),
        "births_ref": births, "deaths_ref": deaths, "births_dist": d_births,
        "pos_err": float(np.abs(ref_pos - out_pos).max()) if same_n else -1.0,
        "type_match": bool(same_n and (ref_type == out_type).all()),
        "post_match": bool(same_n and (ref_post == out_post).all()),
        "sir_counts": [int((out_type == k).sum()) for k in (0, 1, 2)],
        "halo_overflow": halo_ovf,
        "migrate_overflow": mig_ovf,
        "in_flight": in_flight,
        "rebalanced": bool(
            np.any(np.asarray(dst.boundaries) != bounds0)),
        "n_live_per_shard": np.asarray(dst.stats["n_live"]).ravel().tolist(),
    }


    # ---------------- case 3: sharded diffusion (face halos) ----------------
    dspec = DiffusionSpec(dims=(16, 8, 8), coefficient=0.2, decay=0.01,
                          voxel=3.0)
    cfg = EngineConfig(capacity=256, domain_lo=(0, 0, 0),
                       domain_hi=(SIDE, 24, 24), interaction_radius=4.0,
                       dt=0.5, use_forces=False, max_per_box=64,
                       query_chunk=64, diffusion=dspec, diffusion_substeps=2)
    beh = lambda: [Secretion(rate=2.0), Chemotaxis(speed=0.8)]
    pos = rng.uniform(1, 23, (200, 3)).astype(np.float32)
    pos[:, 0] = rng.uniform(1, SIDE - 1, 200)
    dia = np.full(200, 2.0, np.float32)
    sim = Simulation(cfg, beh())
    st = sim.init_state(pos, diameter=dia)
    for _ in range(8):
        st = sim.step(st)
    dcfg = DistConfig(engine=cfg, n_shards=4, local_capacity=128,
                      halo_capacity=64, migrate_capacity=32)
    dsim = DistributedSimulation(dcfg, beh())
    dst = dsim.run(dsim.init_state(pos, diameter=dia), 8,
                   check_overflow=True)
    ref_c = np.asarray(st.conc)
    out_c = np.asarray(dst.conc)
    a = np.asarray(st.pool.alive)
    da = np.asarray(dst.channels["alive"])
    (rp,) = live_summary(np.asarray(st.pool.position)[a])
    (dp,) = live_summary(np.asarray(dst.channels["position"])[da])
    results["diffusion"] = {
        "conc_err": float(np.abs(ref_c - out_c).max()),
        "conc_scale": float(ref_c.max()),
        "pos_err": float(np.abs(rp - dp).max()) if len(rp) == len(dp)
                   else -1.0,
    }

    print("RESULT " + json.dumps(results))
""")


def _run_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


_CACHE = {}


def _results():
    if "res" not in _CACHE:
        _CACHE["res"] = _run_subprocess()
    return _CACHE["res"]


def test_distributed_forces_match_single_device():
    res = _results()["forces"]
    assert res["n_ref"] == res["n_dist"], res
    assert 0 <= res["max_err"] < 1e-3, res
    assert res["owned_committed"], "ghost rows leaked into the committed state"
    # population balance: quantile slabs hold comparable counts
    counts = res["n_live_per_shard"]
    assert max(counts) - min(counts) <= 0.5 * max(counts), counts


def test_distributed_sir_parity_with_births_deaths_migration():
    res = _results()["sir"]
    assert res["halo_overflow"] == 0 and res["migrate_overflow"] == 0, res
    assert res["in_flight"] == 0, res
    assert res["n_ref"] == res["n_dist"], res
    assert res["births_ref"] > 0 and res["deaths_ref"] > 0, \
        f"scenario must exercise births+deaths: {res}"
    assert res["births_dist"] == res["births_ref"], res
    assert 0 <= res["pos_err"] < 1e-3, res
    assert res["type_match"], "infection state diverged from the oracle"
    assert res["post_match"], "behavior extra channel diverged (ghost/migration layout)"
    assert res["sir_counts"][1] + res["sir_counts"][2] > 10, \
        f"epidemic should have spread: {res}"
    assert res["rebalanced"], "in-loop rebalance never updated boundaries"


def test_distributed_diffusion_slab_halos():
    res = _results()["diffusion"]
    assert res["conc_err"] <= 1e-4 * max(1.0, res["conc_scale"]), res
    assert 0 <= res["pos_err"] < 1e-3, res


# ---------------- pure-host hardening (no subprocess) ----------------

def test_quantile_boundaries_all_dead():
    import jax.numpy as jnp
    from repro.core.distributed import quantile_boundaries
    x = jnp.linspace(0.0, 10.0, 64)
    alive = jnp.zeros((64,), bool)
    b = np.asarray(quantile_boundaries(x, alive, 4, 0.0, 10.0))
    assert b[0] == 0.0 and b[-1] == 10.0
    assert np.all(np.diff(b) >= 0), b
    assert np.all((b >= 0.0) & (b <= 10.0)), b


def test_quantile_boundaries_single_cluster():
    import jax.numpy as jnp
    from repro.core.distributed import quantile_boundaries
    x = jnp.full((128,), 7.25)
    alive = jnp.ones((128,), bool)
    b = np.asarray(quantile_boundaries(x, alive, 8, 0.0, 10.0))
    assert b[0] == 0.0 and b[-1] == 10.0
    assert np.all(np.diff(b) >= 0), b
    # every inner boundary collapses onto the cluster; agents land in 1 slab
    assert np.all(b[1:-1] == np.float32(7.25)), b


def test_quantile_boundaries_balanced_split():
    import jax.numpy as jnp
    from repro.core.distributed import quantile_boundaries
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(0, 10, 4096).astype(np.float32))
    alive = jnp.asarray(rng.uniform(size=4096) < 0.7)
    b = np.asarray(quantile_boundaries(x, alive, 4, 0.0, 10.0))
    assert np.all(np.diff(b) > 0)
    xs = np.asarray(x)[np.asarray(alive)]
    counts = np.histogram(xs, bins=b)[0]
    assert max(counts) - min(counts) <= 0.1 * max(counts), counts
