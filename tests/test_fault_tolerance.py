"""Fault-tolerance subsystem (DESIGN.md §7.5): health guards, checkpoint /
resume, rollback-with-degradation, and crash recovery.

Every recovery path is exercised deterministically through the test-only
fault-injection hooks in core/health.py (NaN writes, bit flips, flag storms)
plus real SIGKILLs delivered to subprocess runs:

  * in-graph health bitmask: each predicate fires on exactly its fault;
  * checkpoint round-trips are bit-exact, every_k cache included;
  * CapacityExhausted carries the last-good state (supervisors recover);
  * the SupervisedRunner survives an injected NaN — rollback + degradation
    recorded in the run report, final state bit-exact with a clean run
    (the fused→sequential remedy is bit-exact, so recovery is invisible);
  * a SIGKILLed single-device capacity-ladder run resumes from the latest
    checkpoint bit-exact vs an uninterrupted oracle (subprocess);
  * same for a 4-shard distributed run, which also restores onto a
    different shard count (subprocess, conftest keeps this process 1-CPU).
"""

import dataclasses
import json
import os
import signal
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CapacityExhausted, CapacityLadder, EngineConfig,
                        ForceParams, LadderConfig, Simulation,
                        SupervisedRunner, restore_state, save_state)
from repro.core import health, simcheck
from repro.core.behaviors import GrowDivide, RandomWalk
from repro.core.grid import RebuildPolicy
from repro.core.stats import StepStats


def _cfg(**kw):
    base = dict(capacity=64, domain_lo=(0, 0, 0), domain_hi=(32, 32, 32),
                interaction_radius=2.0, dt=0.1, max_per_box=32,
                query_chunk=64, force=ForceParams(max_displacement=0.5))
    base.update(kw)
    return EngineConfig(**base)


def _pos(n=20, seed=0):
    return np.random.default_rng(seed).uniform(2, 30, (n, 3)).astype(
        np.float32)


def _same_trees(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# health predicates
# ---------------------------------------------------------------------------

def test_step_health_unit_bits():
    hcfg = health.HealthConfig(max_step_displacement=1.0)
    mask = jnp.asarray([True, True, False])
    lo = jnp.zeros(3)
    hi = jnp.full(3, 10.0)
    good = jnp.full((3, 3), 5.0)
    move = jnp.zeros((3, 3))
    assert int(health.step_health(hcfg, mask, good, lo, hi,
                                  move_d=move)) == 0
    nanp = good.at[0, 1].set(jnp.nan)
    assert int(health.step_health(hcfg, mask, nanp, lo, hi,
                                  move_d=move)) == health.NONFINITE
    esc = good.at[1, 2].set(11.0)
    assert int(health.step_health(hcfg, mask, esc, lo, hi,
                                  move_d=move)) == health.ESCAPE
    jump = move.at[0, 0].set(2.0)
    assert int(health.step_health(hcfg, mask, good, lo, hi,
                                  move_d=jump)) == health.DISPLACEMENT
    # masked rows never report (ghost/dead slots)
    dead_nan = good.at[2, 0].set(jnp.nan)
    assert int(health.step_health(hcfg, mask, dead_nan, lo, hi,
                                  move_d=move)) == 0
    # NaN force under the finite check
    nf = jnp.zeros((3, 3)).at[1, 0].set(jnp.inf)
    assert int(health.step_health(hcfg, mask, good, lo, hi, force=nf,
                                  move_d=move)) == health.NONFINITE
    assert health.describe(health.NONFINITE | health.ESCAPE) == (
        "nonfinite", "domain_escape")


def test_engine_detects_injected_nan_in_graph():
    sim = Simulation(_cfg(), [])
    st = sim.run(sim.init_state(_pos()), 2)
    assert int(st.stats["health"]) == 0
    bad = health.inject_value(st, "position", 3, np.nan)
    out = sim.step(bad)
    assert out.stats.health_bits() & health.NONFINITE
    # observability only: nothing raised, the run continued
    assert int(out.stats["n_live"]) > 0


def test_engine_detects_escape_and_flip_bits():
    sim = Simulation(_cfg(use_forces=False), [])
    st = sim.run(sim.init_state(_pos()), 1)
    esc = health.inject_value(st, "position", 5, 99.0)   # outside the box
    out = sim.step(esc)
    assert out.stats.health_bits() & health.ESCAPE
    # a flipped sign bit throws the agent below domain_lo deterministically
    flip = health.flip_bits(st, "position", 2, mask=0x80000000)
    out2 = sim.step(flip)
    assert out2.stats.health_bits() & health.ESCAPE


def test_engine_displacement_guard():
    hcfg = health.HealthConfig(max_step_displacement=0.05)
    sim = Simulation(_cfg(use_forces=False, health=hcfg),
                     [RandomWalk(sigma=5.0)])
    st = sim.step(sim.init_state(_pos()))
    assert st.stats.health_bits() & health.DISPLACEMENT


def test_health_disabled_entirely():
    sim = Simulation(_cfg(health=None), [])
    st = sim.step(sim.init_state(_pos()))
    assert int(st.stats["health"]) == 0


def test_storm_flags_injection():
    sim = Simulation(_cfg(), [])
    st = sim.step(sim.init_state(_pos()))
    stormy = health.storm_flags(st, "birth_overflow", 3)
    assert stormy.stats.flags() == {"birth_overflow": 3}
    assert stormy.stats.any_overflow()


# ---------------------------------------------------------------------------
# StepStats helpers
# ---------------------------------------------------------------------------

def test_stats_flags_helpers():
    s = StepStats.zeros()
    assert s.flags() == {} and not s.any_overflow() and s.health_bits() == 0
    s = dataclasses.replace(s, halo_overflow=jnp.asarray(2, jnp.int32),
                            box_demand=jnp.asarray(99, jnp.int32),
                            health=jnp.asarray(5, jnp.int32))
    assert s.flags() == {"halo_overflow": 2}      # demands are not flags
    assert s.any_overflow()
    assert s.health_bits() == 5
    # per-shard vectors reduce across shards
    v = StepStats.zeros((4,))
    v = dataclasses.replace(
        v, birth_overflow=jnp.asarray([0, 1, 0, 2], jnp.int32),
        health=jnp.asarray([1, 0, 4, 0], jnp.int32))
    assert v.flags() == {"birth_overflow": 3}
    assert v.health_bits() == 5


# ---------------------------------------------------------------------------
# CapacityExhausted
# ---------------------------------------------------------------------------

def test_capacity_exhausted_carries_state():
    cfg = _cfg(capacity=32, domain_hi=(64, 64, 64), interaction_radius=6.0,
               max_per_box=64, dt=0.2,
               force=ForceParams(max_displacement=1.0))
    lad = CapacityLadder(cfg, [GrowDivide(rate=3.0, threshold_diameter=5.0)],
                         LadderConfig(max_capacity=48))
    # diameter 3.0 → ~4 growth steps before the mass division, so the
    # carried last-good state is a real mid-run state, not the init state
    st = lad.init_state(np.random.default_rng(1).uniform(
        20, 44, (30, 3)).astype(np.float32),
        diameter=np.full(30, 3.0, np.float32))
    with pytest.raises(CapacityExhausted, match="ladder exhausted") as e:
        lad.run(st, 60)
    exc = e.value
    assert isinstance(exc, RuntimeError)          # legacy contract
    assert exc.state is not None and exc.stats is not None
    assert exc.iteration == int(exc.state.iteration)
    assert exc.demand > exc.max_capacity == 48
    # the carried state is steppable — a supervisor can checkpoint it
    assert int(exc.state.stats["n_live"]) > 0


# ---------------------------------------------------------------------------
# checkpoint / resume (single device, in-process)
# ---------------------------------------------------------------------------

def test_simcheck_roundtrip_bit_exact(tmp_path):
    cfg = _cfg()
    sim = Simulation(cfg, [RandomWalk(sigma=0.2)])
    st = sim.run(sim.init_state(_pos(), seed=7), 5)
    save_state(str(tmp_path), st, cfg)
    st2, cfg2 = restore_state(str(tmp_path), cfg, [RandomWalk(sigma=0.2)])
    assert _same_trees(st, st2)
    a = sim.run(st, 6)
    b = Simulation(cfg2, [RandomWalk(sigma=0.2)]).run(st2, 6)
    assert _same_trees(a, b), "resume must be bit-exact"


def test_simcheck_roundtrip_every_k_cache(tmp_path):
    cfg = _cfg(rebuild=RebuildPolicy(mode="every_k", k=4,
                                     displacement_bound=0.5))
    sim = Simulation(cfg, [RandomWalk(sigma=0.05)])
    st = sim.run(sim.init_state(_pos(), seed=3), 6)
    save_state(str(tmp_path), st, cfg)
    st2, cfg2 = restore_state(str(tmp_path), cfg, [RandomWalk(sigma=0.05)])
    assert st2.env is not None
    assert int(st2.env.steps_since) == int(st.env.steps_since)
    a = sim.run(st, 7)
    b = Simulation(cfg2, [RandomWalk(sigma=0.05)]).run(st2, 7)
    assert _same_trees(a, b), \
        "every_k skip schedule must survive the round-trip bit-exactly"
    # rebuild accounting carried over: skip cadence identical
    assert int(a.stats["rebuild_skips"]) == int(b.stats["rebuild_skips"])


def test_restore_adapts_env_across_rebuild_modes(tmp_path):
    cfg = _cfg(rebuild=RebuildPolicy(mode="every_k", k=4,
                                     displacement_bound=0.5))
    sim = Simulation(cfg, [])
    st = sim.run(sim.init_state(_pos()), 3)
    save_state(str(tmp_path), st, cfg)
    # a degraded target config dropped the cache: env must be dropped too
    target = _cfg()        # every_step
    st2, cfg2 = restore_state(str(tmp_path), target, [], apply_knobs="rungs")
    assert cfg2.rebuild.mode == "every_step" and st2.env is None
    Simulation(cfg2, []).run(st2, 2)             # steppable


def test_restore_rejects_non_sim_checkpoint(tmp_path):
    from repro.train import checkpoint
    checkpoint.save(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError, match="not a simulation checkpoint"):
        restore_state(str(tmp_path), _cfg(), [])


# ---------------------------------------------------------------------------
# degradation policy + supervised runner
# ---------------------------------------------------------------------------

def test_degradation_policy_order():
    pol = simcheck.DegradationPolicy(max_dt_shrinks=2)
    cfg = _cfg(rebuild=RebuildPolicy(mode="every_k", k=4,
                                     displacement_bound=0.5))
    applied = []
    names = []
    while True:
        r = pol.next_remedy(cfg, applied)
        if r is None:
            break
        name, cfg = r
        names.append(name)
        applied.append(name)
    assert names == ["rebuild_every_step", "sequential_sweep", "shrink_dt",
                     "shrink_dt"]
    assert cfg.rebuild.mode == "every_step"
    assert not cfg.fused_sweep and cfg.force_impl == "xla"
    assert abs(cfg.dt - 0.1 * 0.25) < 1e-9


def test_supervisor_nan_rollback_and_degradation(tmp_path):
    cfg = _cfg()
    pos = _pos()
    clean = CapacityLadder(cfg, [])
    oracle = clean.run(clean.init_state(pos, seed=7), 12)

    fired = []

    def hook(it, state):
        if it == 6 and not fired:
            fired.append(it)
            return health.inject_value(state, "position", 3, np.nan)
        return None

    lad = CapacityLadder(cfg, [])
    runner = SupervisedRunner(lad, str(tmp_path), checkpoint_every=5,
                              fault_hook=hook)
    final, report = runner.run(lad.init_state(pos, seed=7), 12)
    assert report.completed and report.final_iteration == 12
    assert report.retries == 1
    [iv] = report.interventions
    assert iv["kind"] == "health" and "nonfinite" in iv["flags"]
    assert iv["remedy"] == "sequential_sweep"     # fused → sequential XLA
    assert iv["rolled_back_to"] == 5
    # the sequential remedy is bit-exact, so recovery leaves no trace
    assert _same_trees(oracle.pool, final.pool)
    assert int(final.iteration) == int(oracle.iteration)


def test_supervisor_reraises_with_report_when_remedies_exhausted(tmp_path):
    cfg = _cfg(fused_sweep=False)                 # only dt shrinks remain

    def hook(it, state):                          # corrupt every attempt
        return health.inject_value(state, "position", 1, np.nan)

    lad = CapacityLadder(cfg, [])
    runner = SupervisedRunner(
        lad, str(tmp_path), checkpoint_every=5,
        policy=simcheck.DegradationPolicy(max_dt_shrinks=1), fault_hook=hook)
    with pytest.raises(health.HealthFault) as e:
        runner.run(lad.init_state(_pos(), seed=7), 12)
    rep = e.value.report
    assert rep is not None and not rep.completed
    assert [iv["remedy"] for iv in rep.interventions] == ["shrink_dt"]


def test_supervisor_capacity_exhaustion_emergency_checkpoint(tmp_path):
    cfg = _cfg(capacity=32, domain_hi=(64, 64, 64), interaction_radius=6.0,
               max_per_box=64, dt=0.2,
               force=ForceParams(max_displacement=1.0))
    lad = CapacityLadder(cfg, [GrowDivide(rate=3.0, threshold_diameter=5.0)],
                         LadderConfig(max_capacity=48))
    st = lad.init_state(np.random.default_rng(1).uniform(
        20, 44, (30, 3)).astype(np.float32),
        diameter=np.full(30, 3.0, np.float32))
    runner = SupervisedRunner(lad, str(tmp_path), checkpoint_every=50,
                              max_retries=2)
    with pytest.raises(CapacityExhausted) as e:
        runner.run(st, 60)
    rep = e.value.report
    assert rep.retries > 0
    assert any(iv["kind"] == "capacity_exhausted"
               for iv in rep.interventions)
    # the emergency checkpoint preserved the last-good trajectory on disk
    from repro.train import checkpoint
    assert checkpoint.latest_step(str(tmp_path)) is not None


# ---------------------------------------------------------------------------
# crash-resume: SIGKILL mid-flight, resume bit-exact (subprocess)
# ---------------------------------------------------------------------------

_CRASH_SCRIPT = textwrap.dedent("""
    import hashlib, os, signal, sys
    import numpy as np
    from repro.core import (CapacityLadder, EngineConfig, ForceParams,
                            LadderConfig, SupervisedRunner, restore_state)
    from repro.core.behaviors import GrowDivide, RandomDeath, RandomWalk

    mode, ckpt = sys.argv[1], sys.argv[2]
    TOTAL, KILL_AT = 40, 23

    def make():
        cfg = EngineConfig(capacity=256, domain_lo=(0, 0, 0),
                           domain_hi=(160, 160, 160),
                           interaction_radius=14.0, dt=0.2,
                           sort_frequency=10, max_per_box=160,
                           force=ForceParams(max_displacement=1.0))
        behs = [GrowDivide(rate=0.7, threshold_diameter=12.0),
                RandomWalk(sigma=0.1), RandomDeath(rate=0.012)]
        return cfg, behs

    def digest(state):
        a = np.asarray(state.pool.alive)
        p = np.asarray(state.pool.position)[a]
        p = p[np.lexsort(p.T)]
        return hashlib.sha256(p.tobytes()).hexdigest()

    rng = np.random.default_rng(3)
    pos = rng.uniform(55, 105, (200, 3)).astype(np.float32)
    dia = np.full(200, 9.0, np.float32)
    cfg, behs = make()

    if mode == "oracle":
        lad = CapacityLadder(cfg, behs)
        st = lad.run(lad.init_state(pos, diameter=dia), TOTAL)
        print("RESULT " + digest(st) + " " + str(int(st.iteration)))
    elif mode == "kill":
        def hook(it, state):
            if it == KILL_AT:
                os.kill(os.getpid(), signal.SIGKILL)   # no cleanup, no atexit
            return None
        lad = CapacityLadder(cfg, behs)
        runner = SupervisedRunner(lad, ckpt, checkpoint_every=5,
                                  fault_hook=hook)
        runner.run(lad.init_state(pos, diameter=dia), TOTAL)
        print("RESULT survived")                        # must never print
    elif mode == "resume":
        st, rcfg = restore_state(ckpt, cfg, behs)
        lad = CapacityLadder(rcfg, behs)
        runner = SupervisedRunner(lad, ckpt, checkpoint_every=5)
        st, report = runner.run(st, TOTAL - int(st.iteration))
        assert report.completed, report
        print("RESULT " + digest(st) + " " + str(int(st.iteration)))
""")


def _run_child(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-c", _CRASH_SCRIPT] + args,
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


def _result_line(proc):
    assert proc.returncode == 0, proc.stderr[-3000:]
    return [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1][len("RESULT "):]


def test_sigkill_ladder_run_resumes_bit_exact(tmp_path):
    ckpt = str(tmp_path / "ck")
    killed = _run_child(["kill", ckpt])
    assert killed.returncode == -signal.SIGKILL, \
        f"child exited {killed.returncode}: {killed.stderr[-2000:]}"
    assert "RESULT survived" not in killed.stdout
    from repro.train import checkpoint
    assert checkpoint.latest_step(ckpt) is not None, \
        "no checkpoint survived the kill"
    resumed = _result_line(_run_child(["resume", ckpt]))
    oracle = _result_line(_run_child(["oracle", str(tmp_path / "unused")]))
    assert resumed == oracle, \
        f"resumed {resumed} != uninterrupted {oracle}"


# ---------------------------------------------------------------------------
# distributed: checkpoint/SIGKILL-resume/reshard on 4 shards (subprocess)
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import hashlib, os, signal, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from repro.core import (DistConfig, DistributedCapacityLadder,
                            DistributedSimulation, EngineConfig, ForceParams,
                            SupervisedRunner, restore_dist_state,
                            save_dist_state)
    from repro.core import health
    from repro.core.behaviors import RandomWalk

    mode, ckpt = sys.argv[1], sys.argv[2]
    TOTAL, KILL_AT = 16, 10
    SIDE = 48.0

    def make(n_shards=4, local=256):
        cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0),
                           domain_hi=(SIDE,) * 3, interaction_radius=4.0,
                           dt=0.1, max_per_box=64, query_chunk=128,
                           force=ForceParams(max_displacement=0.5))
        return DistConfig(engine=cfg, n_shards=n_shards,
                          local_capacity=local, halo_capacity=128,
                          migrate_capacity=64), [RandomWalk(sigma=0.3)]

    def digest(state):
        a = np.asarray(state.channels["alive"])
        p = np.asarray(state.channels["position"])[a]
        p = p[np.lexsort(p.T)]
        return hashlib.sha256(p.tobytes()).hexdigest()

    rng = np.random.default_rng(0)
    pos = rng.uniform(2, SIDE - 2, (400, 3)).astype(np.float32)
    dia = np.full(400, 3.0, np.float32)
    dcfg, behs = make()

    if mode == "oracle":
        lad = DistributedCapacityLadder(dcfg, behs)
        st = lad.run(lad.init_state(pos, diameter=dia), TOTAL)
        print("RESULT " + digest(st) + " " + str(int(st.iteration)))
    elif mode == "kill":
        def hook(it, state):
            if it == KILL_AT:
                os.kill(os.getpid(), signal.SIGKILL)
            return None
        lad = DistributedCapacityLadder(dcfg, behs)
        runner = SupervisedRunner(lad, ckpt, checkpoint_every=4,
                                  fault_hook=hook)
        runner.run(lad.init_state(pos, diameter=dia), TOTAL)
        print("RESULT survived")
    elif mode == "resume":
        st, rcfg = restore_dist_state(ckpt, dcfg, behs)
        lad = DistributedCapacityLadder(rcfg, behs)
        runner = SupervisedRunner(lad, ckpt, checkpoint_every=4)
        st, report = runner.run(st, TOTAL - int(st.iteration))
        assert report.completed, report
        print("RESULT " + digest(st) + " " + str(int(st.iteration)))
    elif mode == "reshard":
        # restore a 4-shard checkpoint onto 2 shards: population and
        # iteration survive; the run continues (layout differs, so no
        # bit-exactness claim)
        dsim = DistributedSimulation(dcfg, behs)
        st = dsim.run(dsim.init_state(pos, diameter=dia), 5)
        save_dist_state(ckpt, st, dcfg)
        n_before = int(np.asarray(st.channels["alive"]).sum())
        d2, _ = make(n_shards=2, local=512)
        st2, rcfg = restore_dist_state(ckpt, d2, behs)
        assert rcfg.n_shards == 2
        assert int(st2.iteration) == 5
        n_after = int(np.asarray(st2.channels["alive"]).sum())
        assert n_after == n_before, (n_before, n_after)
        out = DistributedSimulation(rcfg, behs).run(st2, 3,
                                                    check_overflow=True)
        print("RESULT ok " + str(int(np.asarray(
            out.channels["alive"]).sum())))
    elif mode == "inject":
        # in-graph guard + supervisor recovery on the distributed engine
        fired = []
        def hook(it, state):
            if it == 6 and not fired:
                fired.append(it)
                return health.inject_value(state, "position", 3, np.nan)
            return None
        lad = DistributedCapacityLadder(dcfg, behs)
        runner = SupervisedRunner(lad, ckpt, checkpoint_every=4,
                                  fault_hook=hook)
        st, report = runner.run(lad.init_state(pos, diameter=dia), TOTAL)
        assert report.completed, report
        assert len(report.interventions) == 1, report.interventions
        assert report.interventions[0]["kind"] == "health"
        lad2 = DistributedCapacityLadder(*make())
        oracle = lad2.run(lad2.init_state(pos, diameter=dia), TOTAL)
        assert digest(st) == digest(oracle), "recovery must be invisible"
        print("RESULT ok " + report.interventions[0]["remedy"])
""")


def _run_dist_child(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-c", _DIST_SCRIPT] + args,
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


def test_dist_sigkill_resume_bit_exact(tmp_path):
    ckpt = str(tmp_path / "ck")
    killed = _run_dist_child(["kill", ckpt])
    assert killed.returncode == -signal.SIGKILL, \
        f"child exited {killed.returncode}: {killed.stderr[-2000:]}"
    resumed = _result_line(_run_dist_child(["resume", ckpt]))
    oracle = _result_line(_run_dist_child(["oracle",
                                           str(tmp_path / "unused")]))
    assert resumed == oracle, \
        f"resumed {resumed} != uninterrupted {oracle}"


def test_dist_restore_onto_different_shard_count(tmp_path):
    out = _result_line(_run_dist_child(["reshard", str(tmp_path / "ck")]))
    assert out.startswith("ok "), out


def test_dist_nan_injection_supervised_recovery(tmp_path):
    out = _result_line(_run_dist_child(["inject", str(tmp_path / "ck")]))
    assert out == "ok sequential_sweep", out
