"""Engine integration: the paper's five simulation archetypes at test scale."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import (GrowDivide, Infection, RandomDeath,
                                  RandomWalk, Chemotaxis, Secretion,
                                  INFECTED, SUSCEPTIBLE)
from repro.core import diffusion as D


def test_proliferation_grows(rng):
    cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0), domain_hi=(80, 80, 80),
                       interaction_radius=14.0, dt=0.2, max_per_box=64,
                       force=ForceParams(max_displacement=1.0))
    sim = Simulation(cfg, [GrowDivide(rate=2.0, threshold_diameter=12.0)])
    pos = rng.uniform(30, 50, (32, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(32, 8.0, np.float32))
    st = sim.run(st, 25, check_overflow=True)
    assert int(st.stats["n_live"]) > 32
    assert not np.isnan(np.asarray(st.pool.position)).any()
    assert not np.isnan(np.asarray(st.pool.diameter)).any()


def test_epidemiology_spreads(rng):
    cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0), domain_hi=(40, 40, 40),
                       interaction_radius=3.0, use_forces=False)
    sim = Simulation(cfg, [RandomWalk(sigma=0.8),
                           Infection(radius=3.0, beta=0.5, recovery_time=20)])
    pos = rng.uniform(0, 40, (800, 3)).astype(np.float32)
    types = np.zeros(800, np.int32)
    types[:8] = INFECTED
    st = sim.init_state(pos, diameter=np.full(800, 1.0, np.float32),
                        agent_type=types,
                        extra_init={"infect_timer": np.full(800, 20, np.int32)})
    st = sim.run(st, 40)
    t = np.asarray(st.pool.agent_type[:800])
    assert ((t == 1) | (t == 2)).sum() > 8, "epidemic must spread beyond seeds"
    assert int(st.stats["n_live"]) == 800  # SIR conserves population


def test_static_detection_quiesces():
    cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0), domain_hi=(40, 40, 40),
                       interaction_radius=4.0, detect_static=True, dt=0.1)
    sim = Simulation(cfg, [])
    xs = np.stack(np.meshgrid(*[np.arange(5) * 6.0 + 5] * 3), -1
                  ).reshape(-1, 3).astype(np.float32)
    st = sim.init_state(xs, diameter=np.full(len(xs), 2.0, np.float32))
    st = sim.step(st)                       # iteration 0: everything active
    assert int(st.stats["n_active"]) == len(xs)
    st = sim.step(st)                       # iteration 1: all static
    assert int(st.stats["n_active"]) == 0


def test_static_detection_wakes_on_insertion():
    """Condition (iii): adding an agent wakes its neighborhood."""
    cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0), domain_hi=(40, 40, 40),
                       interaction_radius=4.0, detect_static=True, dt=0.1,
                       force=ForceParams(move_eps=1e-6))
    sim = Simulation(cfg, [GrowDivide(rate=0.0, threshold_diameter=3.9)])
    # separated dimers; rate 0 so nothing divides after warmup
    xs = np.stack(np.meshgrid(*[np.arange(4) * 8.0 + 4] * 3), -1
                  ).reshape(-1, 3).astype(np.float32)
    st = sim.init_state(xs, diameter=np.full(len(xs), 2.0, np.float32))
    for _ in range(3):
        st = sim.step(st)
    assert int(st.stats["n_active"]) == 0
    # bump one diameter over the division threshold -> a birth occurs ->
    # neighborhood must wake next iteration
    pool = st.pool
    dia = pool.diameter.at[0].set(3.95)
    st = dataclasses.replace(st, pool=dataclasses.replace(pool, diameter=dia))
    st = sim.step(st)                      # division happens here
    assert int(st.stats["births"]) >= 1
    st = sim.step(st)                      # newborn + mother active now
    assert int(st.stats["n_active"]) >= 2


def test_oncology_death_compacts(rng):
    cfg = EngineConfig(capacity=512, domain_lo=(0, 0, 0), domain_hi=(30, 30, 30),
                       interaction_radius=3.0, use_forces=False)
    sim = Simulation(cfg, [RandomDeath(rate=0.2)])
    pos = rng.uniform(0, 30, (400, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(400, 1.0, np.float32))
    st = sim.run(st, 10)
    n = int(st.stats["n_live"])
    assert n < 400
    alive = np.asarray(st.pool.alive)
    assert alive[:n].all() and not alive[n:].any()   # compaction invariant


def test_clustering_with_diffusion(rng):
    dspec = D.DiffusionSpec(dims=(16, 16, 16), coefficient=0.4, decay=0.01,
                            voxel=2.0)
    cfg = EngineConfig(capacity=256, domain_lo=(0, 0, 0), domain_hi=(32, 32, 32),
                       interaction_radius=3.0, use_forces=False,
                       diffusion=dspec)
    sim = Simulation(cfg, [Secretion(rate=2.0), Chemotaxis(speed=0.4)])
    pos = rng.uniform(4, 28, (128, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(128, 1.0, np.float32))
    p0 = np.asarray(st.pool.position[:128])
    st = sim.run(st, 30)
    p1 = np.asarray(st.pool.position[:128])
    # mean pairwise distance must shrink (agents chase their own secretion)
    def mpd(p):
        d = np.sqrt(((p[:, None] - p[None]) ** 2).sum(-1))
        return d[np.triu_indices(len(p), 1)].mean()
    assert mpd(p1) < mpd(p0)
    assert float(st.conc.max()) > 0.0


def test_sort_frequency_preserves_semantics(rng):
    """Sorting is a pure layout optimization: population statistics match."""
    pos = rng.uniform(10, 50, (200, 3)).astype(np.float32)
    results = []
    for freq in (0, 1, 5):
        cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0),
                           domain_hi=(60, 60, 60), interaction_radius=12.0,
                           dt=0.2, sort_frequency=freq, max_per_box=64,
                           force=ForceParams(max_displacement=1.0))
        sim = Simulation(cfg, [GrowDivide(rate=1.0, threshold_diameter=12.0)])
        st = sim.init_state(pos, diameter=np.full(200, 9.0, np.float32))
        st = sim.run(st, 12)
        results.append(int(st.stats["n_live"]))
    assert results[0] == results[1] == results[2]


def test_brute_force_env_matches_grid(rng):
    """Same simulation under brute_force and uniform_grid environments."""
    pos = rng.uniform(10, 30, (60, 3)).astype(np.float32)
    finals = {}
    for env in ("uniform_grid", "brute_force"):
        cfg = EngineConfig(capacity=128, domain_lo=(0, 0, 0),
                           domain_hi=(40, 40, 40), interaction_radius=6.0,
                           dt=0.1, environment=env, max_per_box=64,
                           force=ForceParams(max_displacement=0.5))
        sim = Simulation(cfg, [])
        st = sim.init_state(pos, diameter=np.full(60, 5.0, np.float32))
        st = sim.run(st, 5)
        finals[env] = np.asarray(st.pool.position[:60])
    np.testing.assert_allclose(finals["uniform_grid"], finals["brute_force"],
                               rtol=1e-5, atol=1e-5)
