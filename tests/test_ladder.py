"""Capacity ladder (DESIGN.md §4.3): overflow→grow→re-run must be invisible.

The contract under test: a run that starts in a deliberately tiny pool and
grows through several rungs (capacity, max_per_run — and distributed:
local/halo/migrate capacity) produces **bit-identical** live trajectories to
a run pre-sized at the final rung. This leans on two engine properties that
are tested here on their own as well:

  * restage safety — grow_pool/grow_channels preserve the live prefix
    verbatim and append dead zero slots (donation or not);
  * capacity-stable randomness — behaviors draw through rand.py, so a draw
    at slot i is independent of the pool's capacity.

The dtype policy is a tolerance trade, not bit-exact: its parity test is
approximate by design.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp

import pytest

from repro.core import (CapacityLadder, DtypePolicy, EngineConfig, ForceParams,
                        LadderConfig, Simulation, grow_channels, grow_pool,
                        make_pool)
from repro.core import rand
from repro.core.behaviors import GrowDivide, RandomDeath, RandomWalk


def _live_sorted(pool):
    a = np.asarray(pool.alive)
    p = np.asarray(pool.position)[a]
    o = np.lexsort(p.T)
    return p[o], np.asarray(pool.diameter)[a][o], np.asarray(pool.agent_type)[a][o]


# ---------------------------------------------------------------------------
# restage / dtype-policy building blocks
# ---------------------------------------------------------------------------

def test_grow_pool_preserves_live_prefix_and_dtypes():
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 10, (5, 3)).astype(np.float32)
    policy = DtypePolicy(aux_float="bfloat16", compact_ints=True)
    pool = make_pool(8, position=pos, diameter=np.full(5, 2.0, np.float32),
                     agent_type=np.arange(5, dtype=np.int32),
                     extra_specs={"t": ((), jnp.int32, 7)}, policy=policy)
    grown = grow_pool(pool, 32)
    assert grown.capacity == 32
    for k, v in pool.channels().items():
        g = grown.channels()[k]
        assert g.dtype == v.dtype, k
        assert np.array_equal(np.asarray(g[:8]), np.asarray(v)), k
    assert not np.asarray(grown.alive[8:]).any()
    assert int(grown.n_live) == int(pool.n_live) == 5
    # shrinking is refused, same-size is the identity
    with pytest.raises(ValueError):
        grow_channels(pool.channels(), 4)
    assert grow_pool(pool, 8) is not None


def test_grow_channels_donation_safety():
    """Explicit donate=True must produce the same values as donate=False —
    and on backends without donation support it degrades to a copy (jax
    warns 'donated buffers were not usable' on CPU; that is the expected
    degradation, not an error)."""
    import warnings
    ch = {"a": jnp.arange(12, dtype=jnp.float32).reshape(6, 2),
          "alive": jnp.asarray([True, True, False, True, False, False])}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)
        out_d = grow_channels(dict(ch), 10, donate=True)
    out_n = grow_channels(dict(ch), 10, donate=False)
    for k in ch:
        assert np.array_equal(np.asarray(out_d[k]), np.asarray(out_n[k]))
    assert out_d["a"].shape == (10, 2)
    assert not np.asarray(out_d["alive"][6:]).any()


def test_dtype_policy_shrinks_bytes_per_agent():
    base = make_pool(64, policy=DtypePolicy())
    lean = make_pool(64, policy=DtypePolicy(aux_float="bfloat16",
                                            compact_ints=True))
    nbytes = lambda p: sum(v.nbytes for v in p.channels().values())
    assert lean.diameter.dtype == jnp.bfloat16
    assert lean.agent_type.dtype == jnp.int16
    assert lean.force_nnz.dtype == jnp.int16
    assert lean.position.dtype == jnp.float32          # never narrowed
    assert lean.born_iter.dtype == jnp.int32           # iteration counter
    assert nbytes(lean) < nbytes(base)


def test_rand_rows_are_capacity_stable():
    import jax
    key = jax.random.PRNGKey(42)
    u_small = np.asarray(rand.uniform_rows(key, 50))
    u_big = np.asarray(rand.uniform_rows(key, 5000))
    assert np.array_equal(u_small, u_big[:50])
    n_small = np.asarray(rand.normal_rows(key, 50, 3))
    n_big = np.asarray(rand.normal_rows(key, 700, 3))
    assert np.array_equal(n_small, n_big[:50])
    # sanity: the streams are actually random-looking
    u = np.asarray(rand.uniform_rows(key, 20000))
    assert 0.45 < u.mean() < 0.55 and u.min() >= 0.0 and u.max() < 1.0
    z = np.asarray(rand.normal_rows(key, 20000))
    assert abs(z.mean()) < 0.05 and 0.9 < z.std() < 1.1


# ---------------------------------------------------------------------------
# overflow provenance (stats.py)
# ---------------------------------------------------------------------------

def test_overflow_provenance_demands():
    rng = np.random.default_rng(1)
    n = 48
    cfg = EngineConfig(capacity=n, domain_lo=(0, 0, 0), domain_hi=(24.0,) * 3,
                       interaction_radius=4.0, dt=1.0, max_per_box=2,
                       query_chunk=64, use_forces=False)
    sim = Simulation(cfg, [GrowDivide(rate=3.0, threshold_diameter=6.0)])
    # clustered into ~2×2×2 boxes so a z-run far exceeds run_capacity=6
    pos = rng.uniform(1, 9, (n, 3)).astype(np.float32)
    st = sim.init_state(pos, diameter=np.full(n, 5.0, np.float32))
    st = sim.step(st)            # every cell divides: 48 newborns, 0 free slots
    s = st.stats
    assert int(s["birth_overflow"]) > 0
    assert int(s["capacity_demand"]) == int(s["n_live"]) + int(s["birth_overflow"])
    # max_per_box=2 → run capacity 6; a 48-in-24³ population exceeds it
    assert int(s["box_overflow"]) == 1
    assert int(s["box_demand"]) > cfg.grid_spec.run_capacity


# ---------------------------------------------------------------------------
# the ladder itself: bit-parity vs a pre-sized pool
# ---------------------------------------------------------------------------

def _scenario():
    return [GrowDivide(rate=0.8, threshold_diameter=6.0),
            RandomWalk(sigma=0.3),
            RandomDeath(rate=0.01)]


_BASE = dict(domain_lo=(0, 0, 0), domain_hi=(96.0,) * 3,
             interaction_radius=4.0, dt=1.0, max_per_box=4, query_chunk=256,
             force=ForceParams(max_displacement=0.5))


def test_ladder_bit_parity_vs_presized():
    rng = np.random.default_rng(0)
    n0 = 64
    pos = rng.uniform(4, 92, (n0, 3)).astype(np.float32)
    dia = np.full(n0, 5.2, np.float32)

    ladder = CapacityLadder(EngineConfig(capacity=96, **_BASE), _scenario(),
                            LadderConfig(growth_factor=2.0, round_to=32))
    st = ladder.init_state(pos, diameter=dia)
    st = ladder.run(st, 9)

    fields = {r["field"] for r in ladder.rungs}
    assert "capacity" in fields, ladder.rungs
    assert ladder.recompiles == len(ladder.rungs) >= 3

    # oracle: pre-sized at the ladder's final rung, same seed state
    sim = Simulation(ladder.config, _scenario())
    st2 = sim.init_state(pos, diameter=dia)
    st2 = sim.run(st2, 9, check_overflow=True)

    assert int(st.stats["n_live"]) == int(st2.stats["n_live"]) > n0
    p1, d1, t1 = _live_sorted(st.pool)
    p2, d2, t2 = _live_sorted(st2.pool)
    assert np.array_equal(p1, p2), "positions must be bit-identical"
    assert np.array_equal(d1, d2)
    assert np.array_equal(t1, t2)


def test_ladder_box_rung_bit_parity():
    """A pure run-capacity (max_per_run) rung mid-run: forces computed at a
    wider gather width must still be bit-identical (zero lanes are exact
    additive identities in the streamed reduction)."""
    rng = np.random.default_rng(3)
    n = 256
    cfg = EngineConfig(capacity=1024, domain_lo=(0, 0, 0),
                       domain_hi=(24.0,) * 3, interaction_radius=4.0, dt=0.5,
                       max_per_box=3, query_chunk=128,
                       force=ForceParams(max_displacement=0.5))
    pos = rng.uniform(1, 23, (n, 3)).astype(np.float32)
    dia = np.full(n, 3.0, np.float32)
    ladder = CapacityLadder(cfg, [GrowDivide(rate=0.5, threshold_diameter=5.0)])
    st = ladder.run(ladder.init_state(pos, diameter=dia), 5)
    assert any(r["field"] == "max_per_run" for r in ladder.rungs), ladder.rungs

    sim = Simulation(ladder.config, [GrowDivide(rate=0.5, threshold_diameter=5.0)])
    st2 = sim.run(sim.init_state(pos, diameter=dia), 5, check_overflow=True)
    p1, d1, _ = _live_sorted(st.pool)
    p2, d2, _ = _live_sorted(st2.pool)
    assert np.array_equal(p1, p2)
    assert np.array_equal(d1, d2)


def test_ladder_max_capacity_raises():
    rng = np.random.default_rng(5)
    pos = rng.uniform(4, 92, (64, 3)).astype(np.float32)
    ladder = CapacityLadder(EngineConfig(capacity=96, **_BASE),
                            [GrowDivide(rate=2.0, threshold_diameter=6.0)],
                            LadderConfig(max_capacity=128))
    st = ladder.init_state(pos, diameter=np.full(64, 5.5, np.float32))
    with pytest.raises(RuntimeError, match="ladder exhausted"):
        ladder.run(st, 6)


def test_dtype_policy_trajectory_parity_within_tolerance():
    """bfloat16 aux channels trade precision for bytes: trajectories must
    track the float32 run closely (same counts, nearby positions) without
    being bit-equal."""
    rng = np.random.default_rng(7)
    n = 200
    pos = rng.uniform(4, 60, (n, 3)).astype(np.float32)
    dia = np.full(n, 3.0, np.float32)
    mk = lambda policy: Simulation(
        EngineConfig(capacity=512, domain_lo=(0, 0, 0), domain_hi=(64.0,) * 3,
                     interaction_radius=4.0, dt=0.5, max_per_box=16,
                     query_chunk=256, force=ForceParams(max_displacement=0.5),
                     dtypes=policy),
        [GrowDivide(rate=0.25, threshold_diameter=4.5)])
    s32 = mk(DtypePolicy())
    lean = mk(DtypePolicy(aux_float="bfloat16", compact_ints=True))
    st32 = s32.run(s32.init_state(pos, diameter=dia), 6, check_overflow=True)
    stbf = lean.run(lean.init_state(pos, diameter=dia), 6, check_overflow=True)
    assert stbf.pool.diameter.dtype == jnp.bfloat16
    n32, nbf = int(st32.stats["n_live"]), int(stbf.stats["n_live"])
    assert abs(n32 - nbf) <= 0.05 * n32, (n32, nbf)
    if n32 == nbf:
        p1, _, _ = _live_sorted(st32.pool)
        p2, _, _ = _live_sorted(stbf.pool)
        # bf16 diameters perturb forces ~1%; positions stay within ~2% of
        # the domain scale over this horizon
        assert float(np.abs(p1 - p2).max()) < 1.5


# ---------------------------------------------------------------------------
# distributed ladder: 4 shards, mid-run migration, agreed global rungs
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax.numpy as jnp
    from repro.core import (DistConfig, DistributedCapacityLadder,
                            DistributedSimulation, EngineConfig, ForceParams,
                            LadderConfig)
    from repro.core.behaviors import Behavior, BehaviorEffects, GrowDivide

    class Drift(Behavior):
        '''Deterministic +x drift: forces agents across slab boundaries.'''
        def __call__(self, ctx, pool, rng):
            step = jnp.asarray([1.0, 0.0, 0.0]) * ctx.dt
            new_pos = jnp.where(ctx.owned[:, None], pool.position + step,
                                pool.position)
            new_pos = jnp.clip(new_pos, ctx.domain_lo, ctx.domain_hi)
            return BehaviorEffects(set_channels={"position": new_pos})

    beh = lambda: [GrowDivide(rate=0.8, threshold_diameter=6.0), Drift()]
    rng = np.random.default_rng(1)
    SIDE = 64.0
    N0 = 64
    cfg = EngineConfig(capacity=N0, domain_lo=(0, 0, 0),
                       domain_hi=(SIDE,) * 3, interaction_radius=4.0, dt=1.0,
                       max_per_box=8, query_chunk=128,
                       force=ForceParams(max_displacement=0.5))
    pos = rng.uniform(2, SIDE - 2, (N0, 3)).astype(np.float32)
    dia = np.full(N0, 5.2, np.float32)

    dl = DistributedCapacityLadder(
        DistConfig(engine=cfg, n_shards=4, local_capacity=48,
                   halo_capacity=24, migrate_capacity=12,
                   rebalance_frequency=3),
        beh(), LadderConfig())
    st = dl.init_state(pos, diameter=dia)
    st = dl.run(st, 7)

    ds = DistributedSimulation(dl.dcfg, beh())
    st2 = ds.init_state(pos, diameter=dia)
    st2 = ds.run(st2, 7, check_overflow=True)

    a1 = np.asarray(st.channels["alive"]); a2 = np.asarray(st2.channels["alive"])
    p1 = np.asarray(st.channels["position"])[a1]
    p2 = np.asarray(st2.channels["position"])[a2]
    o1 = np.lexsort(p1.T); o2 = np.lexsort(p2.T)
    results = {
        "n_ladder": int(a1.sum()), "n_presized": int(a2.sum()), "n0": N0,
        "bit_exact": bool(a1.sum() == a2.sum()
                          and np.array_equal(p1[o1], p2[o2])),
        "rung_fields": sorted({r["field"] for r in dl.rungs}),
        "recompiles": dl.recompiles,
        "migrated": bool(np.asarray(st.stats["n_live"]).min() > 0),
    }
    print("RESULT " + json.dumps(results))
""")


def test_distributed_ladder_bit_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["n_ladder"] == res["n_presized"] > res["n0"], res
    assert res["bit_exact"], res
    assert "local_capacity" in res["rung_fields"], res
    assert res["recompiles"] >= 2, res
