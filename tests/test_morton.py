"""Morton space-filling curve: roundtrips + locality properties (paper §4.2)."""

import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import morton


def test_roundtrip_3d_exhaustive_small():
    g = np.arange(16, dtype=np.uint32)
    x, y, z = np.meshgrid(g, g, g, indexing="ij")
    x, y, z = (jnp.asarray(a.ravel()) for a in (x, y, z))
    c = morton.encode3(x, y, z)
    dx, dy, dz = morton.decode3(c)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(dy), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(dz), np.asarray(z))
    # bijectivity on the sample
    assert len(np.unique(np.asarray(c))) == c.shape[0]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1023), st.integers(0, 1023),
                          st.integers(0, 1023)), min_size=1, max_size=64))
def test_roundtrip_3d_property(coords):
    a = np.asarray(coords, dtype=np.uint32)
    c = morton.encode3(jnp.asarray(a[:, 0]), jnp.asarray(a[:, 1]),
                       jnp.asarray(a[:, 2]))
    dx, dy, dz = morton.decode3(c)
    np.testing.assert_array_equal(np.asarray(dx), a[:, 0])
    np.testing.assert_array_equal(np.asarray(dy), a[:, 1])
    np.testing.assert_array_equal(np.asarray(dz), a[:, 2])


@settings(max_examples=30, deadline=None)
@given(st.tuples(st.integers(0, 65535), st.integers(0, 65535)))
def test_roundtrip_2d_property(xy):
    x, y = xy
    c = morton.encode2(jnp.uint32(x), jnp.uint32(y))
    dx, dy = morton.decode2(c)
    assert int(dx) == x and int(dy) == y


def test_same_box_same_key():
    pos = jnp.asarray([[1.1, 2.2, 3.3], [1.9, 2.8, 3.9], [2.1, 2.2, 3.3]])
    keys = morton.morton_keys(pos, jnp.zeros(3), 1.0, (8, 8, 8))
    assert int(keys[0]) == int(keys[1])      # same unit box
    assert int(keys[0]) != int(keys[2])      # crossed x boundary


def test_locality_beats_rowmajor():
    """Mean |key(i) - key(j)| over 3-D-adjacent cells is smaller for Morton
    than for row-major linearization — the paper's cache-locality argument."""
    n = 32
    g = np.arange(n, dtype=np.uint32)
    x, y, z = np.meshgrid(g, g, g, indexing="ij")
    x, y, z = x.ravel(), y.ravel(), z.ravel()
    mor = np.asarray(morton.encode3(jnp.asarray(x), jnp.asarray(y), jnp.asarray(z)),
                     dtype=np.int64)
    row = (x.astype(np.int64) * n + y) * n + z
    # +x neighbors
    mask = x < n - 1
    mor_nb = np.asarray(morton.encode3(jnp.asarray(x + 1), jnp.asarray(y),
                                       jnp.asarray(z)), dtype=np.int64)
    row_nb = ((x + 1).astype(np.int64) * n + y) * n + z
    d_m = np.abs(mor_nb - mor)[mask].mean()
    d_r = np.abs(row_nb - row)[mask].mean()
    assert d_m < d_r


def test_code_space_size():
    assert morton.code_space_size((8, 8, 8)) == 512
    assert morton.code_space_size((9, 3, 3)) == 16 ** 3  # next pow2 = 16


# --- row-major linear keys (grid indexing — DESIGN.md §3) ---

def test_linear_size_exact():
    assert morton.linear_size((8, 8, 8)) == 512
    assert morton.linear_size((9, 3, 3)) == 81        # no pow2 padding
    assert morton.linear_size((33, 33, 33)) == 35937  # Fig-11 grid


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 19), st.integers(0, 7),
                          st.integers(0, 3)), min_size=1, max_size=64))
def test_linear_roundtrip_anisotropic(coords):
    dims = (20, 8, 4)
    a = np.asarray(coords, dtype=np.uint32)
    c = morton.linear_encode3(jnp.asarray(a[:, 0]), jnp.asarray(a[:, 1]),
                              jnp.asarray(a[:, 2]), dims)
    assert int(jnp.max(c)) < morton.linear_size(dims)
    dx, dy, dz = morton.linear_decode3(c, dims)
    np.testing.assert_array_equal(np.asarray(dx), a[:, 0])
    np.testing.assert_array_equal(np.asarray(dy), a[:, 1])
    np.testing.assert_array_equal(np.asarray(dz), a[:, 2])


def test_linear_z_runs_contiguous():
    """The property grid queries rely on: the 3 stencil boxes (x, y, z-1..z+1)
    have adjacent linear ids, so each (dx, dy) column is one key range."""
    dims = (5, 7, 9)
    x, y, z = jnp.uint32(3), jnp.uint32(2), jnp.uint32(4)
    c0 = morton.linear_encode3(x, y, z - 1, dims)
    c1 = morton.linear_encode3(x, y, z, dims)
    c2 = morton.linear_encode3(x, y, z + 1, dims)
    assert int(c1) == int(c0) + 1 and int(c2) == int(c1) + 1
