"""Train substrate + paged-KV serving substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.data import DataConfig, batch_at
from repro.models import build_model, reduced_config
from repro.serve import kv_cache as kvc
from repro.train import (AdamWConfig, checkpoint, init_state, make_train_step,
                         schedule)


# ---------------------------------------------------------------------------
# optimizer / train step
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=300, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_state(cfg, params)
    from repro.train.optimizer import apply_updates
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(cfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) <= 0.11


def test_train_loss_decreases_small_lm():
    cfg = reduced_config(ARCHS["qwen2-1.5b"])
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    opt = init_state(ocfg, params)
    step = jax.jit(make_train_step(m, ocfg))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch = batch_at(dcfg, 0)
    losses = []
    for i in range(30):
        params, opt, metrics = step(params, opt, batch)  # overfit one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    """grad accumulation (n micro) == single batch step, same params out."""
    cfg = reduced_config(ARCHS["yi-6b"])
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = batch_at(dcfg, 0)
    p1, _, m1 = jax.jit(make_train_step(m, ocfg, n_microbatches=1))(
        params, init_state(ocfg, params), batch)
    p4, _, m4 = jax.jit(make_train_step(m, ocfg, n_microbatches=4))(
        params, init_state(ocfg, params), batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert err < 5e-5, err


# ---------------------------------------------------------------------------
# checkpointing (fault tolerance / elasticity)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": jnp.asarray(3.5, jnp.bfloat16)}}
    checkpoint.save(str(tmp_path), 7, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = checkpoint.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_gc(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        ck.save_async(s, tree)
    ck.wait()
    steps = checkpoint.list_steps(str(tmp_path))
    assert steps == [3, 4]              # older checkpoints gc'd
    assert checkpoint.latest_step(str(tmp_path)) == 4


def test_checkpoint_structure_mismatch_raises(tmp_path):
    checkpoint.save(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 1, {"zzz": jnp.ones(3)})


# ---------------------------------------------------------------------------
# paged KV cache (paper §4.3 pool allocator transfer)
# ---------------------------------------------------------------------------

SPEC = kvc.PagedCacheSpec(n_layers=2, n_kv_heads=2, d_head=8, page_size=4,
                          n_pages=32, max_seqs=4, max_pages_per_seq=8,
                          dtype="float32")


def test_admit_append_gather_roundtrip(rng):
    st = kvc.init_cache(SPEC)
    st, ok = kvc.admit_sequence(SPEC, st, jnp.int32(0), jnp.int32(0))
    assert bool(ok)
    ks, vs = [], []
    for t in range(10):
        k = jnp.asarray(rng.standard_normal((2, 4, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 4, 2, 8)), jnp.float32)
        st, wrote = kvc.append_token(SPEC, st, k, v)
        assert bool(wrote[0])
        ks.append(np.asarray(k[:, 0]))
        vs.append(np.asarray(v[:, 0]))
    for layer in range(2):
        k, v, valid = kvc.gather_kv(SPEC, st, jnp.int32(layer), jnp.int32(0),
                                    s_max=16)
        assert int(valid.sum()) == 10
        got = np.asarray(k[:10])
        exp = np.stack([x[layer] for x in ks])
        np.testing.assert_allclose(got, exp, atol=1e-6)


def test_release_returns_pages():
    st = kvc.init_cache(SPEC)
    st, ok = kvc.admit_sequence(SPEC, st, jnp.int32(1), jnp.int32(9))
    assert bool(ok)
    assert int(st.n_free) == 32 - 3      # ceil(9/4) = 3 pages
    st = kvc.release_sequence(SPEC, st, jnp.int32(1))
    assert int(st.n_free) == 32
    assert not bool(st.seq_active[1])
    # every page id is back exactly once (allocator invariant)
    assert sorted(np.asarray(st.free_stack).tolist()) == list(range(32))


def test_pool_exhaustion_blocks_admission():
    spec = kvc.PagedCacheSpec(n_layers=1, n_kv_heads=1, d_head=4, page_size=4,
                              n_pages=4, max_seqs=4, max_pages_per_seq=4,
                              dtype="float32")
    st = kvc.init_cache(spec)
    st, ok1 = kvc.admit_sequence(spec, st, jnp.int32(0), jnp.int32(16))
    assert bool(ok1)
    st, ok2 = kvc.admit_sequence(spec, st, jnp.int32(1), jnp.int32(4))
    assert not bool(ok2)                 # pool exhausted → graceful refusal
    st = kvc.release_sequence(spec, st, jnp.int32(0))
    st, ok3 = kvc.admit_sequence(spec, st, jnp.int32(1), jnp.int32(4))
    assert bool(ok3)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 3),
                          st.integers(1, 20)), min_size=1, max_size=30))
def test_allocator_never_leaks_property(ops):
    """Property (paper allocator invariant): pages held + pages free == pool,
    under any admit/release interleaving."""
    st_ = kvc.init_cache(SPEC)
    for is_admit, slot, plen in ops:
        if is_admit:
            st_, _ = kvc.admit_sequence(SPEC, st_, jnp.int32(slot),
                                        jnp.int32(plen))
        else:
            st_ = kvc.release_sequence(SPEC, st_, jnp.int32(slot))
        held = int((np.asarray(st_.block_table) >= 0).sum())
        assert held + int(st_.n_free) == SPEC.n_pages
