"""SIR parameter sweep on the ensemble engine (DESIGN.md §8).

One vmapped iteration core advances every sweep member in lockstep: N lanes,
each a small SIR world with its own (beta, gamma) drawn from a grid, served
through the continuous-batching SimService — more parameter points than
lanes, so lanes retire and re-admit as members finish. Prints the aggregate
epidemic-size surface over the (beta, gamma) grid.

    PYTHONPATH=src python examples/ensemble_sweep.py

Environment knobs (CI smoke caps size):
    EXAMPLE_N       agents per lane        (default 400)
    EXAMPLE_LANES   ensemble lanes         (default 8)
    EXAMPLE_POINTS  sweep points           (default 16)
    EXAMPLE_STEPS   per-member step budget (default 120)
"""

import os

import numpy as np
import jax.numpy as jnp

from repro.core import EngineConfig, ScenarioParams
from repro.core.behaviors import (INFECTED, Infection, RandomWalk,
                                  SUSCEPTIBLE)
from repro.serve import SimRequest, SimService

N_AGENTS = int(os.environ.get("EXAMPLE_N", 400))
N_LANES = int(os.environ.get("EXAMPLE_LANES", 8))
N_POINTS = int(os.environ.get("EXAMPLE_POINTS", 16))
MAX_STEPS = int(os.environ.get("EXAMPLE_STEPS", 120))
SIDE = max(30.0, (N_AGENTS ** (1 / 3)) * 4.2)


def make_service() -> SimService:
    # sweep regime: comparison sort — the counting sort's scatter passes
    # batch poorly under the lane axis on XLA:CPU (benchmarks/ensemble.py)
    cfg = EngineConfig(capacity=-(-N_AGENTS // 64) * 64,
                       domain_lo=(0, 0, 0), domain_hi=(SIDE,) * 3,
                       interaction_radius=3.0, use_forces=False,
                       query_chunk=2048, max_per_box=32,
                       sort_impl="argsort")
    behaviors = [
        RandomWalk(sigma=0.8),
        # per-lane rates flow through ScenarioParams → ctx.params: one
        # compiled program serves every (beta, gamma) point
        Infection(radius=3.0, beta=lambda ctx: ctx.params["beta"],
                  recovery_time=lambda ctx: ctx.params["recovery_time"]),
    ]

    def infected(pool, params):
        return jnp.sum((pool.agent_type == INFECTED) & pool.alive)

    return SimService(cfg, behaviors, n_lanes=N_LANES,
                      params_template=ScenarioParams.of(beta=0.0,
                                                        recovery_time=1),
                      metrics_fn=infected,
                      converged_fn=lambda m: int(m) == 0)


def make_request(uid: int, beta: float, recovery_time: int) -> SimRequest:
    r = np.random.RandomState(7000 + uid)
    pos = r.uniform(0, SIDE, (N_AGENTS, 3)).astype(np.float32)
    types = np.zeros(N_AGENTS, np.int32)
    n0 = max(N_AGENTS // 50, 2)
    types[:n0] = INFECTED
    timer = np.zeros(N_AGENTS, np.int32)
    timer[:n0] = recovery_time
    return SimRequest(uid=uid, position=pos,
                      diameter=np.full(N_AGENTS, 1.0, np.float32),
                      agent_type=types,
                      extra_init={"infect_timer": timer}, seed=uid,
                      params=ScenarioParams.of(beta=beta,
                                               recovery_time=recovery_time),
                      max_steps=MAX_STEPS)


def main():
    # (beta, gamma) grid: gamma realized as integer recovery_time = 1/gamma
    n_beta = max(int(np.sqrt(N_POINTS)), 2)
    n_rec = -(-N_POINTS // n_beta)
    betas = np.linspace(0.1, 0.6, n_beta)
    recoveries = np.unique(np.linspace(10, 60, n_rec).astype(int))
    points = [(float(b), int(rt)) for rt in recoveries for b in betas]

    svc = make_service()
    for uid, (beta, rt) in enumerate(points):
        svc.submit(make_request(uid, beta, rt))
    print(f"sweep: {len(points)} members ({n_beta} beta × {len(recoveries)} "
          f"recovery), {N_LANES} lanes, {N_AGENTS} agents/lane")

    ticks = svc.run_until_drained()
    assert len(svc.finished) == len(points)

    print(f"drained in {ticks} ticks "
          f"(vs {sum(f.steps for f in svc.finished)} sequential steps)")
    print(f"{'beta':>6} {'1/gamma':>8} {'steps':>6} {'reason':>10} "
          f"{'peak_I':>7} {'attack_rate':>12}")
    attack = {}
    for f in sorted(svc.finished, key=lambda f: f.uid):
        beta, rt = points[f.uid]
        t = np.asarray(f.final.pool.agent_type)[np.asarray(f.final.pool.alive)]
        rate = float((t != SUSCEPTIBLE).sum()) / max(len(t), 1)
        peak = max(int(np.asarray(m)) for m in f.trajectory)
        attack[(beta, rt)] = rate
        print(f"{beta:6.2f} {rt:8d} {f.steps:6d} {f.reason:>10} "
              f"{peak:7d} {rate:12.3f}")

    # aggregate trajectory sanity: infectivity must matter — the most
    # aggressive corner of the sweep infects more than the mildest
    lo = attack[(float(betas[0]), int(recoveries[0]))]
    hi = attack[(float(betas[-1]), int(recoveries[-1]))]
    assert hi >= lo, f"attack rate not increasing with (beta, 1/gamma): " \
                     f"{lo:.3f} -> {hi:.3f}"
    assert hi > 0, "no epidemic anywhere in the sweep"
    print(f"OK: attack rate {lo:.3f} (mild corner) -> {hi:.3f} "
          f"(aggressive corner) over {len(points)} members")


if __name__ == "__main__":
    main()
