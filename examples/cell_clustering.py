"""Cell clustering (paper Table 1): chemotaxis toward self-secreted substance.

Agents secrete a diffusing chemoattractant and climb its gradient — the
engine's diffusion substrate + behavior composition. Mean pairwise distance
shrinks as clusters form.

    PYTHONPATH=src python examples/cell_clustering.py

``--pairlist`` adds contact mechanics (cells resist overlap as clusters
densify) served from the Verlet pair-list cache (DESIGN.md §3.4): the grid
rebuild is amortized every-k steps and the force sweep runs over the pruned
in-range(+skin) pair table, reused while no agent moves farther than
``--skin``/2. Each epoch prints the realized listed pairs per agent.
"""

import argparse
import os

import numpy as np

from repro.core import (EngineConfig, ForceParams, PairListConfig,
                        RebuildPolicy, Simulation)
from repro.core.behaviors import Chemotaxis, Secretion
from repro.core.diffusion import DiffusionSpec


def mean_pairwise(p, k=512):
    idx = np.random.default_rng(0).choice(len(p), size=min(k, len(p)), replace=False)
    q = p[idx]
    d = np.sqrt(((q[:, None] - q[None]) ** 2).sum(-1))
    return d[np.triu_indices(len(q), 1)].mean()


N_AGENTS = int(os.environ.get("EXAMPLE_N", 4_000))     # CI smoke caps size
SIDE = 64.0


def make_config(pairlist: bool = False, skin: float = 1.5) -> EngineConfig:
    extra = dict(use_forces=False)
    if pairlist:
        extra = dict(
            use_forces=True,
            # cap the per-step contact resolution so motion stays inside the
            # skin budget (reuse requires max step distance <= skin/2)
            force=ForceParams(max_displacement=0.25),
            rebuild=RebuildPolicy(mode="every_k", k=8,
                                  displacement_bound=skin / 2),
            pairlist=PairListConfig(skin=skin, max_pairs=64))
    return EngineConfig(
        capacity=N_AGENTS, domain_lo=(0, 0, 0), domain_hi=(SIDE,) * 3,
        interaction_radius=3.0, query_chunk=4096,
        diffusion=DiffusionSpec(dims=(32, 32, 32), coefficient=0.5,
                                decay=0.01, voxel=2.0), **extra)


def behaviors():
    return [Secretion(rate=2.0), Chemotaxis(speed=0.35)]


def pairs_per_agent(state) -> float:
    """Mean listed in-range(+skin) candidates per live agent — resident
    rows of the cached pair table, averaged over the live mask."""
    alive = np.asarray(state.pool.alive)
    count = np.asarray(state.env.pairs.count)
    n_live = max(int(alive.sum()), 1)
    return float(count[alive].sum()) / n_live


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pairlist", action="store_true",
                    help="contact forces via the Verlet pair-list cache")
    ap.add_argument("--skin", type=float, default=1.5,
                    help="pair-list skin (reuse while motion <= skin/2)")
    args = ap.parse_args()
    rng = np.random.default_rng(4)
    n = N_AGENTS
    epochs = int(os.environ.get("EXAMPLE_EPOCHS", 6))
    side = SIDE
    sim = Simulation(make_config(args.pairlist, args.skin), behaviors())
    pos = rng.uniform(4, side - 4, (n, 3)).astype(np.float32)
    dia = 2.0 if args.pairlist else 1.0
    state = sim.init_state(pos, diameter=np.full(n, dia, np.float32))
    p0 = np.asarray(state.pool.position[:n])
    print(f"initial mean pairwise distance: {mean_pairwise(p0):.2f}")
    for epoch in range(epochs):
        if args.pairlist:
            skips = 0
            for _ in range(10):
                state = sim.run(state, 1, check_overflow=True)
                skips += int(state.stats.rebuild_skips)
            pl = (f"  pairs/agent {pairs_per_agent(state):.1f}"
                  f"  reused {skips}/10 steps")
        else:
            state = sim.run(state, 10, check_overflow=True)
            pl = ""
        p = np.asarray(state.pool.position[:n])
        print(f"iter {int(state.iteration):3d}: mean pairwise "
              f"{mean_pairwise(p):.2f}  substance max "
              f"{float(state.conc.max()):.1f}{pl}")
    assert mean_pairwise(np.asarray(state.pool.position[:n])) < mean_pairwise(p0)
    print("OK: clusters formed")


if __name__ == "__main__":
    main()
