"""Cell clustering (paper Table 1): chemotaxis toward self-secreted substance.

Agents secrete a diffusing chemoattractant and climb its gradient — the
engine's diffusion substrate + behavior composition. Mean pairwise distance
shrinks as clusters form.

    PYTHONPATH=src python examples/cell_clustering.py
"""

import os

import numpy as np

from repro.core import EngineConfig, Simulation
from repro.core.behaviors import Chemotaxis, Secretion
from repro.core.diffusion import DiffusionSpec


def mean_pairwise(p, k=512):
    idx = np.random.default_rng(0).choice(len(p), size=min(k, len(p)), replace=False)
    q = p[idx]
    d = np.sqrt(((q[:, None] - q[None]) ** 2).sum(-1))
    return d[np.triu_indices(len(q), 1)].mean()


N_AGENTS = int(os.environ.get("EXAMPLE_N", 4_000))     # CI smoke caps size
SIDE = 64.0


def make_config() -> EngineConfig:
    return EngineConfig(
        capacity=N_AGENTS, domain_lo=(0, 0, 0), domain_hi=(SIDE,) * 3,
        interaction_radius=3.0, use_forces=False, query_chunk=4096,
        diffusion=DiffusionSpec(dims=(32, 32, 32), coefficient=0.5,
                                decay=0.01, voxel=2.0))


def behaviors():
    return [Secretion(rate=2.0), Chemotaxis(speed=0.35)]


def main():
    rng = np.random.default_rng(4)
    n = N_AGENTS
    epochs = int(os.environ.get("EXAMPLE_EPOCHS", 6))
    side = SIDE
    sim = Simulation(make_config(), behaviors())
    pos = rng.uniform(4, side - 4, (n, 3)).astype(np.float32)
    state = sim.init_state(pos, diameter=np.full(n, 1.0, np.float32))
    p0 = np.asarray(state.pool.position[:n])
    print(f"initial mean pairwise distance: {mean_pairwise(p0):.2f}")
    for epoch in range(epochs):
        state = sim.run(state, 10, check_overflow=True)
        p = np.asarray(state.pool.position[:n])
        print(f"iter {int(state.iteration):3d}: mean pairwise "
              f"{mean_pairwise(p):.2f}  substance max {float(state.conc.max()):.1f}")
    assert mean_pairwise(np.asarray(state.pool.position[:n])) < mean_pairwise(p0)
    print("OK: clusters formed")


if __name__ == "__main__":
    main()
