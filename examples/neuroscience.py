"""Neuroscience (paper Table 1 + §5): neurite growth with static regions.

Growth cones extend and bifurcate, depositing a trail of segments. The
static-region detection mechanism (paper §5) progressively freezes the trail
so force computation tracks only the active front — watch n_active stay far
below n_live (the paper's 9.22× speedup mechanism).

    PYTHONPATH=src python examples/neuroscience.py
"""

import os

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import NeuriteGrowth, GROWTH_CONE


def make_config() -> EngineConfig:
    return EngineConfig(capacity=16384, domain_lo=(0, 0, 0),
                        domain_hi=(120, 120, 120), interaction_radius=4.0,
                        dt=0.5, detect_static=True, sort_frequency=20,
                        max_per_box=64,
                        force=ForceParams(max_displacement=0.2, move_eps=1e-4))


def behaviors():
    return [NeuriteGrowth(speed=0.8, noise=0.2,
                          bifurcation_prob=0.01,
                          segment_every=2.0)]


def main():
    rng = np.random.default_rng(2)
    n_cones = 64
    sim = Simulation(make_config(), behaviors())
    pos = rng.uniform(55, 65, (n_cones, 3)).astype(np.float32)
    d0 = rng.standard_normal((n_cones, 3)).astype(np.float32)
    d0 /= np.linalg.norm(d0, axis=1, keepdims=True)
    state = sim.init_state(pos, diameter=np.full(n_cones, 2.0, np.float32),
                           agent_type=np.full(n_cones, GROWTH_CONE, np.int32),
                           extra_init={"direction": d0})
    epochs = int(os.environ.get("EXAMPLE_EPOCHS", 10))
    print(f"{'iter':>5} {'n_live':>7} {'n_active':>9} {'active%':>8}")
    for epoch in range(epochs):
        state = sim.run(state, 10, check_overflow=True)
        live = int(state.stats["n_live"])
        act = int(state.stats["n_active"])
        print(f"{int(state.iteration):5d} {live:7d} {act:9d} {act / max(live,1):8.1%}")
    live, act = int(state.stats["n_live"]), int(state.stats["n_active"])
    assert live > n_cones * 5, "neurites should have grown"
    assert act < live, "trail should be static (paper §5)"
    print("OK: active growth front << total agents")


if __name__ == "__main__":
    main()
