"""Oncology (paper Table 1): tumor spheroid growth with cell death.

Cells divide under mechanical constraints and die stochastically; deaths
exercise the parallel-removal path (paper §3.2; Fig 9 notes a 31.7% gain for
this use case). Prints population dynamics.

    PYTHONPATH=src python examples/oncology.py
"""

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import GrowDivide, RandomDeath, RandomWalk


def main():
    rng = np.random.default_rng(3)
    cfg = EngineConfig(capacity=16384, domain_lo=(0, 0, 0),
                       domain_hi=(160, 160, 160), interaction_radius=14.0,
                       dt=0.2, sort_frequency=10, max_per_box=160,
                       force=ForceParams(max_displacement=1.0))
    sim = Simulation(cfg, [GrowDivide(rate=0.7, threshold_diameter=12.0),
                           RandomWalk(sigma=0.1),
                           RandomDeath(rate=0.012)])
    pos = rng.uniform(55, 105, (256, 3)).astype(np.float32)
    state = sim.init_state(pos, diameter=np.full(256, 9.0, np.float32))
    print(f"{'iter':>5} {'n_live':>7} {'births':>7} {'deaths':>7}")
    for epoch in range(6):
        state = sim.run(state, 10, check_overflow=True)
        print(f"{int(state.iteration):5d} {int(state.stats['n_live']):7d} "
              f"{int(state.stats['births']):7d} {int(state.stats['deaths']):7d}")
    alive = np.asarray(state.pool.alive)
    n = int(state.stats["n_live"])
    assert alive[:n].all() and not alive[n:].any(), "compaction invariant"
    print("OK: tumor grew with concurrent birth/death churn")


if __name__ == "__main__":
    main()
