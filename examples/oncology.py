"""Oncology (paper Table 1): tumor spheroid growth with cell death.

Cells divide under mechanical constraints and die stochastically; deaths
exercise the parallel-removal path (paper §3.2; Fig 9 notes a 31.7% gain for
this use case). This example runs on the **capacity ladder** (DESIGN.md
§4.3): the pool starts at the seed size and every capacity (pool slots, grid
run width) grows automatically — geometrically, with a rewound re-run of the
overflowing step — when the population outgrows it, so no capacity number in
this file was tuned to the scenario.

    PYTHONPATH=src python examples/oncology.py
"""

import os

import numpy as np

from repro.core import CapacityLadder, EngineConfig, ForceParams

from repro.core.behaviors import GrowDivide, RandomDeath, RandomWalk


N_SEED = 256


def make_config() -> EngineConfig:
    return EngineConfig(capacity=N_SEED,         # seed-sized: the ladder grows it
                        domain_lo=(0, 0, 0),
                        domain_hi=(160, 160, 160), interaction_radius=14.0,
                        dt=0.2, sort_frequency=10, max_per_box=160,
                        force=ForceParams(max_displacement=1.0))


def behaviors():
    return [GrowDivide(rate=0.7, threshold_diameter=12.0),
            RandomWalk(sigma=0.1),
            RandomDeath(rate=0.012)]


def main():
    rng = np.random.default_rng(3)
    n_seed = N_SEED
    ladder = CapacityLadder(make_config(), behaviors())
    pos = rng.uniform(55, 105, (n_seed, 3)).astype(np.float32)
    state = ladder.init_state(pos, diameter=np.full(n_seed, 9.0, np.float32))
    print(f"{'iter':>5} {'n_live':>7} {'births':>7} {'deaths':>7} {'capacity':>9}")
    for epoch in range(int(os.environ.get("EXAMPLE_EPOCHS", 6))):
        state = ladder.run(state, 10)
        print(f"{int(state.iteration):5d} {int(state.stats['n_live']):7d} "
              f"{int(state.stats['births']):7d} "
              f"{int(state.stats['deaths']):7d} "
              f"{ladder.config.capacity:9d}")
    alive = np.asarray(state.pool.alive)
    n = int(state.stats["n_live"])
    assert alive[:n].all() and not alive[n:].any(), "compaction invariant"
    if int(state.iteration) >= 30:     # first division needs ~22 steps
        assert ladder.rungs, \
            "seed-sized pool should have forced at least one rung"
    print(f"rung schedule: {ladder.rungs}")
    print("OK: tumor grew with concurrent birth/death churn "
          f"({ladder.recompiles} automatic capacity recompiles)")

    # --- checkpoint / resume (DESIGN.md §7.5) -------------------------------
    # A long ladder run survives a process kill: checkpoint the complete run
    # state (pool, RNG, rung knobs, step index), then resume elsewhere —
    # bit-exact with never having stopped. Here: save, "crash", restore into
    # a fresh ladder, and verify 10 more steps match the uninterrupted run.
    import tempfile

    from repro.core import Simulation, restore_state, save_state

    ckpt_dir = tempfile.mkdtemp(prefix="oncology_ckpt_")
    save_state(ckpt_dir, state, ladder.config)
    oracle = ladder.run(state, 10)                 # uninterrupted
    resumed_state, resumed_cfg = restore_state(ckpt_dir, make_config(),
                                               behaviors())
    resumed = CapacityLadder(resumed_cfg, behaviors()).run(resumed_state, 10)
    assert np.array_equal(np.asarray(oracle.pool.position),
                          np.asarray(resumed.pool.position)), \
        "resumed trajectory must be bit-exact"
    print(f"OK: resumed from {ckpt_dir} at iteration "
          f"{int(resumed.iteration) - 10}, 10 post-resume steps bit-exact")


if __name__ == "__main__":
    main()
