"""End-to-end LM training driver: data pipeline → sharded train loop →
async checkpoints → resume.

Presets:
  smoke (default) ~7M params, 60 steps  — minutes on one CPU core.
  100m            ~100M params, 300 steps — the assignment's end-to-end size;
                  sized for real hardware (hours on 1 CPU core).

Demonstrates fault tolerance: run it, kill it mid-way, run again — it resumes
from the latest checkpoint and repeats no data.

    PYTHONPATH=src python examples/train_lm.py [smoke|100m] [--ckpt DIR]
"""

import argparse
import dataclasses
import sys

from repro.configs import ARCHS
from repro.launch.train import TrainJob, run
from repro.models import build_model


def make_arch(preset: str):
    base = ARCHS["qwen2-1.5b"]
    if preset == "smoke":
        return dataclasses.replace(
            base, name="qwen2-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, d_head=32, d_ff=512, vocab_size=8192,
            param_dtype="float32", activation_dtype="float32", remat="none")
    # ~100M: tied embeddings 50k x 640 = 32M + 10 blocks x ~6.5M
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=2, d_head=64, d_ff=2560, vocab_size=50304,
        param_dtype="float32", activation_dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("preset", nargs="?", default="smoke",
                    choices=["smoke", "100m"])
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    arch = make_arch(args.preset)
    n = build_model(arch).n_params()
    print(f"[train_lm] arch={arch.name} params={n:,}")
    steps = args.steps or (60 if args.preset == "smoke" else 300)
    job = TrainJob(arch=arch, steps=steps,
                   seq_len=256 if args.preset == "smoke" else 512,
                   global_batch=8, lr=1e-3, warmup=10,
                   ckpt_dir=args.ckpt, ckpt_every=20, log_every=5)
    out = run(job)
    print(f"[train_lm] loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
