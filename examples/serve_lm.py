"""Batched serving with continuous batching + the paged KV pool (paper §4.3).

A small LM serves a queue of requests through fixed decode slots; finished
sequences release their pages back to the pool and queued requests are
admitted — the paper's parallel add/remove (§3.2) as admission control.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import ContinuousBatcher, Request
from repro.serve import kv_cache as kvc


def main():
    arch = dataclasses.replace(
        ARCHS["qwen2-1.5b"], name="qwen2-serve", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=512, vocab_size=8192,
        param_dtype="float32", activation_dtype="float32", remat="none")
    model = build_model(arch)
    params = model.init_params(jax.random.PRNGKey(0))
    s_max = 128

    spec = kvc.PagedCacheSpec(
        n_layers=arch.n_layers, n_kv_heads=arch.n_kv_heads,
        d_head=arch.d_head, page_size=16, n_pages=96, max_seqs=4,
        max_pages_per_seq=s_max // 16, dtype="float32")

    # dense decode caches per slot (model side); the paged pool manages
    # admission/lengths (allocator side)
    caches = model.init_decode_caches(spec.max_seqs, s_max)
    lens = np.zeros(spec.max_seqs, np.int64)

    def prefill_fn(prompt, slot, batcher):
        # write the prompt into this slot's dense cache via decode steps
        nonlocal caches, lens
        tok = None
        for t, p in enumerate(prompt):
            one = jnp.full((spec.max_seqs,), int(p), jnp.int32)
            logits, caches = model.decode_step(params, one, caches,
                                               jnp.int32(int(lens[slot])))
            lens[slot] += 1
            tok = int(jnp.argmax(logits[slot]))
        return None, tok

    decode_calls = {"n": 0}

    def decode_fn(p, tokens, pool_state, active):
        nonlocal caches, lens
        decode_calls["n"] += 1
        logits, caches = model.decode_step(p, tokens, caches,
                                           jnp.int32(int(lens.max())))
        lens[np.asarray(active)] += 1
        nxt = jnp.argmax(logits, axis=-1)
        # keep the paged pool in lock-step (admission control ground truth)
        knew = jnp.zeros((spec.n_layers, spec.max_seqs, spec.n_kv_heads,
                          spec.d_head), jnp.float32)
        pool_state2, _ = kvc.append_token(spec, batcher.state, knew, knew)
        batcher.state = pool_state2
        return nxt, pool_state2

    batcher = ContinuousBatcher(spec, prefill_fn, decode_fn, eos_token=0)
    rng = np.random.default_rng(0)
    for uid in range(10):
        prompt = rng.integers(2, 8192, size=rng.integers(4, 12)).astype(np.int32)
        batcher.submit(Request(uid=uid, prompt=prompt, max_new_tokens=12))

    batcher.run_until_drained(params, max_steps=500)
    done = sorted(f.uid for f in batcher.finished)
    print(f"finished {len(done)} requests: uids={done}")
    print(f"decode engine iterations: {decode_calls['n']} "
          f"(continuous batching packs multiple requests per iteration)")
    assert done == list(range(10))
    assert int(batcher.state.n_free) == spec.n_pages, "all pages returned"
    print("OK: continuous batching drained the queue; pool leaked nothing")


if __name__ == "__main__":
    main()
