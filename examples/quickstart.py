"""Quickstart: cell proliferation (the paper's first benchmark simulation).

A cluster of cells grows and divides under mechanical collision forces.
Runs in ~1 min on one CPU core.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

import numpy as np

from repro.core import EngineConfig, ForceParams, Simulation
from repro.core.behaviors import GrowDivide


def make_config() -> EngineConfig:
    return EngineConfig(
        capacity=32768,
        domain_lo=(0, 0, 0), domain_hi=(120, 120, 120),
        interaction_radius=14.0,
        dt=0.2,
        sort_frequency=10,              # paper §4.2 memory-layout optimization
        max_per_box=64,
        force=ForceParams(max_displacement=1.0),
    )


def behaviors():
    return [GrowDivide(rate=1.0, threshold_diameter=12.0)]


def main():
    rng = np.random.default_rng(0)
    sim = Simulation(make_config(), behaviors())
    pos = rng.uniform(50, 70, (128, 3)).astype(np.float32)
    state = sim.init_state(pos, diameter=np.full(128, 8.0, np.float32))

    for epoch in range(int(os.environ.get("EXAMPLE_EPOCHS", 6))):
        state = sim.run(state, 10, check_overflow=True)
        print(f"iter {int(state.iteration):3d}: n_live={int(state.stats['n_live']):5d} "
              f"births={int(state.stats['births'])}")
    assert int(state.stats["n_live"]) > 128
    print("OK: population grew under mechanical constraints")


if __name__ == "__main__":
    main()
