"""Epidemiology (paper Table 1): spatial SIR with random agent movement.

Prints the classic SIR curves. Demonstrates: neighbor-radius infection via
the uniform grid, no mechanical forces, random walk movement.

    PYTHONPATH=src python examples/epidemiology.py

Running distributed
-------------------
The same scenario runs sharded over devices without touching the model:
every slab executes the shared iteration core (DESIGN.md §7), so behaviors,
births/deaths and the infection state cross slab boundaries automatically.
On a CPU-only machine, fake 4 devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/epidemiology.py --distributed
"""

import os
import sys

import numpy as np

from repro.core import DistConfig, DistributedSimulation, EngineConfig, Simulation
from repro.core.behaviors import (Infection, RandomWalk, INFECTED,
                                  RECOVERED, SUSCEPTIBLE)

N_AGENTS = int(os.environ.get("EXAMPLE_N", 20_000))   # CI smoke caps size
EPOCHS = int(os.environ.get("EXAMPLE_EPOCHS", 10))
SIDE = 140.0


def make_config() -> EngineConfig:
    return EngineConfig(capacity=N_AGENTS, domain_lo=(0, 0, 0),
                        domain_hi=(SIDE,) * 3, interaction_radius=3.0,
                        use_forces=False, query_chunk=4096, max_per_box=32)


def behaviors():
    return [RandomWalk(sigma=0.8),
            Infection(radius=3.0, beta=0.25, recovery_time=40)]


def initial_population(rng):
    pos = rng.uniform(0, SIDE, (N_AGENTS, 3)).astype(np.float32)
    types = np.zeros(N_AGENTS, np.int32)
    types[:20] = INFECTED
    return pos, types


def report(iteration, agent_type, alive):
    t = np.asarray(agent_type)[np.asarray(alive)]
    print(f"{int(iteration):5d} {(t == SUSCEPTIBLE).sum():7d} "
          f"{(t == INFECTED).sum():7d} {(t == RECOVERED).sum():7d}")
    return t


def main():
    rng = np.random.default_rng(1)
    pos, types = initial_population(rng)
    sim = Simulation(make_config(), behaviors())
    state = sim.init_state(pos, diameter=np.full(N_AGENTS, 1.0, np.float32),
                           agent_type=types,
                           extra_init={"infect_timer": np.full(N_AGENTS, 40,
                                                               np.int32)})
    print(f"{'iter':>5} {'S':>7} {'I':>7} {'R':>7}")
    for epoch in range(EPOCHS):
        state = sim.run(state, 20, check_overflow=True)
        t = report(state.iteration, state.pool.agent_type, state.pool.alive)
    assert (t != SUSCEPTIBLE).sum() > 20, "epidemic should have spread"
    print("OK: epidemic spread and recovered")


def main_distributed(n_shards: int = 4):
    """The "running distributed" path: same config + behaviors, quantile
    x-slabs with in-loop rebalance; RandomWalk draws differ per shard, so
    curves are statistically (not bitwise) equal to the single-device run."""
    import jax
    if len(jax.devices()) < n_shards:
        raise SystemExit(
            f"need {n_shards} devices — run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    rng = np.random.default_rng(1)
    pos, types = initial_population(rng)
    local_capacity = 2 * N_AGENTS // n_shards
    dcfg = DistConfig(engine=make_config(), n_shards=n_shards,
                      local_capacity=local_capacity,
                      halo_capacity=min(4096, local_capacity),
                      migrate_capacity=min(2048, local_capacity),
                      rebalance_frequency=10)
    dsim = DistributedSimulation(dcfg, behaviors())
    state = dsim.init_state(pos, diameter=np.full(N_AGENTS, 1.0, np.float32),
                            agent_type=types,
                            extra_init={"infect_timer": np.full(N_AGENTS, 40,
                                                                np.int32)})
    print(f"{'iter':>5} {'S':>7} {'I':>7} {'R':>7}   (over {n_shards} shards)")
    for epoch in range(EPOCHS):
        state = dsim.run(state, 20, check_overflow=True)
        t = report(state.iteration, state.channels["agent_type"],
                   state.channels["alive"])
        print(f"      per-shard live: "
              f"{np.asarray(state.stats['n_live']).tolist()}")
    assert (t != SUSCEPTIBLE).sum() > 20, "epidemic should have spread"
    print("OK: epidemic spread and recovered (distributed)")


if __name__ == "__main__":
    if "--distributed" in sys.argv:
        main_distributed()
    else:
        main()
