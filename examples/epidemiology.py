"""Epidemiology (paper Table 1): spatial SIR with random agent movement.

Prints the classic SIR curves. Demonstrates: neighbor-radius infection via
the uniform grid, no mechanical forces, random walk movement.

    PYTHONPATH=src python examples/epidemiology.py
"""

import numpy as np

from repro.core import EngineConfig, Simulation
from repro.core.behaviors import (Infection, RandomWalk, INFECTED,
                                  RECOVERED, SUSCEPTIBLE)


def main():
    rng = np.random.default_rng(1)
    n = 20_000
    side = 140.0
    cfg = EngineConfig(capacity=n, domain_lo=(0, 0, 0),
                       domain_hi=(side,) * 3, interaction_radius=3.0,
                       use_forces=False, query_chunk=4096, max_per_box=32)
    sim = Simulation(cfg, [RandomWalk(sigma=0.8),
                           Infection(radius=3.0, beta=0.25, recovery_time=40)])
    pos = rng.uniform(0, side, (n, 3)).astype(np.float32)
    types = np.zeros(n, np.int32)
    types[:20] = INFECTED
    state = sim.init_state(pos, diameter=np.full(n, 1.0, np.float32),
                           agent_type=types,
                           extra_init={"infect_timer": np.full(n, 40, np.int32)})
    print(f"{'iter':>5} {'S':>7} {'I':>7} {'R':>7}")
    for epoch in range(10):
        state = sim.run(state, 20)
        t = np.asarray(state.pool.agent_type)[np.asarray(state.pool.alive)]
        print(f"{int(state.iteration):5d} {(t == SUSCEPTIBLE).sum():7d} "
              f"{(t == INFECTED).sum():7d} {(t == RECOVERED).sum():7d}")
    t = np.asarray(state.pool.agent_type)[np.asarray(state.pool.alive)]
    assert (t != SUSCEPTIBLE).sum() > 20, "epidemic should have spread"
    print("OK: epidemic spread and recovered")


if __name__ == "__main__":
    main()
