"""Assert each example's realized neighbor-sweep channel footprint.

The fused sweep (DESIGN.md §3.2) streams only the union of the registered
kernels' declared channel reads. This script pins down, per example, exactly
which channels that union contains — so a behavior silently growing its
footprint (and the per-step memory traffic of *every* example that uses it)
fails CI instead of landing unnoticed. It also runs
``engine.check_kernel_footprints`` on each example: every registered kernel
is traced in isolation with ONLY its declared channels, catching reads that
today ride along on another kernel's union contribution.

    PYTHONPATH=src python examples/check_footprints.py
"""

import importlib
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.core import engine as engine_mod
from repro.core.forces import FORCE_READS

# module name -> expected realized footprint (order = first-appearance order
# of fused_reads: force kernel first when forces are on, then behaviors in
# registration order). An empty tuple means the example runs no neighbor
# sweep at all (no forces, no neighbor-using behaviors).
EXPECTED = {
    # forces only: GrowDivide/NeuriteGrowth register no neighbor kernels
    "quickstart": FORCE_READS,
    "oncology": FORCE_READS,
    "neuroscience": FORCE_READS,
    # SIR: Infection's kernel, and *no* diameter — infection never streams
    # mechanical channels
    "epidemiology": ("position", "alive", "agent_type"),
    # diffusion-driven: Secretion/Chemotaxis read the substrate, not
    # neighbors — the step runs zero neighbor sweeps
    "cell_clustering": (),
}

# configs with the Verlet pair list enabled (DESIGN.md §3.4): the pair list
# prunes *candidates*, never channels — the realized footprint must be
# identical to the same config served by the streamed sweep
PAIRLIST_VARIANTS = {
    "cell_clustering": (lambda mod: mod.make_config(pairlist=True),
                        FORCE_READS),
}


def main() -> int:
    failed = []
    for name, expected in EXPECTED.items():
        mod = importlib.import_module(name)
        cfg, behaviors = mod.make_config(), mod.behaviors()
        got = engine_mod.realized_footprint(cfg, behaviors)
        status = "ok"
        if got != tuple(expected):
            status = f"MISMATCH (expected {tuple(expected)})"
            failed.append(name)
        print(f"{name:18s} footprint={got} {status}")
        try:
            engine_mod.check_kernel_footprints(cfg, behaviors)
        except Exception as e:          # noqa: BLE001 - report and fail
            print(f"{name:18s} footprint check FAILED: {e}")
            failed.append(name)
        if name in PAIRLIST_VARIANTS:
            make_cfg, pl_expected = PAIRLIST_VARIANTS[name]
            pl_cfg = make_cfg(mod)
            assert pl_cfg.pairlist is not None, name
            pl_got = engine_mod.realized_footprint(pl_cfg, behaviors)
            pl_status = "ok"
            if pl_got != tuple(pl_expected):
                pl_status = f"MISMATCH (expected {tuple(pl_expected)})"
                failed.append(name)
            print(f"{name:18s} [pairlist] footprint={pl_got} {pl_status}")
            try:
                engine_mod.check_kernel_footprints(pl_cfg, behaviors)
            except Exception as e:      # noqa: BLE001 - report and fail
                print(f"{name:18s} [pairlist] footprint check FAILED: {e}")
                failed.append(name)
    if failed:
        print(f"FAILED: {sorted(set(failed))}", file=sys.stderr)
        return 1
    print("OK: all example footprints match their pinned channel sets")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
