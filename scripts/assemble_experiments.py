"""Insert generated dry-run/roofline/perf tables into EXPERIMENTS.md markers.

Usage: PYTHONPATH=src python scripts/assemble_experiments.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline import report  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main() -> None:
    results = os.path.join(ROOT, "results", "dryrun")
    perf = os.path.join(ROOT, "results", "perf")
    recs = report.load(results)

    dry = ("### Single pod (16×16 = 256 chips)\n\n"
           + report.dryrun_table(recs, "pod1")
           + "\n\n### Multi-pod (2×16×16 = 512 chips)\n\n"
           + report.dryrun_table(recs, "pod2"))
    roof = ("### Single-pod baseline (all cells)\n\n"
            + report.roofline_table(recs, "pod1")
            + "\n\n### Multi-pod (512 chips)\n\n"
            + report.roofline_table(recs, "pod2"))
    perf_md = report.perf_table(perf)

    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLES -->", dry)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
    text = text.replace("<!-- PERF_LOG -->", perf_md)
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
