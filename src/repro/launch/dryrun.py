import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape) cell: build the step function, jit with
explicit in_shardings on the production mesh, ``.lower().compile()``, print
``memory_analysis()`` and ``cost_analysis()``, run the roofline analysis on the
optimized HLO, and persist one JSON per cell under results/dryrun/.

The two XLA_FLAGS lines above MUST precede every other import (jax locks the
device count at first init). Smoke tests and benches never import this module.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, shape_applicable
from ..roofline import analysis as roofline
from .cells import analytic_step_flops, build_cell, microbatches, probe_config
from .mesh import make_production_mesh, mesh_axes

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, hlo_dir: str | None = None) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if not ok:
        rec = {"cell": tag, "status": "skipped", "reason": why}
        if save:
            _save(tag, rec)
        print(json.dumps(rec))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, axes)
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    print(ma)                                  # proves it fits (bytes/device)
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()

    # depth-probe correction: cost_analysis counts scan bodies once — compile
    # depth-1 and depth-2 probes to reconstruct true per-device FLOPs/bytes
    # (see cells.probe_config).
    def _probe_cost(k: int) -> dict:
        pcfg = probe_config(cfg, k)
        pcell = build_cell(pcfg, shape, mesh, axes, force_micro=1,
                           unroll_scan=True)
        with mesh:
            pc = jax.jit(pcell.fn, in_shardings=pcell.in_shardings
                         ).lower(*pcell.args).compile()
        return pc.cost_analysis()

    pat_blocks = cell.model.n_blocks if hasattr(cell.model, "n_blocks") \
        else cfg.n_layers
    try:
        c1, c2 = _probe_cost(1), _probe_cost(2)
        corrected = {}
        for key in ("flops", "bytes accessed"):
            delta = max(float(c2.get(key, 0.0)) - float(c1.get(key, 0.0)), 0.0)
            corrected[key] = max(float(c1.get(key, 0.0))
                                 + delta * (pat_blocks - 1),
                                 float(ca.get(key, 0.0)))
        probe_note = "depth-probe corrected"
    except Exception as e:  # noqa: BLE001
        corrected = {k: float(ca.get(k, 0.0))
                     for k in ("flops", "bytes accessed")}
        probe_note = f"probe failed ({e!r}); raw cost_analysis"

    # compute term: analytic (EXPERIMENTS.md §Roofline method — XLA CPU-backend
    # cost_analysis undercounts partitioned MoE dots; §Perf B4). memory term:
    # probe-corrected HLO bytes. collective term: parsed HLO wire bytes.
    analytic_global = analytic_step_flops(cfg, shape)
    rl = roofline.analyze(
        {"flops": analytic_global / n_dev,
         "bytes accessed": corrected["bytes accessed"]},
        hlo, default_group=n_dev)

    total_flops_global = analytic_global
    step_time = max(rl.compute_s, rl.memory_s, rl.collective_s)
    # useful-MFU bound: fraction of peak devoted to *model* FLOPs during the
    # bound step time (the honest roofline score; 1.0 = at the compute wall
    # with zero waste)
    useful_mfu = ((cell.model_flops / n_dev / roofline.PEAK_FLOPS) / step_time
                  if step_time else None)
    rec = {
        "cell": tag,
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.shape.values()), "n_devices": int(n_dev),
        "n_params": int(cell.n_params),
        "n_active_params": int(cell.n_active_params),
        "note": cell.note,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
            "total_bytes_per_device": (ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
            "hbm_budget_bytes": 16 * 1024 ** 3,
        },
        "roofline": rl.as_dict(),
        "cost_raw": {k: float(ca.get(k, 0.0))
                     for k in ("flops", "bytes accessed")},
        "hlo_probe": corrected,
        "probe_note": probe_note,
        "model_flops": cell.model_flops,
        "analytic_flops_global": analytic_global,
        "useful_flops_ratio": (cell.model_flops / total_flops_global
                               if total_flops_global else None),
        "roofline_fraction": useful_mfu,
        "step_time_bound_s": step_time,
    }
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    if save:
        _save(tag, rec)
    print(json.dumps({k: rec[k] for k in
                      ("cell", "status", "compile_s", "roofline_fraction")}))
    return rec


def _save(tag: str, rec: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, args.multi_pod, hlo_dir=args.hlo_dir)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((a, s, repr(e)))
            _save(f"{a}__{s}__{'pod2' if args.multi_pod else 'pod1'}",
                  {"cell": f"{a}__{s}", "status": "error", "error": repr(e)})
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
