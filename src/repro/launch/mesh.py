"""Production meshes. IMPORTANT: functions, not module-level constants — importing
this module never touches jax device state."""

from __future__ import annotations

import jax

from ..models.layers import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model). Multi-pod: 2×16×16 = 512
    chips (pod, data, model) — the pod axis carries cross-pod data parallelism
    (DCN-ish in real deployments; the dry-run proves it shards)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(multi_pod: bool = False) -> MeshAxes:
    """Placeholder-axis resolution for this mesh (models/layers.resolve_spec)."""
    return MeshAxes(fsdp=("pod", "data") if multi_pod else ("data",),
                    tp="model")


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke/e2e runs (same code path as prod)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
