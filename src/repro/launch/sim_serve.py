"""Simulation service driver: a lane pool serving an SIR request stream.

    PYTHONPATH=src python -m repro.launch.sim_serve --lanes 8 --requests 32 \
        --agents 256 --steps 100 --beta-min 0.1 --beta-max 0.5

Submits ``--requests`` SIR simulations (per-request seed and infection rate
drawn from the beta range) to a :class:`~repro.serve.SimService` with
``--lanes`` ensemble lanes, then ticks until drained — continuous batching at
iteration granularity (DESIGN.md §8). ``--ckpt-dir`` + ``--checkpoint-every``
snapshot the whole ensemble periodically; ``--resume`` picks a killed service
back up mid-churn (occupied lanes bit-exact; undrained requests must be
re-submitted, which this driver does by replaying the unfinished tail of its
request list).
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from ..core import EngineConfig, ScenarioParams
from ..core.behaviors import INFECTED, Infection, RandomWalk
from ..serve import SimRequest, SimService


def make_service(n_lanes: int, agents: int, side: float) -> SimService:
    # sweep regime: comparison sort — the counting sort's scatter passes
    # batch poorly under the lane axis on XLA:CPU (benchmarks/ensemble.py)
    cfg = EngineConfig(
        capacity=-(-agents // 64) * 64,
        domain_lo=(0.0,) * 3, domain_hi=(side,) * 3,
        interaction_radius=3.0, use_forces=False, query_chunk=2048,
        max_per_box=32, sort_impl="argsort")
    behaviors = [
        RandomWalk(sigma=0.8),
        Infection(radius=3.0, beta=lambda ctx: ctx.params["beta"],
                  recovery_time=lambda ctx: ctx.params["recovery_time"]),
    ]

    def infected_count(pool, params):
        return jnp.sum((pool.agent_type == INFECTED) & pool.alive)

    return SimService(cfg, behaviors, n_lanes=n_lanes,
                      params_template=ScenarioParams.of(beta=0.0,
                                                        recovery_time=1),
                      metrics_fn=infected_count,
                      converged_fn=lambda m: int(m) == 0)


def make_request(uid: int, agents: int, side: float, beta: float,
                 recovery_time: int, max_steps: int) -> SimRequest:
    r = np.random.RandomState(1000 + uid)
    pos = r.uniform(0, side, (agents, 3)).astype(np.float32)
    types = np.zeros(agents, np.int32)
    n0 = max(agents // 50, 2)
    types[:n0] = INFECTED
    timer = np.zeros(agents, np.int32)
    timer[:n0] = recovery_time
    return SimRequest(
        uid=uid, position=pos,
        diameter=np.full(agents, 1.0, np.float32), agent_type=types,
        extra_init={"infect_timer": timer}, seed=uid,
        params=ScenarioParams.of(beta=beta, recovery_time=recovery_time),
        max_steps=max_steps)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--agents", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100,
                    help="per-request step budget")
    ap.add_argument("--beta-min", type=float, default=0.1)
    ap.add_argument("--beta-max", type=float, default=0.5)
    ap.add_argument("--recovery-time", type=int, default=40)
    ap.add_argument("--side", type=float, default=None,
                    help="cubic domain edge (default: density-scaled)")
    ap.add_argument("--report-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint the ensemble every K ticks (0 = off)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir")
    args = ap.parse_args()

    side = args.side or max(40.0, (args.agents ** (1 / 3)) * 5)
    svc = make_service(args.lanes, args.agents, side)
    betas = np.linspace(args.beta_min, args.beta_max, args.requests)

    busy_uids, done_uids = set(), set()
    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume requires --ckpt-dir")
        tick = svc.restore(args.ckpt_dir)
        busy_uids = {info["req"].uid for info in svc.lanes
                     if info is not None}
        done_uids = set(svc.restored_meta.get("finished_uids", []))
        print(f"resumed at tick {tick}: busy={sorted(busy_uids)} "
              f"finished={len(done_uids)}")

    for uid in range(args.requests):
        if uid in busy_uids or uid in done_uids:
            continue
        svc.submit(make_request(uid, args.agents, side, float(betas[uid]),
                                args.recovery_time, args.steps))

    t0 = time.time()
    ticks = 0
    agent_steps = 0
    while svc.queue or any(info is not None for info in svc.lanes):
        stepped = svc.step()
        ticks += 1
        agent_steps += stepped * args.agents
        if args.checkpoint_every and args.ckpt_dir \
                and ticks % args.checkpoint_every == 0:
            svc.checkpoint(args.ckpt_dir, extras={
                "finished_uids": sorted(f.uid for f in svc.finished)})
        if ticks % args.report_every == 0:
            dt = time.time() - t0
            print(f"tick {ticks:5d}  occupancy={svc.occupancy():4.2f}  "
                  f"finished={len(svc.finished):3d}/{args.requests}  "
                  f"{agent_steps / dt:,.0f} agent-steps/s")
    dt = time.time() - t0
    if args.ckpt_dir:
        svc.checkpoint(args.ckpt_dir, extras={
            "finished_uids": sorted(f.uid for f in svc.finished)})
    print(f"drained {len(svc.finished)} simulations in {ticks} ticks "
          f"({dt:.1f} s, {agent_steps / dt:,.0f} agent-steps/s)")
    for f in sorted(svc.finished, key=lambda f: f.uid)[:10]:
        peak = max(int(np.asarray(m)) for m in f.trajectory)
        print(f"  uid={f.uid:3d} beta={betas[f.uid]:.3f} steps={f.steps:4d} "
              f"reason={f.reason:9s} peak_infected={peak}")
    print("done")


if __name__ == "__main__":
    main()
