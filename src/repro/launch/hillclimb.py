import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: compile optimization variants of the three selected
cells, record the three roofline terms per variant to results/perf/.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  kimi-k2-1t-a32b  × train_4k   — worst useful-MFU fraction
  deepseek-v2-lite × train_4k   — most collective-bound
  qwen3-14b        × decode_32k — most paper-representative (KV pool serving)

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [cell_key ...]
"""

import dataclasses
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES
from ..roofline import analysis as roofline
from .cells import analytic_step_flops, build_cell, probe_config
from .mesh import make_production_mesh, mesh_axes

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "perf")


def _variants():
    ds = ARCHS["deepseek-v2-lite-16b"]
    km = ARCHS["kimi-k2-1t-a32b"]
    q3 = ARCHS["qwen3-14b"]
    return {
        # --- deepseek train: attack the collective term ---
        "ds_train_v1_gather": dict(
            cfg=dataclasses.replace(ds, moe_dispatch="gather"),
            shape="train_4k",
            hyp="dispatch as int32 slot-map + activation gather: the f32 "
                "(E,cap,D) scatter-psum becomes one bf16 all-gather "
                "(predict collective −40%)"),
        "ds_train_v2_unshard_ffn": dict(
            cfg=dataclasses.replace(ds, moe_dispatch="gather",
                                    moe_ffn_unsharded=True),
            shape="train_4k",
            hyp="expert FFN dim replicated (weights fit: 1.8 GB/dev): the "
                "down-proj partial-sum all-reduce disappears "
                "(predict collective −50% more)"),
        "ds_train_v3_bf16_sync": dict(
            cfg=dataclasses.replace(ds, moe_dispatch="gather",
                                    moe_ffn_unsharded=True),
            shape="train_4k", grad_sync_dtype="bfloat16",
            hyp="bf16 gradient sync: DP reduce wire halves "
                "(predict collective −20% more)"),
        "ds_train_v4_cf1": dict(
            cfg=dataclasses.replace(ds, moe_dispatch="gather",
                                    moe_ffn_unsharded=True,
                                    capacity_factor=1.0),
            shape="train_4k", grad_sync_dtype="bfloat16",
            hyp="capacity factor 1.25→1.0: dispatched volume −20% "
                "(compute & remaining dispatch wire −20%)"),
        "ds_train_v5_remat_dots": dict(
            cfg=dataclasses.replace(ds, moe_dispatch="gather",
                                    moe_ffn_unsharded=True,
                                    capacity_factor=1.0, remat="dots"),
            shape="train_4k", grad_sync_dtype="bfloat16",
            hyp="remat policy full→dots_saveable: the backward pass stops "
                "replaying the forward's gathers/psums (predict collective "
                "−~25%, memory term up)"),
        # --- kimi train: same levers minus ffn-unshard (weights too big) ---
        "kimi_train_v1_gather": dict(
            cfg=dataclasses.replace(km, moe_dispatch="gather"),
            shape="train_4k",
            hyp="gather dispatch (see ds_v1) at 1T scale"),
        "kimi_train_v2_bf16_sync": dict(
            cfg=dataclasses.replace(km, moe_dispatch="gather"),
            shape="train_4k", grad_sync_dtype="bfloat16",
            hyp="bf16 gradient sync on 1T params"),
        "kimi_train_v3_cf1": dict(
            cfg=dataclasses.replace(km, moe_dispatch="gather",
                                    capacity_factor=1.0),
            shape="train_4k", grad_sync_dtype="bfloat16",
            hyp="capacity factor 1.0"),
        # --- qwen3 decode: attack the memory term ---
        "q3_decode_v1_kv_tp": dict(
            cfg=q3, shape="decode_32k", cache_seq_axis="model",
            hyp="shard the KV seq dim over the idle model axis too: cache "
                "reads spread over 16× more chips (predict memory −~10×, "
                "small softmax psum added)"),
        "q3_decode_v2_tp_only_weights": dict(
            cfg=q3, shape="decode_32k", cache_seq_axis="model",
            axes_override="tp_only",
            hyp="inference weights TP-only (replicated over data — no "
                "optimizer state to co-shard): removes the per-step FSDP "
                "weight all-gather (2.2 GB/dev; predict collective −~45×)"),
    }


def run_variant(key: str, spec: dict, multi_pod: bool = False) -> dict:
    cfg = spec["cfg"]
    shape = SHAPES[spec["shape"]]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(multi_pod)
    if spec.get("axes_override") == "tp_only":
        from ..models.layers import MeshAxes
        axes = MeshAxes(fsdp=(), tp="model",
                        batch_axes=("pod", "data") if multi_pod else ("data",))
    n_dev = mesh.devices.size
    t0 = time.time()
    kw = dict(grad_sync_dtype=spec.get("grad_sync_dtype"),
              cache_seq_axis=spec.get("cache_seq_axis"))
    cell = build_cell(cfg, shape, mesh, axes, **kw)
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings
                           ).lower(*cell.args).compile()
    hlo = compiled.as_text()
    ma = compiled.memory_analysis()

    def _probe(k):
        pcell = build_cell(probe_config(cfg, k), shape, mesh, axes,
                           force_micro=1, unroll_scan=True, **kw)
        with mesh:
            pc = jax.jit(pcell.fn, in_shardings=pcell.in_shardings
                         ).lower(*pcell.args).compile()
        return pc.cost_analysis()

    pat_blocks = getattr(cell.model, "n_blocks", cfg.n_layers)
    c1, c2 = _probe(1), _probe(2)
    mem_bytes = max(float(c1.get("bytes accessed", 0.0))
                    + max(float(c2.get("bytes accessed", 0.0))
                          - float(c1.get("bytes accessed", 0.0)), 0.0)
                    * (pat_blocks - 1), 0.0)

    analytic = analytic_step_flops(cfg, shape)
    rl = roofline.analyze({"flops": analytic / n_dev,
                           "bytes accessed": mem_bytes},
                          hlo, default_group=n_dev)
    step = max(rl.compute_s, rl.memory_s, rl.collective_s)
    rec = {
        "variant": key, "hypothesis": spec["hyp"],
        "arch": cfg.name, "shape": shape.name, "n_devices": int(n_dev),
        "roofline": rl.as_dict(),
        "model_flops": cell.model_flops,
        "roofline_fraction": (cell.model_flops / n_dev / roofline.PEAK_FLOPS
                              / step) if step else None,
        "step_time_bound_s": step,
        "temp_bytes_per_device": ma.temp_size_in_bytes,
        "wall_s": round(time.time() - t0, 1),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, key + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: rec[k] for k in ("variant", "step_time_bound_s",
                                          "roofline_fraction")}))
    return rec


def main() -> None:
    import sys
    keys = sys.argv[1:] or list(_variants().keys())
    vs = _variants()
    for key in keys:
        try:
            run_variant(key, vs[key])
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"VARIANT FAILED: {key}")


if __name__ == "__main__":
    main()
