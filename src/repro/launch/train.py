"""End-to-end training driver: config → mesh → data → train loop → checkpoints.

Fault-tolerance contract (DESIGN.md §7):
  * resumes from the latest checkpoint automatically (crash/preemption safe),
  * checkpoints asynchronously every ``ckpt_every`` steps,
  * the data pipeline is stateless-by-step, so restart repeats no batch,
  * restore reshards onto whatever mesh the restart runs with (elastic).

Runs unchanged on 1 CPU device (host mesh) or a production mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig
from ..data import DataConfig, batch_at
from ..models import build_model
from ..models.layers import MeshAxes, set_hint_axes
from ..train import AdamWConfig, checkpoint, make_train_step
from ..train.optimizer import init_state as opt_init
from .mesh import make_host_mesh, mesh_axes


@dataclasses.dataclass
class TrainJob:
    arch: ArchConfig
    steps: int = 100
    seq_len: int = 512
    global_batch: int = 8
    lr: float = 3e-4
    warmup: int = 20
    n_microbatches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


def run(job: TrainJob, mesh=None, axes: Optional[MeshAxes] = None,
        log=print) -> Dict[str, float]:
    cfg = job.arch
    mesh = mesh or make_host_mesh()
    axes = axes or MeshAxes(fsdp=("data",))
    set_hint_axes(axes)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=job.lr, warmup_steps=job.warmup,
                          total_steps=job.steps,
                          moment_dtype=cfg.opt_moment_dtype)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=job.seq_len,
                      global_batch=job.global_batch,
                      frontend_tokens=(job.seq_len if cfg.encoder_layers
                                       else cfg.frontend_tokens),
                      d_model=cfg.d_model, seed=job.seed)

    params = model.init_params(jax.random.PRNGKey(job.seed))
    opt_state = opt_init(opt_cfg, params)
    start_step = 0

    ck = checkpoint.AsyncCheckpointer(job.ckpt_dir) if job.ckpt_dir else None
    if job.ckpt_dir:
        latest = checkpoint.latest_step(job.ckpt_dir)
        if latest is not None:
            log(f"[train] resuming from checkpoint step {latest}")
            state = checkpoint.restore(job.ckpt_dir, latest,
                                       {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      n_microbatches=job.n_microbatches))
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start_step, job.steps):
            batch = batch_at(dcfg, step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % job.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                losses.append(loss)
                tok_s = (job.global_batch * job.seq_len * (step + 1 - start_step)
                         / max(time.time() - t0, 1e-9))
                log(f"[train] step {step + 1}/{job.steps} loss={loss:.4f} "
                    f"lr={float(metrics['lr']):.2e} "
                    f"gnorm={float(metrics['grad_norm']):.3f} tok/s={tok_s:.0f}")
            if ck and (step + 1) % job.ckpt_every == 0:
                ck.save_async(step + 1, {"params": params, "opt": opt_state})
    if ck:
        ck.save_async(job.steps, {"params": params, "opt": opt_state})
        ck.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan")}
