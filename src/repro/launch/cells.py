"""Dry-run cell assembly: input_specs + shardings for every (arch × shape).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero device allocation. ``cell_shardings``
returns matching NamedSharding trees. Together they define exactly what
``dryrun.py`` lowers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, LayerDesc, ShapeSpec
from ..models import build_model
from ..models.layers import MeshAxes, resolve_spec
from ..train import AdamWConfig
from ..train.optimizer import init_state as opt_init

# per-cell microbatch counts (activation-memory fits; FLOPs unchanged).
MICROBATCHES: Dict[Tuple[str, str], int] = {
    ("kimi-k2-1t-a32b", "train_4k"): 16,
    ("jamba-v0.1-52b", "train_4k"): 4,
    ("deepseek-v2-lite-16b", "train_4k"): 2,
    ("qwen3-14b", "train_4k"): 2,
    ("yi-9b", "train_4k"): 2,
}


def microbatches(arch: str, shape: str) -> int:
    return MICROBATCHES.get((arch, shape), 1)


def _batch_axes(axes: MeshAxes):
    b = axes.batch
    return b if len(b) > 1 else b[0]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


@dataclasses.dataclass
class Cell:
    """Everything dryrun needs to lower one (arch × shape) on one mesh."""
    fn: Any                       # the step function to jit
    args: Tuple                   # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    model: Any
    n_params: int
    n_active_params: int
    model_flops: float            # 6ND train / 2ND decode-prefill
    note: str = ""


def _count_active_params(model, cfg: ArchConfig) -> int:
    """Total params minus the unrouted share of expert weights."""
    total = model.ps.n_params()
    if not cfg.n_experts:
        return total
    import math
    expert = sum(math.prod(i.shape) for p, i in model.ps.infos.items()
                 if "/moe/w_" in p)
    return int(total - expert * (1.0 - cfg.top_k / cfg.n_experts))


def _param_structs(model, axes: MeshAxes, mesh) -> Tuple[Any, Any]:
    shapes = model.ps.shape_tree()
    specs = model.ps.spec_tree(axes)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return shapes, shardings


def _opt_structs(model, cfg: ArchConfig, axes: MeshAxes, mesh):
    mdt = _dt(cfg.opt_moment_dtype)
    shapes = model.ps.shape_tree()
    mom = jax.tree.map(lambda sd: jax.ShapeDtypeStruct(sd.shape, mdt), shapes)
    state = {"mu": mom, "nu": jax.tree.map(lambda x: x, mom),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = model.ps.spec_tree(axes)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    state_sh = {"mu": sh, "nu": jax.tree.map(lambda x: x, sh),
                "step": NamedSharding(mesh, P())}
    return state, state_sh


def _batch_structs(cfg: ArchConfig, shape: ShapeSpec, axes: MeshAxes, mesh,
                   adt) -> Tuple[Dict, Dict]:
    b, s = shape.global_batch, shape.seq_len
    ba = _batch_axes(axes)
    fe_len = cfg.frontend_tokens
    if cfg.encoder_layers > 0:
        # enc-dec: frames on the encoder, tokens on the decoder (both seq_len)
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "frontend_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                         adt)}
        sh = {"tokens": NamedSharding(mesh, P(ba, None)),
              "labels": NamedSharding(mesh, P(ba, None)),
              "frontend_embeds": NamedSharding(mesh, P(ba, None, None))}
        return batch, sh
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    sh = {"tokens": NamedSharding(mesh, P(ba, None)),
          "labels": NamedSharding(mesh, P(ba, None))}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.ShapeDtypeStruct((b, fe_len, cfg.d_model),
                                                        adt)
        sh["frontend_embeds"] = NamedSharding(mesh, P(ba, None, None))
    return batch, sh


def _cache_shardings(model, cfg: ArchConfig, shape: ShapeSpec,
                     axes: MeshAxes, mesh, specs_tree,
                     cache_seq_axis: str | None = None) -> Any:
    """decode_32k: shard caches on batch. long_500k (B=1): shard the sequence
    axis of attention caches over 'data' (sequence-parallel decode); small SSM
    states stay replicated."""
    ba = _batch_axes(axes)
    seq_parallel = shape.global_batch == 1

    def leaf_spec(sd: jax.ShapeDtypeStruct) -> NamedSharding:
        dims: list = [None] * len(sd.shape)
        if seq_parallel:
            for i, d in enumerate(sd.shape):
                if d == shape.seq_len:
                    dims[i] = "data"
                    break
        else:
            # batch axis: the axis matching global_batch (after the optional
            # leading n_blocks stack dim)
            for i, d in enumerate(sd.shape):
                if d == shape.global_batch:
                    dims[i] = ba
                    break
            if cache_seq_axis:   # §Perf: additionally shard the KV seq dim
                for i, d in enumerate(sd.shape):
                    if d == shape.seq_len and dims[i] is None:
                        dims[i] = cache_seq_axis
                        break
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(leaf_spec, specs_tree)


def analytic_step_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Exact GLOBAL FLOPs of one step from the architecture definition.

    XLA's CPU-backend cost_analysis miscounts partitioned MoE einsums (hand
    verification against HLO dot shapes in EXPERIMENTS.md §Perf B4), so the
    roofline *compute* term uses this analytic count; HLO-probe numbers are
    recorded alongside. Conventions: matmul = 2mnk FLOPs; causal attention
    averages S/2 context; train = 3× fwd (+1× fwd when remat='full');
    dispatched MoE tokens include the capacity factor.
    """
    d, v = cfg.d_model, ((cfg.vocab_size + 127) // 128) * 128
    s, b = shape.seq_len, shape.global_batch

    def attn_layer(per_ctx: float) -> float:
        if cfg.mla:
            r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                             cfg.qk_rope_dim, cfg.v_head_dim)
            h = cfg.n_heads
            proj = 2 * d * h * (dn + dr) + 2 * d * (r + dr) \
                + 2 * r * h * (dn + dv) + 2 * h * dv * d
            attn = 2 * 2 * per_ctx * h * (dn + dr + dv) / 2
        else:
            h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
            proj = 2 * d * (h + 2 * hk) * dh + 2 * h * dh * d
            attn = 2 * 2 * per_ctx * h * dh        # scores + values, avg ctx
        return proj + attn

    def mlp_dense() -> float:
        return 3 * 2 * d * cfg.d_ff

    def mlp_moe() -> float:
        f = cfg.moe_d_ff
        routed = 3 * 2 * cfg.top_k * cfg.capacity_factor * d * f
        shared = 3 * 2 * d * f * cfg.n_shared_experts
        return 2 * d * cfg.n_experts + routed + shared

    def ssm_layer(per_ctx: float) -> float:
        di = cfg.ssm_expand * d
        h = di // cfg.ssm_head_dim
        n = cfg.ssm_state
        proj = 2 * d * (2 * di + 2 * n + h) + 2 * di * d
        l = min(cfg.ssm_chunk, max(int(per_ctx), 1))
        ssd = 2 * l * n + 2 * l * di + 8 * di * n     # intra + states, per token
        return proj + ssd

    # per-token flops for one pass over all layers
    per_ctx = s / 2 if shape.kind != "decode" else s   # decode reads full cache
    total = 2 * d * v                                   # logits
    pat = cfg.layer_pattern()
    reps = (cfg.n_layers - cfg.first_dense_layers) // len(pat)
    layers = [LayerDesc(kind="attn", mlp="dense")] * cfg.first_dense_layers \
        + list(pat) * reps
    for ld in layers:
        if ld.kind == "attn":
            total += attn_layer(per_ctx)
        else:
            total += ssm_layer(per_ctx)
        if ld.mlp == "dense":
            total += mlp_dense()
        elif ld.mlp == "moe":
            total += mlp_moe()
    if cfg.encoder_layers:
        enc = sum(attn_layer(s / 2) + mlp_dense()
                  for _ in range(cfg.encoder_layers))
        total += enc

    n_tokens = b * (1 if shape.kind == "decode" else s)
    passes = 1.0
    if shape.kind == "train":
        passes = 3.0 + (1.0 if cfg.remat == "full" else 0.0)
    return float(total) * n_tokens * passes


def probe_config(cfg: ArchConfig, k: int) -> ArchConfig:
    """Depth-k variant for FLOPs/bytes probing.

    XLA's ``cost_analysis`` counts while-loop (lax.scan) bodies ONCE, so the
    full compile under-reports per-step FLOPs by ~n_blocks×. We compile the
    same cell at depths 1 and 2; the difference isolates exactly one pattern
    block, and total = base + n_blocks·delta reconstructs the true per-device
    cost (probes force n_microbatches=1: FLOPs are microbatch-invariant)."""
    pat = cfg.layer_pattern()
    upd: dict = {"n_layers": cfg.first_dense_layers + len(pat) * k}
    if cfg.encoder_layers:
        upd["encoder_layers"] = k
        upd["n_layers"] = k
    return dataclasses.replace(cfg, **upd)


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, axes: MeshAxes,
               attn_impl: str = "xla", force_micro: int | None = None,
               unroll_scan: bool = False,
               grad_sync_dtype: str | None = None,
               cache_seq_axis: str | None = None) -> Cell:
    from ..models.layers import set_hint_axes
    from ..train.train_step import (make_decode_step, make_prefill_step,
                                    make_train_step)

    set_hint_axes(axes)   # activation sharding hints resolve on this mesh
    model = build_model(cfg, attn_impl=attn_impl, unroll_scan=unroll_scan)
    adt = _dt(cfg.activation_dtype)
    n_params = model.ps.n_params()
    n_active = _count_active_params(model, cfg)
    tokens = shape.global_batch * shape.seq_len
    param_shapes, param_sh = _param_structs(model, axes, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.opt_moment_dtype)
        opt_shapes, opt_sh = _opt_structs(model, cfg, axes, mesh)
        batch, batch_sh = _batch_structs(cfg, shape, axes, mesh, adt)
        nm = force_micro or microbatches(cfg.name, shape.name)
        fn = make_train_step(model, opt_cfg, n_microbatches=nm,
                             grad_sync_dtype=grad_sync_dtype)
        return Cell(fn=fn, args=(param_shapes, opt_shapes, batch),
                    in_shardings=(param_sh, opt_sh, batch_sh),
                    model=model, n_params=n_params, n_active_params=n_active,
                    model_flops=6.0 * n_active * tokens,
                    note=f"microbatches={nm}")

    if shape.kind == "prefill":
        batch, batch_sh = _batch_structs(cfg, shape, axes, mesh, adt)
        batch.pop("labels"); batch_sh.pop("labels")
        fn = make_prefill_step(model)
        return Cell(fn=fn, args=(param_shapes, batch),
                    in_shardings=(param_sh, batch_sh),
                    model=model, n_params=n_params, n_active_params=n_active,
                    model_flops=2.0 * n_active * tokens)

    # decode: one new token against a seq_len-deep cache
    b = shape.global_batch
    s_max = shape.seq_len
    if cfg.encoder_layers > 0:
        cache_specs = model.decode_cache_specs(b, s_max, s_enc=s_max)
    else:
        cache_specs = model.decode_cache_specs(b, s_max)
    cache_sh = _cache_shardings(model, cfg, shape, axes, mesh, cache_specs,
                                cache_seq_axis=cache_seq_axis)
    ba = _batch_axes(axes)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    token_sh = NamedSharding(mesh, P(ba if b > 1 else None))
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)
    cur_sh = NamedSharding(mesh, P())
    fn = make_decode_step(model)
    return Cell(fn=fn, args=(param_shapes, token, cache_specs, cur_len),
                in_shardings=(param_sh, token_sh, cache_sh, cur_sh),
                model=model, n_params=n_params, n_active_params=n_active,
                model_flops=2.0 * n_active * b)
