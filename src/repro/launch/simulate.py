"""ABM simulation driver: named scenarios from the paper's Table 1, CLI-sized.

    PYTHONPATH=src python -m repro.launch.simulate --scenario proliferation \
        --agents 10000 --iterations 100 [--force-impl pallas]

Fault-tolerant mode (DESIGN.md §7.5): ``--supervised --ckpt-dir DIR`` runs
under the checkpointing supervisor — periodic atomic checkpoints, in-graph
health guards, rollback + degradation on faults, and a structured run report
printed at the end. ``--resume`` continues a killed run from the latest
checkpoint in ``--ckpt-dir`` (bit-exact with the uninterrupted run).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..core import (CapacityLadder, EngineConfig, ForceParams, Simulation,
                    SupervisedRunner, restore_state)
from ..core.behaviors import (Chemotaxis, GrowDivide, Infection, NeuriteGrowth,
                              RandomDeath, RandomWalk, Secretion,
                              GROWTH_CONE, INFECTED)
from ..core.diffusion import DiffusionSpec

SCENARIOS = ("proliferation", "clustering", "epidemiology", "neuroscience",
             "oncology")


def build(scenario: str, n: int, force_impl: str):
    rng = np.random.default_rng(0)
    if scenario == "proliferation":
        side = max(120.0, (n ** (1 / 3)) * 14)
        cfg = EngineConfig(capacity=max(4 * n, 1024), domain_lo=(0,) * 3,
                           domain_hi=(side,) * 3, interaction_radius=14.0,
                           dt=0.2, sort_frequency=10, max_per_box=128,
                           force_impl=force_impl,
                           force=ForceParams(max_displacement=1.0))
        sim = Simulation(cfg, [GrowDivide(rate=0.6, threshold_diameter=12.0)])
        pos = rng.uniform(side * 0.4, side * 0.6, (n, 3)).astype(np.float32)
        st = sim.init_state(pos, diameter=np.full(n, 8.0, np.float32))
    elif scenario == "clustering":
        side = max(64.0, (n ** (1 / 3)) * 4)
        dim = int(side // 2)
        cfg = EngineConfig(capacity=n, domain_lo=(0,) * 3,
                           domain_hi=(side,) * 3, interaction_radius=3.0,
                           use_forces=False, query_chunk=4096,
                           diffusion=DiffusionSpec(dims=(dim,) * 3,
                                                   coefficient=0.5,
                                                   decay=0.01, voxel=2.0))
        sim = Simulation(cfg, [Secretion(rate=2.0), Chemotaxis(speed=0.35)])
        pos = rng.uniform(4, side - 4, (n, 3)).astype(np.float32)
        st = sim.init_state(pos, diameter=np.full(n, 1.0, np.float32))
    elif scenario == "epidemiology":
        side = max(100.0, (n ** (1 / 3)) * 5)
        cfg = EngineConfig(capacity=n, domain_lo=(0,) * 3,
                           domain_hi=(side,) * 3, interaction_radius=3.0,
                           use_forces=False, query_chunk=4096)
        sim = Simulation(cfg, [RandomWalk(sigma=0.8),
                               Infection(radius=3.0, beta=0.25,
                                         recovery_time=40)])
        pos = rng.uniform(0, side, (n, 3)).astype(np.float32)
        types = np.zeros(n, np.int32)
        types[:max(n // 1000, 5)] = INFECTED
        st = sim.init_state(pos, diameter=np.full(n, 1.0, np.float32),
                            agent_type=types,
                            extra_init={"infect_timer":
                                        np.full(n, 40, np.int32)})
    elif scenario == "neuroscience":
        cfg = EngineConfig(capacity=max(40 * n, 2048), domain_lo=(0,) * 3,
                           domain_hi=(160,) * 3, interaction_radius=4.0,
                           dt=0.5, detect_static=True, sort_frequency=20,
                           max_per_box=64, force_impl=force_impl,
                           force=ForceParams(max_displacement=0.2,
                                             move_eps=1e-4))
        sim = Simulation(cfg, [NeuriteGrowth(speed=0.8, noise=0.2,
                                             bifurcation_prob=0.008)])
        pos = rng.uniform(70, 90, (n, 3)).astype(np.float32)
        d0 = rng.standard_normal((n, 3)).astype(np.float32)
        d0 /= np.linalg.norm(d0, axis=1, keepdims=True)
        st = sim.init_state(pos, diameter=np.full(n, 2.0, np.float32),
                            agent_type=np.full(n, GROWTH_CONE, np.int32),
                            extra_init={"direction": d0})
    elif scenario == "oncology":
        side = max(160.0, (n ** (1 / 3)) * 16)
        cfg = EngineConfig(capacity=max(8 * n, 2048), domain_lo=(0,) * 3,
                           domain_hi=(side,) * 3, interaction_radius=14.0,
                           dt=0.2, sort_frequency=10, max_per_box=160,
                           force_impl=force_impl,
                           force=ForceParams(max_displacement=1.0))
        sim = Simulation(cfg, [GrowDivide(rate=0.7, threshold_diameter=12.0),
                               RandomWalk(sigma=0.1),
                               RandomDeath(rate=0.012)])
        pos = rng.uniform(side * 0.35, side * 0.65, (n, 3)).astype(np.float32)
        st = sim.init_state(pos, diameter=np.full(n, 9.0, np.float32))
    else:
        raise SystemExit(f"unknown scenario {scenario}")
    return sim, st


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=SCENARIOS, default="proliferation")
    ap.add_argument("--agents", type=int, default=10_000)
    ap.add_argument("--iterations", type=int, default=100)
    ap.add_argument("--force-impl", choices=("xla", "pallas"), default="xla")
    ap.add_argument("--report-every", type=int, default=20)
    ap.add_argument("--supervised", action="store_true",
                    help="run under the fault-tolerant supervisor (§7.5)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (required with --supervised)")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir")
    args = ap.parse_args()

    sim, st = build(args.scenario, args.agents, args.force_impl)
    if args.supervised or args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--supervised/--resume require --ckpt-dir")
        cfg, behaviors = sim.config, sim.behaviors
        if args.resume:
            st, cfg = restore_state(args.ckpt_dir, cfg, behaviors)
            print(f"resumed from {args.ckpt_dir} at iteration "
                  f"{int(st.iteration)}")
        runner = SupervisedRunner(CapacityLadder(cfg, behaviors),
                                  args.ckpt_dir,
                                  checkpoint_every=args.checkpoint_every)
        t0 = time.time()
        st, report = runner.run(st, args.iterations)
        dt = time.time() - t0
        print(f"iter {int(st.iteration):5d}  "
              f"n_live={int(st.stats['n_live']):8d}  "
              f"{args.iterations / dt:6.2f} iter/s")
        print("run report: " + json.dumps(report.to_dict()))
        print("done")
        return

    t0 = time.time()
    done = 0
    while done < args.iterations:
        k = min(args.report_every, args.iterations - done)
        st = sim.run(st, k, check_overflow=True)
        done += k
        dt = time.time() - t0
        print(f"iter {done:5d}  n_live={int(st.stats['n_live']):8d}  "
              f"n_active={int(st.stats['n_active']):8d}  "
              f"{done / dt:6.2f} iter/s  "
              f"{int(st.stats['n_live']) * done / dt:,.0f} agent·iter/s")
    print("done")


if __name__ == "__main__":
    main()
