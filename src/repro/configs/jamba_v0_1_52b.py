"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2 every other layer, attention:mamba 1:7 (attn at index 4 of each
8-layer block). Jamba v0.1 uses Mamba-1 internals (d_state=16); we realize all
SSM layers with the Mamba-2 SSD formulation (TPU-friendly chunked scan) at the
same state size — documented adaptation (DESIGN.md §11). [arXiv:2403.19887; hf]"""
from .base import ArchConfig, LayerDesc

_A, _S = "attn", "ssm"
_PATTERN = tuple(
    LayerDesc(kind=(_A if i == 4 else _S), mlp=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=65536,
    pattern=_PATTERN,
    n_experts=16, top_k=2, moe_d_ff=14336,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=128,
    rope_theta=1e4,
)
