"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared), first layer dense
(d_ff=18432). ~1.03T params, ~32B active. Follows the assignment's spec line
(GQA, not MLA). bf16 optimizer moments so state fits 512 chips (DESIGN.md §7).
[arXiv:2501.kimi2; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=18432, vocab_size=163840,
    n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=1, rope_theta=1e6,
    opt_moment_dtype="bfloat16",
)
