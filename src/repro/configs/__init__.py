"""Architecture registry: the 10 assigned architectures (+ paper-native ABM)."""

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   ArchConfig, LayerDesc, ShapeSpec, shape_applicable)

from . import (deepseek_v2_lite_16b, jamba_v0_1_52b, kimi_k2_1t_a32b,
               mamba2_370m, phi_3_vision_4_2b, qwen2_1_5b, qwen3_14b,
               seamless_m4t_large_v2, yi_6b, yi_9b)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    qwen2_1_5b, qwen3_14b, yi_6b, yi_9b, seamless_m4t_large_v2,
    kimi_k2_1t_a32b, deepseek_v2_lite_16b, jamba_v0_1_52b, mamba2_370m,
    phi_3_vision_4_2b)}

SHAPES = {s.name: s for s in ALL_SHAPES}

__all__ = ["ARCHS", "SHAPES", "ArchConfig", "LayerDesc", "ShapeSpec",
           "shape_applicable", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K", "ALL_SHAPES"]
