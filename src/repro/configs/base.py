"""Architecture configuration schema + input-shape sets.

Every assigned architecture is an ``ArchConfig``; the four assigned input
shapes are ``ShapeSpec``s. ``LAYER PATTERNS``: a model is a repeating pattern
of layer descriptors scanned ``n_layers / len(pattern)`` times — this keeps
HLO small (fast multi-pod compiles) and makes hybrid interleaves (Jamba 1:7)
first-class.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """One layer inside the repeating block pattern."""
    kind: str            # "attn" | "ssm"
    mlp: str             # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # layer pattern (repeating); None → [attn+dense] * 1
    pattern: Optional[Tuple[LayerDesc, ...]] = None
    first_dense_layers: int = 0       # leading layers forced to dense MLP (MoE archs)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # dispatch mechanics (hillclimb knobs; EXPERIMENTS.md §Perf):
    #   scatter: tokens scatter-added into (E,cap,D) buffers (baseline)
    #   gather:  int32 slot→token map scattered, activations gathered —
    #            the heavy cross-shard movement becomes one bf16 all-gather
    moe_dispatch: str = "scatter"
    # replicate the expert-FFN dim (weights small enough): removes the
    # (E,cap,D) partial-sum all-reduce of the down-projection entirely
    moe_ffn_unsharded: bool = False

    # MLA (DeepSeek compressed KV)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM (Mamba2 / SSD)
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # encoder-decoder
    encoder_layers: int = 0           # >0 → enc-dec model

    # modality frontend stubs (audio/vision): the dry-run feeds precomputed
    # frame/patch embeddings of this length; 0 → pure token model
    frontend: str = "none"            # none | audio_frames | vision_patches
    frontend_tokens: int = 0

    # numerics / memory policy
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat: str = "full"               # none | dots | full
    opt_moment_dtype: str = "float32" # bf16 for the 1T config (DESIGN.md §7)

    @property
    def is_subquadratic(self) -> bool:
        """True if decode cost per token does not scale with full attention over
        the whole context on every layer (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def layer_pattern(self) -> Tuple[LayerDesc, ...]:
        if self.pattern is not None:
            return self.pattern
        return (LayerDesc(kind="attn", mlp="moe" if self.n_experts else "dense"),)

    @property
    def n_blocks(self) -> int:
        pat = self.layer_pattern()
        assert self.n_layers % len(pat) == 0, (self.name, self.n_layers, len(pat))
        return self.n_layers // len(pat)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("skip: pure full-attention arch — 500k-token decode "
                       "requires sub-quadratic attention (DESIGN.md §6)")
    return True, ""
