"""seamless-m4t-large-v2 [audio]: enc-dec 24L+24L d=1024 16H (kv=16) d_ff=8192
vocab=256206. Audio frontend is a STUB: input_specs() provides precomputed
frame embeddings (assignment rule). [arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab_size=256206, rope_theta=1e4,
    frontend="audio_frames", frontend_tokens=512,
)
