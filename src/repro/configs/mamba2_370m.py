"""mamba2-370m [ssm]: 48L d=1024 attn-free vocab=50280, ssm_state=128,
expand=2, headdim=64 — SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from .base import ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    pattern=(LayerDesc(kind="ssm", mlp="none"),),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=128,
    tie_embeddings=True,
)
