"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H, MLA kv_lora=512, MoE 64 routed
top-6 + 2 shared, expert d_ff=1408, first layer dense (d_ff=10944),
vocab=102400. NOTE: assignment line says both '64e' and '160 routed'; the HF
v2-lite checkpoint has 64 routed + 2 shared — we follow 64 (DESIGN.md §6).
[arXiv:2405.04434; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=10944, vocab_size=102400,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1, rope_theta=1e4,
)
