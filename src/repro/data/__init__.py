from .pipeline import DataConfig, batch_at, iterate
