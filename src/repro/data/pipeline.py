"""Deterministic synthetic token pipeline, shardable across hosts.

Real deployments stream tokenized shards; here the substrate provides the same
interface backed by a counter-based PRNG (stateless → any host can produce any
batch index, which is what makes the pipeline elastic and restart-safe: the
data state IS the step counter, carried by the checkpoint).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    frontend_tokens: int = 0
    d_model: int = 0          # for frontend embeds


def batch_at(cfg: DataConfig, step: int,
             host_id: int = 0, n_hosts: int = 1) -> Dict[str, jnp.ndarray]:
    """Batch for `step`, restricted to this host's shard (host-data-parallel)."""
    per_host = cfg.global_batch // n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))
    # zipf-ish marginal: realistic token frequency skew
    z = rng.zipf(1.3, size=(per_host, cfg.seq_len)).astype(np.int64)
    tokens = (z % (cfg.vocab_size - 2)) + 2
    out = {"tokens": jnp.asarray(tokens, jnp.int32),
           "labels": jnp.asarray(tokens, jnp.int32)}
    if cfg.frontend_tokens:
        fe = rng.standard_normal((per_host, cfg.frontend_tokens,
                                  cfg.d_model)).astype(np.float32)
        out["frontend_embeds"] = jnp.asarray(fe)
    return out


def iterate(cfg: DataConfig, start_step: int = 0,
            host_id: int = 0, n_hosts: int = 1) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step, host_id, n_hosts)
        step += 1
