"""jit'd wrappers around the Pallas kernels (sort, pack, column-map build, unsort)."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import grid, morton
from . import collision_force as k1
from . import flash_attention as k2

BLOCK = k1.BLOCK


# ---------------------------------------------------------------------------
# K1: collision force
# ---------------------------------------------------------------------------

def build_block_cols(sorted_cells: jnp.ndarray,      # (Npad, 3) int32 cells (sorted order)
                     starts: jnp.ndarray,            # (M,) per-box first sorted index
                     counts: jnp.ndarray,            # (M,)
                     row_active: jnp.ndarray,        # (Npad,) bool — needs own force
                     dims: Tuple[int, int, int],
                     maxb: int,
                     span: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block-sparse column map: for each 128-row block, the unique 128-wide
    column blocks covering all stencil neighbor ranges of its *active* rows.

    With the row-major linear key layout the 3×3×3 stencil is **9 merged
    ranges** (contiguous z-runs of ≤3 boxes) per row instead of 27 single-box
    ranges: 3× fewer range lookups, a 3× narrower sort when deduplicating
    block ids, and merged ranges share block boundaries — a tighter map with
    fewer ``pl.when``-skipped tiles (DESIGN.md §3.3).

    Fully-static row blocks get an empty column list — the kernel then skips
    them entirely (paper §5 static regions at block granularity).

    Returns (block_cols (n_row_blocks, maxb) int32 with -1 padding, overflow
    flag ()). ``span`` bounds blocks per merged range (covers z-runs of
    ≤ span·128 agents).
    """
    n_pad = sorted_cells.shape[0]
    n_rb = n_pad // BLOCK
    xy_off = jnp.asarray(k1_run_offsets(), jnp.int32)         # (9, 2)
    sentinel = jnp.int32(2 ** 30)

    def per_row_block(i):
        rows = i * BLOCK + jnp.arange(BLOCK, dtype=jnp.int32)
        cell = sorted_cells[rows]                              # (128, 3)
        act = row_active[rows]
        nx = cell[:, None, 0] + xy_off[None, :, 0]             # (128, 9)
        ny = cell[:, None, 1] + xy_off[None, :, 1]
        inside = ((nx >= 0) & (nx < dims[0]) & (ny >= 0) & (ny < dims[1]))
        nx = jnp.clip(nx, 0, dims[0] - 1)
        ny = jnp.clip(ny, 0, dims[1] - 1)
        z_lo = jnp.maximum(cell[:, 2] - 1, 0)[:, None]
        z_hi = jnp.minimum(cell[:, 2] + 1, dims[2] - 1)[:, None]
        k_lo = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_lo, nx.shape),
                                     dims)
        k_hi = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_hi, nx.shape),
                                     dims)
        s = starts[k_lo]                                       # (128, 9)
        e = starts[k_hi] + counts[k_hi]
        n = jnp.where(inside & act[:, None], e - s, 0)
        b0 = s // BLOCK
        b_last = jnp.where(n > 0, (s + n - 1) // BLOCK, -1)
        ks = jnp.arange(span, dtype=jnp.int32)
        cand = b0[..., None] + ks                              # (128, 9, span)
        ok = (n[..., None] > 0) & (cand <= b_last[..., None])
        ids = jnp.where(ok, cand, sentinel).reshape(-1)
        ids = jnp.sort(ids)
        uniq = jnp.concatenate([jnp.ones((1,), bool), ids[1:] != ids[:-1]])
        uniq &= ids < sentinel
        pos = jnp.cumsum(uniq.astype(jnp.int32)) - 1
        n_uniq = jnp.sum(uniq.astype(jnp.int32))
        out = jnp.full((maxb,), -1, jnp.int32)
        write = jnp.where(uniq & (pos < maxb), pos, maxb)
        out = out.at[write].set(ids.astype(jnp.int32), mode="drop")
        # span overflow: a merged range longer than span blocks would be cut
        span_ovf = jnp.any((b_last - b0 + 1) > span)
        return out, (n_uniq > maxb) | span_ovf

    cols, ovf = jax.lax.map(per_row_block,
                            jnp.arange(n_rb, dtype=jnp.int32),
                            batch_size=min(64, max(n_rb, 1)))
    return cols, jnp.any(ovf)


def k1_run_offsets():
    import numpy as np
    return np.array([(dx, dy) for dx in (-1, 0, 1) for dy in (-1, 0, 1)],
                    dtype=np.int32)


def build_block_cols_from_pairs(pairs: "grid.PairList",
                                row_active: jnp.ndarray,   # (Npad,) bool
                                n_pad: int,
                                maxb: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block-sparse column map derived from a Verlet pair list (grid.PairList)
    instead of the stencil run ranges.

    For each 128-row block, the unique ascending column blocks are
    ``idx // BLOCK`` over every stored candidate of its active rows — a
    subset of what :func:`build_block_cols` would emit, since only blocks
    actually holding an in-range(+skin) candidate survive. The K1 kernel is
    unchanged: it re-tests the radius in-kernel and accumulates column blocks
    sequentially, and a dropped block's contribution is the additive identity
    (every lane masked to +0.0), so the pruned ascending map reproduces the
    streamed map's accumulation bit-exactly while skipping the ~6× of tiles
    that carry no interacting pair.

    Returns (block_cols (n_row_blocks, maxb) int32 with -1 padding, overflow
    flag ()) — same contract as build_block_cols.
    """
    c, p = pairs.idx.shape
    n_rb = n_pad // BLOCK
    sentinel = jnp.int32(2 ** 30)
    lane = jnp.arange(p, dtype=jnp.int32)

    def per_row_block(i):
        rows = i * BLOCK + jnp.arange(BLOCK, dtype=jnp.int32)
        safe_rows = jnp.minimum(rows, c - 1)              # Npad ≥ c padding
        in_pool = rows < c
        act = row_active[rows] & in_pool
        idx_b = pairs.idx[safe_rows]                      # (128, P)
        stored = lane[None, :] < pairs.run_off[safe_rows, -1:]
        ok = stored & act[:, None]
        ids = jnp.where(ok, idx_b // BLOCK, sentinel).reshape(-1)
        ids = jnp.sort(ids)
        uniq = jnp.concatenate([jnp.ones((1,), bool), ids[1:] != ids[:-1]])
        uniq &= ids < sentinel
        pos = jnp.cumsum(uniq.astype(jnp.int32)) - 1
        n_uniq = jnp.sum(uniq.astype(jnp.int32))
        out = jnp.full((maxb,), -1, jnp.int32)
        write = jnp.where(uniq & (pos < maxb), pos, maxb)
        out = out.at[write].set(ids.astype(jnp.int32), mode="drop")
        return out, n_uniq > maxb

    cols, ovf = jax.lax.map(per_row_block,
                            jnp.arange(n_rb, dtype=jnp.int32),
                            batch_size=min(64, max(n_rb, 1)))
    return cols, jnp.any(ovf)


def collision_force_resident(position: jnp.ndarray, diameter: jnp.ndarray,
                             agent_type: jnp.ndarray, alive: jnp.ndarray,
                             active: jnp.ndarray,
                             starts: jnp.ndarray, counts: jnp.ndarray,
                             origin: jnp.ndarray, box_size: jnp.ndarray,
                             *, dims: Tuple[int, int, int], k_rep: float = 2.0,
                             adhesion: Optional[Tuple[Tuple[float, ...], ...]] = None,
                             adhesion_band: float = 0.4, maxb: int = 64,
                             interpret: Optional[bool] = None,
                             pairs: Optional["grid.PairList"] = None
                             ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """K1 over the RESIDENT grid-ordered pool: column map → kernel. No sort,
    no unsort, no candidate matrix.

    ``interpret=None`` resolves per backend: native Mosaic on TPU, interpret
    mode elsewhere (CPU CI, the shard_map host-device parity tests). Both
    engines call through here — the distributed slabs run the identical
    kernel on their local resident pool.

    Inputs must already be in grid-key order with the grid's per-box
    ``(starts, counts)`` tables (grid.build_resident) — the engine's resident
    layout means the op shares the step's one permutation instead of paying
    its own argsort and inverse scatter. The kernel traverses each row
    block's 9 merged stencil runs through the scalar-prefetched block column
    table (build_block_cols); candidates are never materialized — each grid
    step streams one 128-wide column tile through VMEM.

    active: agents whose own force is required (alive & ~static). Static
    agents still *contribute* force to active neighbors (columns, not rows);
    fully-static row blocks get an empty column list and are skipped outright
    (paper §5 at block granularity). Returns (force (C,3) f32 in resident
    order, nnz (C,) i32, column-map overflow flag ()).

    Exactness contract (same as the engine grid, paper §3.1): ``box_size``
    must be ≥ the maximum interaction distance max(r_i + r_j) +
    adhesion_band, so every interacting pair falls inside the 3×3×3
    neighborhood.

    ``pairs`` (grid.PairList, optional): derive the column map from the
    Verlet pair list instead of the stencil ranges — only column blocks that
    hold a listed in-range(+skin) candidate are visited. Bit-exact vs the
    streamed map (build_block_cols_from_pairs); validity is the engine's
    2·pair_disp ≤ skin budget.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = position.shape[0]
    n_pad = ((c + BLOCK - 1) // BLOCK) * BLOCK
    pad = n_pad - c

    def padded(x, fill):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill)

    sp = padded(position, 0.0)
    sd = padded(diameter, 0.0)
    st = padded(agent_type, 0)
    sa = padded(alive, False)
    sact = padded(active & alive, False)

    if pairs is not None:
        # Verlet pair-list mode: column blocks come from the listed
        # candidates, not the full stencil ranges (build_block_cols_from_pairs
        # — bit-exact pruning, the kernel itself is unchanged)
        block_cols, ovf = build_block_cols_from_pairs(pairs, sact, n_pad, maxb)
    else:
        cells = morton.cell_of(sp, origin, box_size, dims)
        block_cols, ovf = build_block_cols(cells, starts, counts, sact, dims,
                                           maxb)

    data_t = jnp.zeros((8, n_pad), jnp.float32)
    data_t = data_t.at[k1.ROW_X].set(sp[:, 0]).at[k1.ROW_Y].set(sp[:, 1])
    data_t = data_t.at[k1.ROW_Z].set(sp[:, 2]).at[k1.ROW_DIA].set(sd)
    data_t = data_t.at[k1.ROW_TYPE].set(st.astype(jnp.float32))
    data_t = data_t.at[k1.ROW_ALIVE].set(sa.astype(jnp.float32))

    out_t = k1.collision_force_kernel(
        data_t, block_cols, k_rep=k_rep, adhesion=adhesion,
        adhesion_band=adhesion_band, interpret=interpret)

    force = jnp.stack([out_t[k1.ROW_FX], out_t[k1.ROW_FY], out_t[k1.ROW_FZ]],
                      axis=-1)[:c]
    nnz = out_t[k1.ROW_NNZ][:c].astype(jnp.int32)
    # rows that were inactive produced zeros; also zero anything masked
    force = jnp.where(sact[:c, None], force, 0.0)
    nnz = jnp.where(sact[:c], nnz, 0)
    return force, nnz, ovf


def fused_resident_sweep(spec, grid_env, channels, kernels, default_mask,
                         *, origin: jnp.ndarray, box_size: jnp.ndarray,
                         k_rep: float = 2.0,
                         adhesion: Optional[Tuple[Tuple[float, ...], ...]] = None,
                         adhesion_band: float = 0.4,
                         chunk: Optional[int] = None,
                         pvary_axes: Tuple[str, ...] = (),
                         maxb: int = 64,
                         interpret: Optional[bool] = None,
                         pairs: Optional["grid.PairList"] = None):
    """Pallas-backed realization of the fused kernel-list sweep.

    Accepts the same ``grid.PairKernel`` registry as
    ``grid.resident_apply_fused``. The kernel named ``"force"`` runs in the
    K1 windowed Pallas kernel — already a single in-kernel pass over the
    resident tables with its (position, diameter, agent_type, alive)
    footprint packed into the (8, N) lane layout, so fusion for it means
    staying inside the kernel. Every other registered kernel shares ONE
    pruned XLA resident sweep over the same tables (arbitrary pair_fns don't
    lower into K1's fixed row layout). The force kernel's ``pair_fn`` is not
    invoked — K1 computes the same functional form (parity vs the XLA pair
    path is covered by tests/test_resident.py).

    Returns ``(results, ovf)``: results keyed like resident_apply_fused,
    ovf the K1 column-map overflow flag (zeros(()) when no force kernel).
    """
    results = {}
    ovf = jnp.zeros((), jnp.int32)
    force_kernels = [k for k in kernels if k.name == "force"]
    rest = [k for k in kernels if k.name != "force"]
    if force_kernels:
        fk = force_kernels[0]
        active = fk.query_mask if fk.query_mask is not None else default_mask
        f, nnz, k_ovf = collision_force_resident(
            channels["position"], channels["diameter"],
            channels["agent_type"], channels["alive"], active,
            grid_env.starts, grid_env.counts, origin, box_size,
            dims=spec.dims, k_rep=k_rep, adhesion=adhesion,
            adhesion_band=adhesion_band, maxb=maxb, interpret=interpret,
            pairs=pairs)
        results["force"] = {"force": f, "force_nnz": nnz}
        ovf = k_ovf
    if rest:
        results.update(grid.resident_apply_fused(
            spec, grid_env, channels, rest, default_mask, chunk,
            pvary_axes=pvary_axes, pairs=pairs))
    return results, ovf


@functools.partial(jax.jit, static_argnames=(
    "dims", "k_rep", "adhesion", "adhesion_band", "maxb", "interpret"))
def collision_force(position: jnp.ndarray, diameter: jnp.ndarray,
                    agent_type: jnp.ndarray, alive: jnp.ndarray,
                    active: jnp.ndarray,
                    origin: jnp.ndarray, box_size: jnp.ndarray,
                    *, dims: Tuple[int, int, int], k_rep: float = 2.0,
                    adhesion: Optional[Tuple[Tuple[float, ...], ...]] = None,
                    adhesion_band: float = 0.4, maxb: int = 64,
                    interpret: Optional[bool] = None
                    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Slot-order compat wrapper: linear-key sort → resident core → unsort.

    For callers whose arrays are NOT already grid-ordered. The engine never
    uses this — its pool is resident (grid.build_resident) and it calls
    :func:`collision_force_resident` with the step's existing grid tables.
    Same contract and returns, in the caller's slot order.
    """
    c = position.shape[0]
    keys = morton.grid_sort_keys(position, alive, origin, box_size, dims)
    order = grid.counting_sort_order(keys, morton.linear_size(dims))
    sorted_keys = keys[order]

    starts, counts = grid.box_tables(sorted_keys, morton.linear_size(dims))

    f_sorted, nnz_sorted, ovf = collision_force_resident(
        position[order], diameter[order], agent_type[order], alive[order],
        (active & alive)[order], starts, counts, origin, box_size,
        dims=dims, k_rep=k_rep, adhesion=adhesion,
        adhesion_band=adhesion_band, maxb=maxb, interpret=interpret)

    force = jnp.zeros((c, 3), jnp.float32).at[order].set(f_sorted)
    nnz = jnp.zeros((c,), jnp.int32).at[order].set(nnz_sorted)
    return force, nnz, ovf


# ---------------------------------------------------------------------------
# K2: flash attention
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Padding-safe wrapper: pads Sq/Sk to block multiples, masks, unpads."""
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, max(16, 1 << (sq - 1).bit_length() if sq > 1 else 16))
    block_k = min(block_k, max(16, 1 << (sk - 1).bit_length() if sk > 1 else 16))
    sq_pad = ((sq + block_q - 1) // block_q) * block_q
    sk_pad = ((sk + block_k - 1) // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    out = k2.flash_attention_kernel(qp, kp, vp, causal=causal, scale=scale,
                                    block_q=block_q, block_k=block_k,
                                    sk_actual=sk, kv_offset=sk - sq,
                                    interpret=interpret)
    return out[:, :, :sq, :]
