"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def collision_force_ref(position: jnp.ndarray, diameter: jnp.ndarray,
                        agent_type: jnp.ndarray, alive: jnp.ndarray,
                        k_rep: float, adhesion: tuple | None,
                        adhesion_band: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense O(N²) Cortex3D force (same math as core.forces.pair_force).

    Returns (force (N,3), nnz (N,) int32). Only pairs with both endpoints alive
    interact; self-pairs excluded. ``adhesion`` is a nested tuple (T,T) or None.
    """
    n = position.shape[0]
    d = position[None, :, :] - position[:, None, :]           # (N, N, 3) q->n
    dist = jnp.sqrt(jnp.maximum(jnp.sum(d * d, -1), 1e-18))
    r_q = diameter[:, None] * 0.5
    r_n = diameter[None, :] * 0.5
    delta = r_q + r_n - dist
    r_eff = jnp.maximum(r_q * r_n / jnp.maximum(r_q + r_n, 1e-12), 1e-12)
    f_rep = k_rep * jnp.sqrt(r_eff) * jnp.power(jnp.maximum(delta, 0.0), 1.5)
    if adhesion is not None:
        adh = jnp.asarray(adhesion, jnp.float32)
        mu = adh[agent_type[:, None], agent_type[None, :]]
        band = jnp.maximum(delta + adhesion_band, 0.0)
        f_adh = jnp.where(delta + adhesion_band > 0.0,
                          mu * jnp.sqrt(r_eff * band), 0.0)
    else:
        f_adh = 0.0
    f_mag = f_rep - f_adh
    valid = (alive[:, None] & alive[None, :]
             & ~jnp.eye(n, dtype=bool)
             & (delta + adhesion_band > 0.0))
    direction = d / dist[..., None]
    pair = jnp.where(valid[..., None], -f_mag[..., None] * direction, 0.0)
    force = jnp.sum(pair, axis=1)
    nnz = jnp.sum(jnp.sum(pair * pair, -1) > (1e-7) ** 2, axis=1).astype(jnp.int32)
    return force, nnz


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, scale: float | None = None
                        ) -> jnp.ndarray:
    """Reference softmax attention with GQA broadcast.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if causal:
        # supports Sq == Sk (training/prefill) and Sq < Sk (chunked) with the
        # query block aligned to the *end* of the key sequence
        qpos = jnp.arange(sq) + (sk - sq)
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
