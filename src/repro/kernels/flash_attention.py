"""K2: flash attention (online-softmax tiling) — Pallas TPU kernel.

Prefill attention at 32k tokens is the LM substrate's compute hot spot; the
full (Sq × Sk) score matrix never fits VMEM, so we tile with the standard
online-softmax recurrence (running row-max m, normalizer l, accumulator acc).

Grid: (batch, q_heads, Sq/Bq, Sk/Bk) with the key axis innermost; causal
blocks strictly above the diagonal are skipped via ``pl.when`` (block-level
work elision, the same mechanism K1 uses for static regions). GQA is handled
in the BlockSpec index map: query head h reads KV head h // group.

Validated in interpret mode on CPU (the container has no TPU); on TPU pass
interpret=False. Numerics: fp32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, sk_actual: int,
                  block_q: int, block_k: int, kv_offset: int):
    i_q = pl.program_id(2)
    i_k = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: query block rows span [qlo, qhi]; keys start at klo.
    qhi = (i_q + 1) * block_q - 1 + kv_offset
    klo = i_k * block_k
    should = (klo <= qhi) if causal else True

    @pl.when(should)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32)            # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (Bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = klo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < sk_actual                        # key padding
        if causal:
            qpos = i_q * block_q + kv_offset + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (Bq, 128) replicated
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)                     # (Bq,)
        m_new = jnp.maximum(m_prev[:, 0], m_cur)
        alpha = jnp.exp(m_prev[:, 0] - m_new)          # (Bq,)
        p = jnp.exp(s - m_new[:, None])                # (Bq, Bk)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev[:, 0] * alpha + jnp.sum(p, axis=1)
        acc = acc_scr[...] * alpha[:, None]
        acc += jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)
        acc_scr[...] = acc

    @pl.when(i_k == n_k - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           sk_actual: int | None = None,
                           kv_offset: int | None = None,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0.

    Sq/Sk must be multiples of block_q/block_k (ops.flash_attention pads).
    ``sk_actual`` masks trailing key padding; ``kv_offset`` is the causal
    position of query row 0 (defaults to sk_actual - Sq; pass the *unpadded*
    offset when Sq was padded).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    sk_actual = sk if sk_actual is None else sk_actual
    if kv_offset is None:
        kv_offset = sk_actual - sq          # query block aligned to sequence end
    n_q, n_k = sq // block_q, sk // block_k

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, sk_actual=sk_actual,
        block_q=block_q, block_k=block_k, kv_offset=kv_offset)

    return pl.pallas_call(
        kern,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
