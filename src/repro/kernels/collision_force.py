"""K1: tiled pairwise collision force — Pallas TPU kernel.

The paper identifies the pairwise mechanical force as the dominant cost (§5).
On TPU we exploit the resident grid-key layout (row-major linear keys,
DESIGN.md §3): the pool arrives already in key order (grid.build_resident),
so each grid box — and each 3-box z-run of the stencil — is contiguous, and
the candidate neighbors of a *block* of 128 consecutive agents live in a
small set of 128-wide column blocks. The engine derives a scalar-prefetched
run table — the block-sparse column map of the 9 merged stencil runs
(ops.build_block_cols) — and the kernel traverses it per row block,
(row_block × listed col_blocks), computing a 128×128 pairwise force tile in
VMEM per step: candidates are never materialized in HBM —
flash-attention-like structure with VPU math instead of MXU.

Correctness does not depend on the column map being tight: any pair within the
interaction radius is necessarily inside the 27-box neighborhood (box ≥ radius),
and the map covers those ranges, so extra candidates are masked by the radius
test. Sentinel (-1) column entries are skipped with ``pl.when`` — the same
block-skipping mechanism that realizes the paper's static-region optimization
at block granularity (DESIGN.md §2/O6).

Data layout (TPU-friendly): agents are packed along *lanes*:
  data_t: (8, N_pad) f32 rows = [x, y, z, diameter, type, alive, 0, 0]
  out_t:  (8, N_pad) f32 rows = [fx, fy, fz, nnz, 0, 0, 0, 0]
so each (8, 128) tile is one native VREG tile set.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 128
ROW_X, ROW_Y, ROW_Z, ROW_DIA, ROW_TYPE, ROW_ALIVE = 0, 1, 2, 3, 4, 5
ROW_FX, ROW_FY, ROW_FZ, ROW_NNZ = 0, 1, 2, 3


def _force_tile(row: jnp.ndarray, col: jnp.ndarray,
                row_base: jnp.ndarray, col_base: jnp.ndarray,
                k_rep: float, adhesion: Optional[Tuple[Tuple[float, ...], ...]],
                adhesion_band: float) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(8,128) row tile × (8,128) col tile → per-row (fx, fy, fz, nnz)."""
    rx, ry, rz = row[ROW_X], row[ROW_Y], row[ROW_Z]        # (128,)
    cx, cy, cz = col[ROW_X], col[ROW_Y], col[ROW_Z]
    dx = cx[None, :] - rx[:, None]                          # (128,128) q->n
    dy = cy[None, :] - ry[:, None]
    dz = cz[None, :] - rz[:, None]
    dist2 = dx * dx + dy * dy + dz * dz
    dist = jnp.sqrt(jnp.maximum(dist2, 1e-18))
    r_q = row[ROW_DIA][:, None] * 0.5
    r_n = col[ROW_DIA][None, :] * 0.5
    delta = r_q + r_n - dist
    r_eff = jnp.maximum(r_q * r_n / jnp.maximum(r_q + r_n, 1e-12), 1e-12)
    f_rep = k_rep * jnp.sqrt(r_eff) * jnp.power(jnp.maximum(delta, 0.0), 1.5)
    if adhesion is not None:
        # tiny type-count: unroll the (T,T) adhesion table as select terms
        t = len(adhesion)
        ti = row[ROW_TYPE][:, None]
        tj = col[ROW_TYPE][None, :]
        mu = jnp.zeros_like(dist)
        for a in range(t):
            for b in range(t):
                coeff = adhesion[a][b]
                if coeff != 0.0:
                    mu += coeff * ((ti == a) & (tj == b)).astype(dist.dtype)
        band = jnp.maximum(delta + adhesion_band, 0.0)
        f_adh = jnp.where(delta + adhesion_band > 0.0, mu * jnp.sqrt(r_eff * band), 0.0)
    else:
        f_adh = jnp.zeros_like(dist)
    f_mag = f_rep - f_adh

    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (BLOCK, BLOCK), 0)
    col_ids = col_base + jax.lax.broadcasted_iota(jnp.int32, (BLOCK, BLOCK), 1)
    valid = ((row[ROW_ALIVE][:, None] > 0.5) & (col[ROW_ALIVE][None, :] > 0.5)
             & (row_ids != col_ids) & (delta + adhesion_band > 0.0))
    f = jnp.where(valid, -f_mag, 0.0)
    inv = 1.0 / dist
    fx = jnp.sum(f * dx * inv, axis=1)
    fy = jnp.sum(f * dy * inv, axis=1)
    fz = jnp.sum(f * dz * inv, axis=1)
    mag2 = f * f
    nnz = jnp.sum((mag2 > (1e-7) ** 2).astype(jnp.float32), axis=1)
    return fx, fy, fz, nnz


def _kernel(cols_ref,            # scalar prefetch: (n_row_blocks, maxb) int32
            data_row_ref,        # (8, BLOCK) row agents
            data_col_ref,        # (8, BLOCK) candidate col agents
            out_ref,             # (8, BLOCK) accumulated [fx fy fz nnz ...]
            *, k_rep: float, adhesion, adhesion_band: float):
    i = pl.program_id(0)
    j = pl.program_id(1)
    col_id = cols_ref[i, j]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(col_id >= 0)
    def _accum():
        row = data_row_ref[...]
        col = data_col_ref[...]
        fx, fy, fz, nnz = _force_tile(
            row, col, i * BLOCK, col_id * BLOCK,
            k_rep, adhesion, adhesion_band)
        acc = out_ref[...]
        upd = jnp.zeros_like(acc)
        upd = upd.at[ROW_FX].set(fx).at[ROW_FY].set(fy)
        upd = upd.at[ROW_FZ].set(fz).at[ROW_NNZ].set(nnz)
        out_ref[...] = acc + upd


def collision_force_kernel(data_t: jnp.ndarray,
                           block_cols: jnp.ndarray,
                           *, k_rep: float,
                           adhesion: Optional[Tuple[Tuple[float, ...], ...]],
                           adhesion_band: float,
                           interpret: bool = True) -> jnp.ndarray:
    """Run the kernel. data_t: (8, N_pad); block_cols: (N_pad/128, MAXB) int32.

    Returns out_t (8, N_pad): rows [fx, fy, fz, nnz]. The container is CPU-only,
    so interpret=True is the validated path; on TPU pass interpret=False.
    """
    n_pad = data_t.shape[1]
    n_row_blocks = n_pad // BLOCK
    maxb = block_cols.shape[1]
    kern = functools.partial(_kernel, k_rep=k_rep, adhesion=adhesion,
                             adhesion_band=adhesion_band)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_row_blocks, maxb),
            in_specs=[
                pl.BlockSpec((8, BLOCK), lambda i, j, cols: (0, i)),
                pl.BlockSpec((8, BLOCK),
                             lambda i, j, cols: (0, jnp.maximum(cols[i, j], 0))),
            ],
            out_specs=pl.BlockSpec((8, BLOCK), lambda i, j, cols: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((8, n_pad), jnp.float32),
        interpret=interpret,
    )(block_cols, data_t, data_t)
