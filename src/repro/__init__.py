"""repro — BioDynaMo-JAX: TPU-native high-performance agent-based simulation engine.

Reproduction (and TPU adaptation) of:
  "High-Performance and Scalable Agent-Based Simulation with BioDynaMo"
  Breitwieser, Hesam, Rademakers, Gomez-Luna, Mutlu (CS.DC 2023)

Package layout:
  repro.core      -- the paper's engine (grid neighbor search, Morton sort,
                     parallel add/remove, static-region detection, forces)
  repro.kernels   -- Pallas TPU kernels for perf-critical hot spots
  repro.models    -- LM substrate for the assigned architecture pool
  repro.configs   -- architecture configs (10 assigned + ABM-native)
  repro.train     -- optimizer / train_step / checkpointing
  repro.serve     -- paged KV cache + decode + continuous batching
  repro.launch    -- mesh, multi-pod dry-run, drivers
  repro.roofline  -- roofline analysis from compiled HLO
"""

__version__ = "1.0.0"
