"""Encoder-decoder backbone (seamless-m4t): audio-frame encoder + text decoder.

The audio frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model); the encoder is a non-causal
transformer stack over them. The decoder is a causal stack with cross-attention
whose K/V are cached at prefill (decode never re-encodes).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerDesc
from . import attention as attn_mod
from .layers import ParamSet, cross_entropy, rms_norm
from .lm import _dtype, apply_pattern_block, register_pattern_block


class EncDecLM:
    def __init__(self, cfg: ArchConfig, attn_impl: str = "xla",
                 unroll_scan: bool = False):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.unroll = unroll_scan
        self.pdt = _dtype(cfg.param_dtype)
        self.adt = _dtype(cfg.activation_dtype)
        self.pat = (LayerDesc(kind="attn", mlp="dense"),)

        self.v_pad = ((cfg.vocab_size + 127) // 128) * 128
        ps = ParamSet(dtype=self.pdt)
        ps.add("embed/tokens", (self.v_pad, cfg.d_model), ("tp", "fsdp"))
        register_pattern_block(ps, "enc_blocks", cfg, self.pat,
                               (cfg.encoder_layers,))
        ps.add("enc_norm", (cfg.d_model,), (None,), init="ones")
        register_pattern_block(ps, "dec_blocks", cfg, self.pat,
                               (cfg.n_layers,), cross=True)
        ps.add("final_norm", (cfg.d_model,), (None,), init="ones")
        ps.add("lm_head", (cfg.d_model, self.v_pad), ("fsdp", "tp"))
        self.ps = ps

    def init_params(self, rng):
        return self.ps.init_params(rng)

    def n_params(self) -> int:
        return self.ps.n_params()

    # -- encoder -------------------------------------------------------------
    def encode(self, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = frames.astype(self.adt)

        def block_fn(carry, p_block):
            xx, _ = carry
            xx, aux, _ = apply_pattern_block(p_block, xx, cfg, self.pat,
                                             "full", causal=False,
                                             attn_impl=self.attn_impl)
            return (xx, aux), ()

        if cfg.remat != "none":
            block_fn = jax.checkpoint(block_fn)
        (x, _), _ = jax.lax.scan(block_fn, (x, jnp.zeros((), jnp.float32)),
                                 params["enc_blocks"], unroll=self.unroll)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder -------------------------------------------------------------
    def _decode_full(self, params: Dict, tokens: jnp.ndarray,
                     enc_out: jnp.ndarray, want_cache: bool
                     ) -> Tuple[jnp.ndarray, Tuple]:
        cfg = self.cfg
        x = params["embed"]["tokens"][tokens].astype(self.adt)

        def block_fn(carry, p_block):
            xx, _ = carry
            xx, aux, c = apply_pattern_block(
                p_block, xx, cfg, self.pat, "full", enc_out=enc_out,
                cross=True, attn_impl=self.attn_impl, want_cache=want_cache)
            return (xx, aux), c

        if cfg.remat != "none":
            block_fn = jax.checkpoint(block_fn)
        (x, _), caches = jax.lax.scan(block_fn,
                                      (x, jnp.zeros((), jnp.float32)),
                                      params["dec_blocks"], unroll=self.unroll)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        if self.v_pad != cfg.vocab_size:
            col = jnp.arange(self.v_pad)
            logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
        return logits, caches

    # -- public API ------------------------------------------------------------
    def train_loss(self, params: Dict, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        enc_out = self.encode(params, batch["frontend_embeds"])
        logits, _ = self._decode_full(params, batch["tokens"], enc_out,
                                      want_cache=False)
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                           batch.get("loss_mask"))
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def prefill(self, params: Dict, tokens: jnp.ndarray,
                frontend_embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Tuple]:
        enc_out = self.encode(params, frontend_embeds)
        logits, caches = self._decode_full(params, tokens, enc_out,
                                           want_cache=True)
        return logits[:, -1], ([], caches)

    def decode_step(self, params: Dict, token: jnp.ndarray, caches: Tuple,
                    cur_len: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple]:
        cfg = self.cfg
        _, block_caches = caches
        x = params["embed"]["tokens"][token[:, None]].astype(self.adt)

        def block_fn(carry, inp):
            xx = carry
            p_block, cache = inp
            xx, _, c = apply_pattern_block(p_block, xx, cfg, self.pat,
                                           "decode", caches=cache,
                                           cur_len=cur_len, cross=True)
            return xx, c

        x, new_caches = jax.lax.scan(block_fn, x,
                                     (params["dec_blocks"], block_caches),
                                     unroll=self.unroll)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        if self.v_pad != cfg.vocab_size:
            col = jnp.arange(self.v_pad)
            logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
        return logits[:, 0], ([], new_caches)

    # -- caches ----------------------------------------------------------------
    def decode_cache_specs(self, batch: int, s_max: int, s_enc: int
                           ) -> Tuple[List, Tuple]:
        cfg = self.cfg
        kv = attn_mod.gqa_cache_spec(cfg, batch, s_max, self.adt)
        xshape = (batch, cfg.n_kv_heads, s_enc, cfg.d_head)
        spec = {**kv,
                "xk": jax.ShapeDtypeStruct(xshape, self.adt),
                "xv": jax.ShapeDtypeStruct(xshape, self.adt)}
        stacked = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((cfg.n_layers,) + sd.shape, sd.dtype),
            spec)
        return [], (stacked,)
