"""repro.models — LM substrate for the assigned architecture pool."""

from .lm import LM
from .encdec import EncDecLM
from .zoo import build_model, reduced_config

__all__ = ["LM", "EncDecLM", "build_model", "reduced_config"]
