"""Attention layers: GQA (optionally qk_norm / qkv bias) and DeepSeek MLA.

Two execution modes per layer:
  * full-sequence (train / prefill): softmax attention over the whole sequence
    (XLA einsum path by default; the Pallas flash kernel K2 is the TPU target
    for prefill — selected with attn_impl="pallas").
  * cached decode: one new token against a preallocated KV cache. For MLA the
    cache stores the *compressed* c_kv (+ rope key) — the memory win that makes
    MLA attractive at 32k context — and uses the absorbed-weight formulation.

KV caches are dense (B, S_max, ...) tensors here; the paged pool-allocator
cache (paper §4.3 transfer) lives in repro.serve.kv_cache and is exercised by
the serving substrate.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import ParamSet, hint, rms_norm, rope


# ---------------------------------------------------------------------------
# Parameter registration
# ---------------------------------------------------------------------------

def register_attn(ps: ParamSet, prefix: str, cfg: ArchConfig,
                  stack: Tuple[int, ...]) -> None:
    """GQA projection weights. ``stack`` is the leading scan dims (n_blocks,)."""
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = tuple(stack)
    ns = (None,) * len(s)
    ps.add(f"{prefix}/wq", s + (d, h * dh), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/wk", s + (d, hk * dh), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/wv", s + (d, hk * dh), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/wo", s + (h * dh, d), ns + ("tp", "fsdp"))
    if cfg.qkv_bias:
        ps.add(f"{prefix}/bq", s + (h * dh,), ns + ("tp",), init="zeros")
        ps.add(f"{prefix}/bk", s + (hk * dh,), ns + ("tp",), init="zeros")
        ps.add(f"{prefix}/bv", s + (hk * dh,), ns + ("tp",), init="zeros")
    if cfg.qk_norm:
        ps.add(f"{prefix}/q_norm", s + (dh,), ns + (None,), init="ones")
        ps.add(f"{prefix}/k_norm", s + (dh,), ns + (None,), init="ones")
    ps.add(f"{prefix}/norm", s + (d,), ns + (None,), init="ones")


def register_mla(ps: ParamSet, prefix: str, cfg: ArchConfig,
                 stack: Tuple[int, ...]) -> None:
    """DeepSeek-V2 MLA: compressed KV (kv_lora_rank) + decoupled rope key."""
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    s = tuple(stack)
    ns = (None,) * len(s)
    ps.add(f"{prefix}/wq", s + (d, h * (dn + dr)), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/w_dkv", s + (d, r), ns + ("fsdp", None))       # down proj
    ps.add(f"{prefix}/w_kpe", s + (d, dr), ns + ("fsdp", None))      # rope key
    ps.add(f"{prefix}/w_uk", s + (r, h * dn), ns + (None, "tp"))     # up: key
    ps.add(f"{prefix}/w_uv", s + (r, h * dv), ns + (None, "tp"))     # up: value
    ps.add(f"{prefix}/wo", s + (h * dv, d), ns + ("tp", "fsdp"))
    ps.add(f"{prefix}/norm", s + (d,), ns + (None,), init="ones")
    ps.add(f"{prefix}/kv_norm", s + (r,), ns + (None,), init="ones")


# ---------------------------------------------------------------------------
# Core softmax attention (XLA path; K2 pallas is the TPU prefill target)
# ---------------------------------------------------------------------------

def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool,
          kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B,H,Sq,Dh); k,v: (B,Hkv,Sk,Dh'). Returns (B,H,Sq,Dv)."""
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, dh)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (dh ** 0.5)
    kpos = jnp.arange(sk)
    neg = jnp.asarray(-1e30, jnp.float32)
    if causal:
        qpos = jnp.arange(sq) + (sk - sq)
        logits = jnp.where(kpos[None, :] <= qpos[:, None], logits, neg)
    if kv_len is not None:   # decode: mask unwritten cache slots
        logits = jnp.where(kpos[None, None, None, None, :] < kv_len, logits, neg)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, v.shape[-1]).astype(q.dtype)


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------

def gqa_full(p: Dict, x: jnp.ndarray, cfg: ArchConfig, causal: bool = True,
             positions: Optional[jnp.ndarray] = None, attn_impl: str = "xla"
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence GQA. Returns (output, kv_for_cache)."""
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = hint(jnp.einsum("bsd,de->bse", xn, p["wq"]), "batch", None, "tp")
    k = hint(jnp.einsum("bsd,de->bse", xn, p["wk"]), "batch", None, "tp")
    v = hint(jnp.einsum("bsd,de->bse", xn, p["wv"]), "batch", None, "tp")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q, k, v = _split_heads(q, h), _split_heads(k, hk), _split_heads(v, hk)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = positions if positions is not None else jnp.arange(s)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    if attn_impl == "pallas":
        from ..kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=causal, interpret=True)
    else:
        o = _sdpa(q, k, v, causal)
    out = jnp.einsum("bse,ed->bsd", _merge_heads(o), p["wo"])
    return x + hint(out, "batch", None, None), {"k": k, "v": v}


def gqa_decode(p: Dict, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cur_len: jnp.ndarray, cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, Hkv, S_max, Dh)."""
    b = x.shape[0]
    h, hk = cfg.n_heads, cfg.n_kv_heads
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", xn, p["wq"])
    k = jnp.einsum("bsd,de->bse", xn, p["wk"])
    v = jnp.einsum("bsd,de->bse", xn, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q, k, v = _split_heads(q, h), _split_heads(k, hk), _split_heads(v, hk)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = cur_len[None] if cur_len.ndim == 0 else cur_len
    q = rope(q, pos.reshape(1, 1, -1), cfg.rope_theta)
    k = rope(k, pos.reshape(1, 1, -1), cfg.rope_theta)
    kc = cache["k"].at[:, :, cur_len, :].set(k[:, :, 0, :])
    vc = cache["v"].at[:, :, cur_len, :].set(v[:, :, 0, :])
    o = _sdpa(q, kc, vc, causal=False, kv_len=cur_len + 1)
    out = jnp.einsum("bse,ed->bsd", _merge_heads(o), p["wo"])
    return x + out, {"k": kc, "v": vc}


def gqa_cache_spec(cfg: ArchConfig, batch: int, s_max: int, dtype
                   ) -> Dict[str, jax.ShapeDtypeStruct]:
    shp = (batch, cfg.n_kv_heads, s_max, cfg.d_head)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


# ---------------------------------------------------------------------------
# MLA layer (DeepSeek-V2): train/prefill materialized; decode absorbed
# ---------------------------------------------------------------------------

def mla_full(p: Dict, x: jnp.ndarray, cfg: ArchConfig, causal: bool = True
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, s, h, dn + dr)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    pos = jnp.arange(s)
    q_pe = rope(q_pe, pos, cfg.rope_theta)

    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", xn, p["w_dkv"]), p["kv_norm"],
                    cfg.norm_eps)                                   # (B,S,r)
    k_pe = rope(jnp.einsum("bsd,dr->bsr", xn, p["w_kpe"])[:, None, :, :],
                pos, cfg.rope_theta)                                # (B,1,S,dr)
    k_nope = jnp.einsum("bsr,re->bse", c_kv, p["w_uk"]).reshape(
        b, s, h, dn).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsr,re->bse", c_kv, p["w_uv"]).reshape(
        b, s, h, dv).transpose(0, 2, 1, 3)

    qf = jnp.concatenate([q_nope, q_pe], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, h, s, dr))], axis=-1)
    o = _sdpa(qf, kf, v, causal)
    out = jnp.einsum("bse,ed->bsd", _merge_heads(o), p["wo"])
    return x + out, {"c_kv": c_kv, "k_pe": k_pe[:, 0]}


def mla_decode(p: Dict, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cur_len: jnp.ndarray, cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed-weight MLA decode: cache holds compressed c_kv (B,S,r) and
    k_pe (B,S,dr) — 512+64 floats/token vs h*(dn+dv)=4096 for materialized KV:
    an 18× cache-memory reduction (the technique's raison d'être)."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, 1, h, dn + dr)
    q = q.transpose(0, 2, 1, 3)                                   # (B,h,1,dn+dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = rope(q_pe, cur_len.reshape(1, 1, 1), cfg.rope_theta)

    c_new = rms_norm(jnp.einsum("bsd,dr->bsr", xn, p["w_dkv"]), p["kv_norm"],
                     cfg.norm_eps)                                 # (B,1,r)
    kpe_new = rope(jnp.einsum("bsd,dr->bsr", xn, p["w_kpe"])[:, None],
                   cur_len.reshape(1, 1, 1), cfg.rope_theta)[:, 0]  # (B,1,dr)
    c_kv = cache["c_kv"].at[:, cur_len, :].set(c_new[:, 0])
    k_pe = cache["k_pe"].at[:, cur_len, :].set(kpe_new[:, 0])

    # absorb W_uk into the query:  score = (q_nope W_uk^T) · c_kv + q_pe · k_pe
    w_uk = p["w_uk"].reshape(r, h, dn)
    q_abs = jnp.einsum("bhsd,rhd->bhsr", q_nope, w_uk)             # (B,h,1,r)
    logits = (jnp.einsum("bhsr,btr->bhst", q_abs.astype(jnp.float32),
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bhsr,btr->bhst", q_pe.astype(jnp.float32),
                           k_pe.astype(jnp.float32))) / ((dn + dr) ** 0.5)
    mask = jnp.arange(c_kv.shape[1])[None, None, None, :] < cur_len + 1
    logits = jnp.where(mask, logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bhsr", pr, c_kv.astype(jnp.float32))  # (B,h,1,r)
    w_uv = p["w_uv"].reshape(r, h, dv)
    o = jnp.einsum("bhsr,rhv->bhsv", ctx, w_uv.astype(jnp.float32)
                   ).astype(x.dtype)                               # (B,h,1,dv)
    out = jnp.einsum("bse,ed->bsd", _merge_heads(o), p["wo"])
    return x + out, {"c_kv": c_kv, "k_pe": k_pe}


def mla_cache_spec(cfg: ArchConfig, batch: int, s_max: int, dtype
                   ) -> Dict[str, jax.ShapeDtypeStruct]:
    return {"c_kv": jax.ShapeDtypeStruct((batch, s_max, cfg.kv_lora_rank), dtype),
            "k_pe": jax.ShapeDtypeStruct((batch, s_max, cfg.qk_rope_dim), dtype)}
