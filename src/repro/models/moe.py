"""Mixture-of-Experts with capacity-bounded scatter dispatch.

Expert parallelism: expert tensors are sharded over the "tp"/model axis. The
dispatch is the *same machinery as the paper's parallel agent add/remove and
sorting* (§3.2/§4.2 — DESIGN.md §2): positions-in-expert come from a prefix sum
over token→expert assignments (the paper's prefix-sum slot reservation), and
tokens are scattered into fixed-capacity per-expert buffers (the paper's
fixed-capacity pools, O5). Tokens over capacity are dropped (standard GShard
semantics; the residual path carries them).

Under pjit, the scatter from data-sharded tokens into expert-sharded buffers
lowers to the expert all-to-all/all-gather pattern — visible in the roofline
collective term and a prime hillclimb target.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import ParamSet, hint, rms_norm, swiglu


def expert_axes(cfg: ArchConfig) -> Tuple[str, str]:
    """(expert-dim axis, ffn-dim axis) for 2D expert sharding.

    Experts shard over the fsdp axes and the expert FFN dim over tp — the
    partitioning under which the (e,c,d)×(e,d,f) einsums split cleanly on
    (e@fsdp, f@tp) with an UNSHARDED contraction dim. Sharding d (fsdp) here
    instead makes the contraction conflict and XLA falls back to fully
    replicated expert compute (256× FLOPs — observed in the baseline dry-run;
    EXPERIMENTS.md §Perf bring-up). Archs whose expert count does not divide
    the largest fsdp extent (jamba: 16 experts vs 32-way multi-pod fsdp) flip
    the assignment."""
    if cfg.moe_ffn_unsharded:
        return ("fsdp" if cfg.n_experts % 32 == 0 else "tp"), None
    if cfg.n_experts % 32 == 0:
        return "fsdp", "tp"
    return "tp", "fsdp"


def register_moe(ps: ParamSet, prefix: str, cfg: ArchConfig,
                 stack: Tuple[int, ...]) -> None:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    e_ax, f_ax = expert_axes(cfg)
    s = tuple(stack)
    ns = (None,) * len(s)
    ps.add(f"{prefix}/router", s + (d, e), ns + ("fsdp", None), std=0.006)
    ps.add(f"{prefix}/w_gate", s + (e, d, f), ns + (e_ax, None, f_ax))
    ps.add(f"{prefix}/w_up", s + (e, d, f), ns + (e_ax, None, f_ax))
    ps.add(f"{prefix}/w_down", s + (e, f, d), ns + (e_ax, f_ax, None))
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        ps.add(f"{prefix}/ws_gate", s + (d, fs), ns + ("fsdp", "tp"))
        ps.add(f"{prefix}/ws_up", s + (d, fs), ns + ("fsdp", "tp"))
        ps.add(f"{prefix}/ws_down", s + (fs, d), ns + ("tp", "fsdp"))
    ps.add(f"{prefix}/norm", s + (d,), ns + (None,), init="ones")


def capacity(tokens: int, cfg: ArchConfig) -> int:
    c = math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)     # pad to 8 for clean layouts


def moe_layer(p: Dict, x: jnp.ndarray, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D). Returns (output, router aux loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    xt = xn.reshape(b * s, d)
    t = b * s
    cap = capacity(t, cfg)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                    # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch/GShard): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                       # (E,)
    assign1 = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    fe = assign1.mean(axis=0)
    aux = e * jnp.sum(fe * me) * cfg.router_aux_coef

    # position-in-expert via prefix sum over the flattened (T*k) assignments —
    # the paper's §3.2 prefix-sum slot reservation, verbatim.
    flat_e = expert_idx.reshape(-1)                               # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot                 # before me
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap                                              # drop overflow
    pos_c = jnp.where(keep, pos, cap)                             # parked

    e_ax, f_ax = expert_axes(cfg)
    if cfg.moe_dispatch == "gather":
        # int32 slot→token map (tiny scatter); activations move as ONE gather
        # (lowers to a bf16 all-gather of xt instead of the f32 (E,cap,D)
        # scatter-psum — §Perf hillclimb iteration)
        token_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        slot_tok = jnp.zeros((e, cap + 1), jnp.int32).at[flat_e, pos_c].set(
            token_ids)
        slot_ok = jnp.zeros((e, cap + 1), bool).at[flat_e, pos_c].set(keep)
        buf = xt[slot_tok[:, :cap]] * slot_ok[:, :cap, None].astype(xt.dtype)
        buf = hint(buf, e_ax, None, None)
    else:
        # scatter tokens into (E, cap, D) expert buffers (fixed-capacity pools)
        src = jnp.repeat(xt, k, axis=0)                           # (T*k, D)
        buf = jnp.zeros((e, cap + 1, d), xt.dtype)
        buf = buf.at[flat_e, pos_c].add(src * keep[:, None].astype(xt.dtype))
        buf = hint(buf[:, :cap], e_ax, None, None)

    # expert FFN: 2D partition (e@e_ax, f@f_ax); contraction dim unsharded
    h = hint(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]), e_ax, None, f_ax)
    u = hint(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]), e_ax, None, f_ax)
    out_e = hint(jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"]),
                 e_ax, None, None)

    # combine: gather back and weight by the (renormalized) gate
    gathered = out_e[flat_e, jnp.minimum(pos_c, cap - 1)]         # (T*k, D)
    gathered *= (keep[:, None] * gate.reshape(-1)[:, None]).astype(xt.dtype)
    y = gathered.reshape(t, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + swiglu(xt, p["ws_gate"], p["ws_up"], p["ws_down"])

    return x + y.reshape(b, s, d), aux
