"""Config → model builder + reduced-config factory for smoke tests."""

from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, LayerDesc
from .encdec import EncDecLM
from .lm import LM


def build_model(cfg: ArchConfig, attn_impl: str = "xla",
                unroll_scan: bool = False):
    if cfg.encoder_layers > 0:
        return EncDecLM(cfg, attn_impl=attn_impl, unroll_scan=unroll_scan)
    return LM(cfg, attn_impl=attn_impl, unroll_scan=unroll_scan)


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Same family/topology, toy sizes — per the assignment: 'small layers/width,
    few experts, tiny embedding tables' — runnable on one CPU in seconds."""
    pat = cfg.layer_pattern()
    upd: dict = dict(
        name=cfg.name + "-reduced",
        n_layers=cfg.first_dense_layers + len(pat),
        d_model=64,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
        remat="none",
    )
    if cfg.n_heads:
        upd.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
                   d_head=16)
    if cfg.n_experts:
        # capacity_factor high enough that reduced-scale tests never drop
        # tokens (drops are legal GShard semantics but break exact
        # decode-vs-prefill equivalence checks)
        upd.update(n_experts=8, top_k=min(cfg.top_k, 2),
                   moe_d_ff=32, capacity_factor=4.0,
                   n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.mla:
        upd.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if any(ld.kind == "ssm" for ld in pat):
        upd.update(ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
                   ssm_chunk=16)
    if cfg.encoder_layers:
        upd.update(encoder_layers=2, n_layers=2)
    if cfg.frontend != "none":
        upd.update(frontend_tokens=8)
    return dataclasses.replace(cfg, **upd)
