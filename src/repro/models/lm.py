"""Unified decoder-only LM over repeating layer patterns.

One definition serves all assigned families:
  dense  — pattern [attn+dense]                     (qwen2/qwen3/yi/phi3v)
  moe    — prefix dense layers + [attn+moe]          (kimi-k2, deepseek-v2-lite)
  hybrid — pattern of 8: 7×ssm + 1×attn, moe on odd  (jamba)
  ssm    — pattern [ssm+none]                        (mamba2)

Layers are scanned over ``n_blocks`` repeats of the pattern (small HLO, fast
multi-pod compiles); the optional dense-MLP prefix layers (MoE archs) are
unscanned. Modality frontends (vision patches / audio frames) enter as
precomputed embeddings concatenated ahead of token embeddings (stub per the
assignment).

Modes:
  train(tokens, labels)        → mean CE + aux
  prefill(tokens[, embeds])    → last-position logits + decode caches
  decode(token, caches, len)   → next logits + updated caches
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerDesc
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import ParamSet, cross_entropy, hint, rms_norm, swiglu


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def register_mlp(ps: ParamSet, prefix: str, cfg: ArchConfig,
                 stack: Tuple[int, ...]) -> None:
    d, f = cfg.d_model, cfg.d_ff
    s = tuple(stack)
    ns = (None,) * len(s)
    ps.add(f"{prefix}/w_gate", s + (d, f), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/w_up", s + (d, f), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/w_down", s + (f, d), ns + ("tp", "fsdp"))
    ps.add(f"{prefix}/norm", s + (d,), ns + (None,), init="ones")


def mlp_layer(p: Dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    return x + swiglu(rms_norm(x, p["norm"], cfg.norm_eps),
                      p["w_gate"], p["w_up"], p["w_down"])


def register_pattern_block(ps: ParamSet, prefix: str, cfg: ArchConfig,
                           pattern: Tuple[LayerDesc, ...],
                           stack: Tuple[int, ...],
                           cross: bool = False) -> None:
    for i, ld in enumerate(pattern):
        pfx = f"{prefix}/l{i}"
        if ld.kind == "attn":
            if cfg.mla:
                attn_mod.register_mla(ps, f"{pfx}/attn", cfg, stack)
            else:
                attn_mod.register_attn(ps, f"{pfx}/attn", cfg, stack)
            if cross:
                attn_mod.register_attn(ps, f"{pfx}/xattn", cfg, stack)
        elif ld.kind == "ssm":
            ssm_mod.register_ssm(ps, f"{pfx}/ssm", cfg, stack)
        else:
            raise ValueError(ld.kind)
        if ld.mlp == "dense":
            register_mlp(ps, f"{pfx}/mlp", cfg, stack)
        elif ld.mlp == "moe":
            moe_mod.register_moe(ps, f"{pfx}/moe", cfg, stack)


def _cross_full(p: Dict, x: jnp.ndarray, enc_out: jnp.ndarray,
                cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Cross-attention (no rope, non-causal) against encoder output."""
    h, hk = cfg.n_heads, cfg.n_kv_heads
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = attn_mod._split_heads(jnp.einsum("bsd,de->bse", xn, p["wq"]), h)
    k = attn_mod._split_heads(jnp.einsum("bsd,de->bse", enc_out, p["wk"]), hk)
    v = attn_mod._split_heads(jnp.einsum("bsd,de->bse", enc_out, p["wv"]), hk)
    o = attn_mod._sdpa(q, k, v, causal=False)
    return x + jnp.einsum("bse,ed->bsd", attn_mod._merge_heads(o), p["wo"]), \
        {"xk": k, "xv": v}


def _cross_decode(p: Dict, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                  cfg: ArchConfig) -> jnp.ndarray:
    h = cfg.n_heads
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = attn_mod._split_heads(jnp.einsum("bsd,de->bse", xn, p["wq"]), h)
    o = attn_mod._sdpa(q, cache["xk"], cache["xv"], causal=False)
    return x + jnp.einsum("bse,ed->bsd", attn_mod._merge_heads(o), p["wo"])


def apply_pattern_block(p_block: Dict, x: jnp.ndarray, cfg: ArchConfig,
                        pattern: Tuple[LayerDesc, ...], mode: str,
                        caches: Optional[Tuple] = None,
                        cur_len: Optional[jnp.ndarray] = None,
                        enc_out: Optional[jnp.ndarray] = None,
                        cross: bool = False,
                        causal: bool = True,
                        attn_impl: str = "xla",
                        want_cache: bool = False
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, Tuple]:
    """Apply one pattern block. mode: "full" | "decode". Returns
    (x, aux_loss, new_caches)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches: List[Any] = []
    for i, ld in enumerate(pattern):
        lp = p_block[f"l{i}"]
        cache_i = caches[i] if caches is not None else None
        if ld.kind == "attn":
            if mode == "full":
                if cfg.mla:
                    x, c = attn_mod.mla_full(lp["attn"], x, cfg, causal=causal)
                else:
                    x, c = attn_mod.gqa_full(lp["attn"], x, cfg, causal=causal,
                                             attn_impl=attn_impl)
                if cross:
                    x, cx = _cross_full(lp["xattn"], x, enc_out, cfg)
                    c = {**c, **cx}
            else:
                if cfg.mla:
                    x, c = attn_mod.mla_decode(lp["attn"], x, cache_i, cur_len,
                                               cfg)
                else:
                    sub = {"k": cache_i["k"], "v": cache_i["v"]}
                    x, c = attn_mod.gqa_decode(lp["attn"], x, sub, cur_len, cfg)
                if cross:
                    x = _cross_decode(lp["xattn"], x, cache_i, cfg)
                    c = {**c, "xk": cache_i["xk"], "xv": cache_i["xv"]}
        elif ld.kind == "ssm":
            if mode == "full":
                x, c = ssm_mod.ssm_full(lp["ssm"], x, cfg)
            else:
                x, c = ssm_mod.ssm_decode(lp["ssm"], x, cache_i, cfg)
        if ld.mlp == "dense":
            x = mlp_layer(lp["mlp"], x, cfg)
        elif ld.mlp == "moe":
            x, a = moe_mod.moe_layer(lp["moe"], x, cfg)
            aux = aux + a
        if mode == "full" and not want_cache:
            c = ()   # train mode: no cache retention
        new_caches.append(c)
    return x, aux, tuple(new_caches)


class LM:
    """Decoder-only language model (pattern-scanned)."""

    def __init__(self, cfg: ArchConfig, attn_impl: str = "xla",
                 unroll_scan: bool = False):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.unroll = unroll_scan
        self.pattern = cfg.layer_pattern()
        self.n_prefix = cfg.first_dense_layers
        n_scanned = cfg.n_layers - self.n_prefix
        assert n_scanned % len(self.pattern) == 0, cfg.name
        self.n_blocks = n_scanned // len(self.pattern)
        self.pdt = _dtype(cfg.param_dtype)
        self.adt = _dtype(cfg.activation_dtype)

        # vocab padded to a 128 multiple so the table shards on any TP degree
        # (Megatron-style); padded logit columns are masked in _logits
        self.v_pad = ((cfg.vocab_size + 127) // 128) * 128
        ps = ParamSet(dtype=self.pdt)
        ps.add("embed/tokens", (self.v_pad, cfg.d_model), ("tp", "fsdp"))
        prefix_pat = (LayerDesc(kind="attn", mlp="dense"),)
        for i in range(self.n_prefix):
            register_pattern_block(ps, f"prefix{i}", cfg, prefix_pat, ())
        register_pattern_block(ps, "blocks", cfg, self.pattern,
                               (self.n_blocks,))
        ps.add("final_norm", (cfg.d_model,), (None,), init="ones")
        if not cfg.tie_embeddings:
            ps.add("lm_head", (cfg.d_model, self.v_pad), ("fsdp", "tp"))
        self.ps = ps
        self.prefix_pattern = prefix_pat

    # -- parameter plumbing --------------------------------------------------
    def init_params(self, rng: jax.Array) -> Dict:
        return self.ps.init_params(rng)

    def n_params(self) -> int:
        return self.ps.n_params()

    # -- embedding / head ----------------------------------------------------
    def _embed(self, params: Dict, tokens: jnp.ndarray,
               frontend_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
        x = params["embed"]["tokens"][tokens].astype(self.adt)
        if frontend_embeds is not None:
            x = jnp.concatenate([frontend_embeds.astype(self.adt), x], axis=1)
        return hint(x, "batch", None, None)

    def _logits(self, params: Dict, x: jnp.ndarray) -> jnp.ndarray:
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tokens"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        if self.v_pad != self.cfg.vocab_size:   # mask padded vocab columns
            col = jnp.arange(self.v_pad)
            logits = jnp.where(col < self.cfg.vocab_size, logits, -1e30)
        return hint(logits, "batch", None, "tp")

    # -- full-sequence pass ----------------------------------------------------
    def _run_blocks_full(self, params: Dict, x: jnp.ndarray,
                         want_cache: bool) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                    List, Tuple]:
        cfg = self.cfg
        prefix_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(self.n_prefix):
            x, aux, c = apply_pattern_block(
                params[f"prefix{i}"], x, cfg, self.prefix_pattern, "full",
                attn_impl=self.attn_impl, want_cache=want_cache)
            aux_total += aux
            prefix_caches.append(c)

        def block_fn(carry, p_block):
            xx, aux_acc = carry
            xx, aux, c = apply_pattern_block(
                p_block, xx, cfg, self.pattern, "full",
                attn_impl=self.attn_impl, want_cache=want_cache)
            return (xx, aux_acc + aux), c

        if cfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block_fn = jax.checkpoint(block_fn, policy=policy)
        (x, aux_total), caches = jax.lax.scan(block_fn, (x, aux_total),
                                              params["blocks"],
                                              unroll=self.unroll)
        return x, aux_total, prefix_caches, caches

    # -- public entry points ---------------------------------------------------
    def train_loss(self, params: Dict, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        x = self._embed(params, batch["tokens"], batch.get("frontend_embeds"))
        x, aux, _, _ = self._run_blocks_full(params, x, want_cache=False)
        logits = self._logits(params, x)
        nfe = 0 if batch.get("frontend_embeds") is None \
            else batch["frontend_embeds"].shape[1]
        logits_tok = logits[:, nfe:, :]
        ce = cross_entropy(logits_tok[:, :-1], batch["labels"][:, 1:],
                           batch.get("loss_mask"))
        loss = ce + aux.astype(jnp.float32)
        return loss, {"ce": ce, "aux": aux}

    def prefill(self, params: Dict, tokens: jnp.ndarray,
                frontend_embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Tuple[List, Tuple]]:
        x = self._embed(params, tokens, frontend_embeds)
        x, _, prefix_caches, caches = self._run_blocks_full(params, x,
                                                            want_cache=True)
        logits = self._logits(params, x[:, -1:, :])
        return logits[:, 0], (prefix_caches, caches)

    def decode_step(self, params: Dict, token: jnp.ndarray,
                    caches: Tuple[List, Tuple], cur_len: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Tuple[List, Tuple]]:
        """token: (B,) int32; cur_len: () — position being written."""
        cfg = self.cfg
        prefix_caches, block_caches = caches
        x = params["embed"]["tokens"][token[:, None]].astype(self.adt)
        new_prefix = []
        for i in range(self.n_prefix):
            x, _, c = apply_pattern_block(
                params[f"prefix{i}"], x, cfg, self.prefix_pattern, "decode",
                caches=prefix_caches[i], cur_len=cur_len)
            new_prefix.append(c)

        def block_fn(carry, inp):
            xx = carry
            p_block, cache = inp
            xx, _, c = apply_pattern_block(p_block, xx, cfg, self.pattern,
                                           "decode", caches=cache,
                                           cur_len=cur_len)
            return xx, c

        x, new_caches = jax.lax.scan(block_fn, x,
                                     (params["blocks"], block_caches),
                                     unroll=self.unroll)
        logits = self._logits(params, x)
        return logits[:, 0], (new_prefix, new_caches)

    # -- cache construction ------------------------------------------------------
    def _slot_cache_spec(self, ld: LayerDesc, batch: int, s_max: int,
                         stack: Tuple[int, ...]) -> Any:
        cfg = self.cfg

        def stacked(tree):
            return jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct(stack + sd.shape, sd.dtype), tree)

        if ld.kind == "attn":
            if cfg.mla:
                return stacked(attn_mod.mla_cache_spec(cfg, batch, s_max,
                                                       self.adt))
            return stacked(attn_mod.gqa_cache_spec(cfg, batch, s_max, self.adt))
        return stacked(ssm_mod.ssm_cache_spec(cfg, batch, self.adt))

    def decode_cache_specs(self, batch: int, s_max: int) -> Tuple[List, Tuple]:
        prefix = [tuple(self._slot_cache_spec(ld, batch, s_max, ())
                        for ld in self.prefix_pattern)
                  for _ in range(self.n_prefix)]
        blocks = tuple(self._slot_cache_spec(ld, batch, s_max, (self.n_blocks,))
                       for ld in self.pattern)
        return prefix, blocks

    def init_decode_caches(self, batch: int, s_max: int) -> Tuple[List, Tuple]:
        specs = self.decode_cache_specs(batch, s_max)
        return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), specs)
