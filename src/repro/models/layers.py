"""Model-layer primitives + parameter/sharding registry.

Parameters are nested dicts of arrays. A ``ParamSet`` records, for every
parameter: shape, dtype, init std, and a ``PartitionSpec`` — so a single
definition yields (a) real initialization for training/smoke tests, (b)
``jax.eval_shape`` trees for the dry-run, and (c) in/out shardings for pjit.

Sharding axis convention (DESIGN.md §7):
  "fsdp"  — placeholder resolved to ("pod","data") (multi-pod) or ("data",)
  "tp"    — placeholder resolved to "model"
Resolution happens in resolve_specs() so one model definition serves every mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Parameter registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParamInfo:
    shape: Tuple[int, ...]
    dtype: Any
    spec: Tuple[Optional[str], ...]       # axis names: "fsdp" | "tp" | None
    init: str = "normal"                  # normal | zeros | ones
    std: float = 0.02


class ParamSet:
    """Collects ParamInfo under nested string paths ('a/b/c')."""

    def __init__(self, dtype=jnp.float32):
        self.infos: Dict[str, ParamInfo] = {}
        self.default_dtype = dtype

    def add(self, path: str, shape: Sequence[int],
            spec: Sequence[Optional[str]], init: str = "normal",
            std: float = 0.02, dtype=None) -> None:
        assert path not in self.infos, f"duplicate param {path}"
        assert len(spec) == len(shape), (path, shape, spec)
        self.infos[path] = ParamInfo(tuple(shape), dtype or self.default_dtype,
                                     tuple(spec), init, std)

    # -- materialization ----------------------------------------------------
    def init_params(self, rng: jax.Array) -> Dict[str, Any]:
        keys = jax.random.split(rng, max(len(self.infos), 1))
        out: Dict[str, Any] = {}
        for (path, info), key in zip(sorted(self.infos.items()), keys):
            if info.init == "zeros":
                val = jnp.zeros(info.shape, info.dtype)
            elif info.init == "ones":
                val = jnp.ones(info.shape, info.dtype)
            else:
                val = (jax.random.normal(key, info.shape, jnp.float32)
                       * info.std).astype(info.dtype)
            _set(out, path, val)
        return out

    def shape_tree(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for path, info in self.infos.items():
            _set(out, path, jax.ShapeDtypeStruct(info.shape, info.dtype))
        return out

    def spec_tree(self, axes: "MeshAxes") -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for path, info in self.infos.items():
            _set(out, path, resolve_spec(info.spec, axes))
        return out

    def n_params(self) -> int:
        return sum(math.prod(i.shape) for i in self.infos.values())


def _set(tree: Dict[str, Any], path: str, val: Any) -> None:
    parts = path.split("/")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = val


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """How placeholder axis names map onto the physical mesh.

    fsdp=() replicates parameters across the data axes (inference mode: no
    optimizer state, weights TP-only — kills the per-step FSDP all-gather).
    """
    fsdp: Tuple[str, ...]        # e.g. ("data",) or ("pod", "data") or ()
    tp: str = "model"
    batch_axes: Optional[Tuple[str, ...]] = None

    @property
    def batch(self) -> Tuple[str, ...]:
        return self.batch_axes if self.batch_axes is not None else self.fsdp


# ---------------------------------------------------------------------------
# Intermediate-activation sharding hints
# ---------------------------------------------------------------------------
# XLA's sharding propagation sometimes materializes huge unsharded
# intermediates (e.g. the (B,S,V) logits) when left to its own devices;
# models insert `hint()` constraints at layer boundaries. Hints resolve
# against the MeshAxes installed by the launcher; when none is installed
# (CPU unit tests) they are no-ops.

_HINT_AXES: Optional["MeshAxes"] = None


def set_hint_axes(axes: Optional["MeshAxes"]) -> None:
    global _HINT_AXES
    _HINT_AXES = axes


def hint(x: jnp.ndarray, *spec: Optional[str]) -> jnp.ndarray:
    if _HINT_AXES is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve_spec(tuple(spec), _HINT_AXES))


def resolve_spec(spec: Tuple[Optional[str], ...], axes: MeshAxes) -> P:
    def _axes_or_none(t):
        if not t:
            return None
        return t if len(t) > 1 else t[0]

    resolved = []
    for s in spec:
        if s is None:
            resolved.append(None)
        elif s == "fsdp":
            resolved.append(_axes_or_none(axes.fsdp))
        elif s == "tp":
            resolved.append(axes.tp)
        elif s == "batch":
            resolved.append(_axes_or_none(axes.batch))
        else:
            raise ValueError(f"unknown axis placeholder {s}")
    return P(*resolved)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4
         ) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, D_even); positions: (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    hspec = ("batch",) + (None,) * (x.ndim - 2) + ("tp",)
    g = hint(jnp.einsum("...d,df->...f", x, w_gate), *hspec)
    u = hint(jnp.einsum("...d,df->...f", x, w_up), *hspec)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray
             ) -> jnp.ndarray:
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(
        jnp.einsum("...d,df->...f", x, w_in)), w_out)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy in f32. logits (..., V); labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
