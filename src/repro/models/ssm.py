"""Mamba2 — SSD (state-space duality), chunked train/prefill + O(1) decode.

The chunked SSD algorithm (Dao & Gu 2024): split the sequence into chunks of
length L; within a chunk the output is a masked (decay-weighted) attention-like
quadratic form; across chunks a (B*H, P, N) state is carried by a scan. Decode
is a pure recurrence on that state — which is why the 500k-token cell is
assigned to SSM/hybrid archs only.

State caches are fixed-capacity pools (paper O5): conv window (B, d_conv-1, C)
and SSM state (B, H, P, N), preallocated once per sequence.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import ParamSet, hint, rms_norm


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def register_ssm(ps: ParamSet, prefix: str, cfg: ArchConfig,
                 stack: Tuple[int, ...]) -> None:
    d = cfg.d_model
    di, h, hp, n = _dims(cfg)
    conv_dim = di + 2 * n                     # conv over (x, B, C)
    s = tuple(stack)
    ns = (None,) * len(s)
    # in_proj → [z (di), x (di), B (n), C (n), dt (h)]
    ps.add(f"{prefix}/w_in", s + (d, 2 * di + 2 * n + h), ns + ("fsdp", "tp"))
    ps.add(f"{prefix}/conv_w", s + (cfg.ssm_conv, conv_dim), ns + (None, "tp"))
    ps.add(f"{prefix}/conv_b", s + (conv_dim,), ns + ("tp",), init="zeros")
    ps.add(f"{prefix}/a_log", s + (h,), ns + (None,), init="zeros")
    ps.add(f"{prefix}/dt_bias", s + (h,), ns + (None,), init="zeros")
    ps.add(f"{prefix}/d_skip", s + (h,), ns + (None,), init="ones")
    ps.add(f"{prefix}/out_norm", s + (di,), ns + (None,), init="ones")
    ps.add(f"{prefix}/w_out", s + (di, d), ns + ("tp", "fsdp"))
    ps.add(f"{prefix}/norm", s + (d,), ns + (None,), init="ones")


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    di, h, hp, n = _dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv over time. xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev
    xp = jnp.concatenate([pad, xbc], axis=1)                   # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                bmat: jnp.ndarray, cmat: jnp.ndarray, chunk: int,
                h0: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over a full sequence.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); a: (H,) (negative);
    bmat/cmat: (B,S,N). Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    c = s // l
    xc = x.reshape(b, c, l, h, p)
    dtc = dt.reshape(b, c, l, h)
    bc = bmat.reshape(b, c, l, n)
    cc = cmat.reshape(b, c, l, n)

    da = dtc * a                                              # (B,C,L,H) ≤ 0
    cum = jnp.cumsum(da, axis=2)                              # within-chunk
    # intra-chunk decay matrix: exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,C,L,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcln,bcmn->bclm", cc, bc)            # (B,C,L,L)
    w = scores[..., None] * decay * dtc[:, :, None, :, :]     # (B,C,L,L,H)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", w, xc)

    # chunk states: contribution of each chunk to the carried state
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,C,L,H)
    st = jnp.einsum("bcln,bclh,bclhp->bchpn", bc,
                    dtc * decay_to_end, xc)                   # (B,C,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,C,H)

    def scan_fn(hprev, inp):
        st_c, dec_c = inp
        hnew = hprev * dec_c[..., None, None] + st_c
        return hnew, hprev

    init = h0 if h0 is not None else jnp.zeros((b, h, p, n), x.dtype)
    hfin, hprevs = jax.lax.scan(scan_fn,
                                init,
                                (st.transpose(1, 0, 2, 3, 4),
                                 chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                  # (B,C,H,P,N)

    # inter-chunk: y += C · (decay_in * h_prev)
    decay_in = jnp.exp(cum)                                   # (B,C,L,H)
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", cc, decay_in, hprevs)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, hfin


def ssm_full(p: Dict, x: jnp.ndarray, cfg: ArchConfig
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence Mamba2 block. Returns (out, cache for decode handoff)."""
    b, s, d = x.shape
    di, h, hp, n = _dims(cfg)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    proj = hint(jnp.einsum("bsd,de->bse", xn, p["w_in"]), "batch", None, None)
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xin = xbc[..., :di].reshape(b, s, h, hp)
    bmat = xbc[..., di:di + n]
    cmat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    # pad S to a chunk multiple with identity timesteps (dt=0 ⇒ decay=1 and
    # zero state contribution), so the carried state is unaffected
    l = min(cfg.ssm_chunk, s) if s % min(cfg.ssm_chunk, s) == 0 else cfg.ssm_chunk
    s_pad = ((s + l - 1) // l) * l
    if s_pad != s:
        pz = ((0, 0), (0, s_pad - s))
        xin_p = jnp.pad(xin, pz + ((0, 0), (0, 0)))
        dt_p = jnp.pad(dt, pz + ((0, 0),))
        b_p = jnp.pad(bmat, pz + ((0, 0),))
        c_p = jnp.pad(cmat, pz + ((0, 0),))
    else:
        xin_p, dt_p, b_p, c_p = xin, dt, bmat, cmat
    y, hfin = ssd_chunked(xin_p.astype(jnp.float32), dt_p, a,
                          b_p.astype(jnp.float32), c_p.astype(jnp.float32),
                          l)
    y = y[:, :s]
    y = y + xin.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = hint(jnp.einsum("bse,ed->bsd", y, p["w_out"]), "batch", None, None)
    # decode handoff: cache the *pre-conv* tail window + final SSM state
    kw = cfg.ssm_conv - 1
    conv_tail = (xbc_raw[:, s - kw:, :] if s >= kw
                 else jnp.pad(xbc_raw, ((0, 0), (kw - s, 0), (0, 0))))
    cache = {"conv": conv_tail, "state": hfin.astype(x.dtype)}
    return x + out, cache


def ssm_decode(p: Dict, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token recurrence. x: (B,1,D); cache: conv window + SSM state."""
    b = x.shape[0]
    di, h, hp, n = _dims(cfg)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn, p["w_in"])
    z, xbc_new, dt = _split_proj(cfg, proj)

    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B,K,C)
    k = p["conv_w"].shape[0]
    conv_out = jnp.einsum("bkc,kc->bc", window[:, -k:, :], p["conv_w"])
    xbc = jax.nn.silu(conv_out + p["conv_b"])[:, None, :]       # (B,1,C)

    xin = xbc[..., :di].reshape(b, h, hp)
    bmat = xbc[:, 0, di:di + n]
    cmat = xbc[:, 0, di + n:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a)                                    # (B,H)
    state = cache["state"].astype(jnp.float32)
    state = (state * decay[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt1, xin.astype(jnp.float32),
                          bmat.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, cmat.astype(jnp.float32))
    y = y + xin.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return x + out, {"conv": window[:, 1:, :], "state": state.astype(x.dtype)}


def ssm_cache_spec(cfg: ArchConfig, batch: int, dtype
                   ) -> Dict[str, jax.ShapeDtypeStruct]:
    di, h, hp, n = _dims(cfg)
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, di + 2 * n),
                                         dtype),
            "state": jax.ShapeDtypeStruct((batch, h, hp, n), dtype)}
