"""Simulation-as-a-service — continuous batching over ensemble lanes.

The token-serving side (batching.py) keeps a fixed-slot decode batch full:
finished sequences retire, queued requests admit into the freed slots, and
the jitted step always runs at static shape with inactive slots masked. This
module is the same loop with a *simulation* as the unit of work and an
ensemble lane (core/ensemble.py) as the slot:

  request  = initial agents + seed + per-request ScenarioParams + step budget
  admit    = stage a solo init state, write it into a free lane (jitted
             lane-indexed scatter; no recompile)
  step     = ONE vmapped Algorithm-1 iteration advances every occupied lane
             (under the ensemble capacity ladder, so worst-lane overflow
             grows the shared rung with the usual rewind)
  stream   = per-tick, per-lane metrics (a user ``metrics_fn`` vmapped over
             the ensemble) + per-lane StepStats flow back to the caller
  retire   = converged / budget-exhausted lanes freeze, final state is read
             out, and the lane returns to the free pool — at *iteration*
             granularity, like batching.py retires at token granularity

Admission is blocked, never dropped: with every lane occupied a request
stays queued (the bounded-memory property batching.py inherits from the
paper's fixed pools). Checkpointing snapshots the whole ensemble plus the
host-side lane table through core/simcheck.py, so a SIGKILLed service
resumes mid-churn with every occupied lane bit-exact; the *queue* is the
caller's to re-submit (requests are caller-owned inputs, not run state).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.behaviors import Behavior
from ..core.engine import (EngineConfig, EngineState, LadderConfig,
                           ScenarioParams)
from ..core.ensemble import EnsembleCapacityLadder, EnsembleEngine
from ..core.simcheck import restore_ensemble_state, save_ensemble_state


@dataclasses.dataclass
class SimRequest:
    """One simulation to run: initial agents, RNG seed, per-request knobs."""
    uid: int
    position: Any                              # (N, 3) initial positions
    diameter: Any = None
    agent_type: Any = None
    extra_init: Optional[Dict[str, Any]] = None
    seed: int = 0
    params: Optional[ScenarioParams] = None    # structure must match the
                                               # service's params_template
    max_steps: int = 100


@dataclasses.dataclass
class FinishedSim:
    """A retired simulation: identity, why it ended, and what it produced."""
    uid: int
    lane: int
    steps: int
    reason: str                                # "converged" | "max_steps"
    final: EngineState                         # lane state at retirement
    trajectory: List[Any]                      # per-step metrics_fn values


class SimService:
    """Host-side orchestrator around the jitted ensemble step.

    ``metrics_fn(pool, params) -> value`` is vmapped over lanes and read
    back each tick (the streamed per-step output); ``converged_fn(value) ->
    bool`` decides early retirement from the latest metric. Both optional —
    without them lanes run to their step budget.
    """

    def __init__(self, config: EngineConfig,
                 behaviors: Sequence[Behavior] = (), n_lanes: int = 4,
                 params_template: Optional[ScenarioParams] = None,
                 metrics_fn: Optional[Callable] = None,
                 converged_fn: Optional[Callable] = None,
                 ladder: Optional[LadderConfig] = None):
        self.driver = EnsembleCapacityLadder(config, behaviors, n_lanes,
                                             params_template, ladder)
        self.n_lanes = n_lanes
        self.metrics_fn = metrics_fn
        self.converged_fn = converged_fn
        self.state = self.driver.init_state()
        self.queue: List[SimRequest] = []
        self.lanes: List[Optional[dict]] = [None] * n_lanes
        self.finished: List[FinishedSim] = []
        self._metrics_jit = None

    @property
    def engine(self) -> EnsembleEngine:
        return self.driver.engine

    def _metrics(self, state):
        if self.metrics_fn is None:
            return None
        if self._metrics_jit is None:
            # (re)built lazily: the ladder swaps engines across rungs but the
            # metric is shape-polymorphic per compile, like the step itself
            self._metrics_jit = jax.jit(lambda pool, params: jax.vmap(
                self.metrics_fn)(pool, params))
        return np.asarray(self._metrics_jit(state.pool, state.params))

    # -- admission -----------------------------------------------------------
    def submit(self, req: SimRequest) -> None:
        self.queue.append(req)

    def _admit(self) -> int:
        n = 0
        for i in range(self.n_lanes):
            if self.lanes[i] is not None:
                continue
            if not self.queue:
                break
            req = self.queue.pop(0)            # full lanes → stays queued
            lane_state = self.engine.stage_lane(
                req.position, req.diameter, req.agent_type, req.extra_init,
                seed=req.seed)
            self.state = self.engine.admit(self.state, i, lane_state,
                                           req.params)
            self.lanes[i] = {"req": req, "steps": 0, "trajectory": []}
            n += 1
        return n

    # -- retirement ----------------------------------------------------------
    def _retire(self, lane: int, reason: str) -> None:
        info = self.lanes[lane]
        final = self.engine.read_lane(self.state, lane)
        self.finished.append(FinishedSim(
            uid=info["req"].uid, lane=lane, steps=info["steps"],
            reason=reason, final=final, trajectory=info["trajectory"]))
        self.state = self.engine.retire(self.state, lane)
        self.lanes[lane] = None

    # -- one service tick ----------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests, advance every occupied lane one
        iteration, stream metrics, retire finished lanes. Returns the
        number of lanes stepped; 0 with everything idle — the early exit
        never launches the jitted step."""
        self._admit()
        if all(info is None for info in self.lanes):
            return 0
        self.state = self.driver.step(self.state)
        metrics = self._metrics(self.state)
        n = 0
        for i, info in enumerate(self.lanes):
            if info is None:
                continue
            n += 1
            info["steps"] += 1
            m = None if metrics is None else metrics[i]
            if m is not None:
                info["trajectory"].append(m)
            if (self.converged_fn is not None and m is not None
                    and self.converged_fn(m)):
                self._retire(i, "converged")
            elif info["steps"] >= info["req"].max_steps:
                self._retire(i, "max_steps")
        return n

    def run_until_drained(self, max_ticks: int = 100_000) -> int:
        """Tick until the queue and every lane are empty. Returns ticks."""
        for t in range(max_ticks):
            if not self.queue and all(info is None for info in self.lanes):
                return t
            self.step()
        raise RuntimeError(f"service not drained after {max_ticks} ticks "
                           f"({len(self.queue)} queued, "
                           f"{sum(i is not None for i in self.lanes)} busy)")

    # -- occupancy / introspection -------------------------------------------
    def occupancy(self) -> float:
        """Fraction of lanes currently running a simulation."""
        return sum(i is not None for i in self.lanes) / self.n_lanes

    # -- checkpoint / resume --------------------------------------------------
    def checkpoint(self, ckpt_dir: str,
                   extras: Optional[Dict] = None) -> str:
        """Snapshot the ensemble + the lane table (uid/steps/budget per
        occupied lane). Queued requests are NOT checkpointed — they are
        caller-owned inputs; re-submit them after a restore (``extras`` is
        the place to record what a caller needs for that, e.g. finished
        uids — it round-trips through ``restored_meta``)."""
        table = [None if info is None else
                 {"uid": info["req"].uid, "steps": info["steps"],
                  "max_steps": info["req"].max_steps}
                 for info in self.lanes]
        meta = {"lanes": table}
        if extras:
            meta.update(extras)
        return save_ensemble_state(ckpt_dir, self.state, self.driver.config,
                                   extras=meta)

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore ensemble state + lane table; returns the restored tick.

        Bit-exact resume: the rung knobs recorded in the manifest rebuild
        the exact jit program, occupied lanes pick up mid-trajectory (their
        streamed trajectories restart empty — history already went to the
        caller)."""
        state, cfg, meta = restore_ensemble_state(
            ckpt_dir, self.driver.config, self.driver.behaviors,
            self.driver.params_template, step=step)
        if meta["n_lanes"] != self.n_lanes:
            raise ValueError(f"checkpoint has {meta['n_lanes']} lanes, "
                             f"service has {self.n_lanes}")
        self.driver.config = cfg
        self.driver._sim = EnsembleEngine(cfg, self.driver.behaviors,
                                          self.n_lanes,
                                          self.driver.params_template)
        self._metrics_jit = None
        self.state = state
        self.restored_meta = meta
        self.lanes = [
            None if entry is None else
            {"req": SimRequest(uid=entry["uid"],
                               position=np.zeros((0, 3), np.float32),
                               max_steps=entry["max_steps"]),
             "steps": entry["steps"], "trajectory": []}
            for entry in meta["lanes"]]
        return int(state.tick)
