"""Continuous batching — the paper's parallel add/remove (§3.2) for serving.

A fixed-slot decode batch (= the paper's fixed-capacity agent pool): finished
sequences are retired and their pages released; queued requests are admitted
into free slots — all with the same prefix-sum slot-reservation machinery the
engine uses for agents. The decode step always runs at full (static) batch
shape; inactive slots are masked — no recompilation as load varies, which is
what makes this viable at fleet scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kv_cache as kvc


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Finished:
    uid: int
    tokens: List[int]


class ContinuousBatcher:
    """Host-side orchestrator around a jitted masked decode step.

    decode_fn(params, tokens (S,), caches, seq_len (S,), active (S,)) →
    (next_tokens (S,), caches). The KV pool is the paged cache; admission is
    blocked (queued) when the pool is out of pages — graceful degradation
    instead of OOM (paper O5's bounded-memory property).
    """

    def __init__(self, spec: kvc.PagedCacheSpec,
                 prefill_fn: Callable, decode_fn: Callable,
                 eos_token: int = 1):
        self.spec = spec
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.eos = eos_token
        self.state = kvc.init_cache(spec)
        self.queue: List[Request] = []
        self.slots: List[Optional[dict]] = [None] * spec.max_seqs
        self.finished: List[Finished] = []

    # -- admission (paper §3.2 additions) ------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.spec.max_seqs):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            st, ok = kvc.admit_sequence(
                self.spec, self.state, jnp.int32(i),
                jnp.int32(len(req.prompt)))
            if not bool(ok):
                break                      # pool exhausted: stay queued
            self.queue.pop(0)
            self.state = st
            kv_prompt, last_tok = self.prefill_fn(req.prompt, i, self)
            self.slots[i] = {"req": req, "generated": [],
                             "last": int(last_tok), "left": req.max_new_tokens}

    # -- retirement (paper §3.2 removals) -------------------------------------
    def _retire(self, slot: int) -> None:
        info = self.slots[slot]
        self.finished.append(Finished(info["req"].uid, info["generated"]))
        self.state = kvc.release_sequence(self.spec, self.state,
                                          jnp.int32(slot))
        self.slots[slot] = None

    # -- one engine iteration --------------------------------------------------
    def step(self, params) -> int:
        self._admit()
        active = np.array([s is not None for s in self.slots])
        if not active.any():
            return 0
        tokens = np.array([s["last"] if s else 0 for s in self.slots],
                          np.int32)
        next_tokens, self.state = self.decode_fn(
            params, jnp.asarray(tokens), self.state,
            jnp.asarray(active))
        next_np = np.asarray(next_tokens)
        n = 0
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tok = int(next_np[i])
            s["generated"].append(tok)
            s["last"] = tok
            s["left"] -= 1
            n += 1
            if tok == self.eos or s["left"] <= 0:
                self._retire(i)
        return n

    def run_until_drained(self, params, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step(params)
