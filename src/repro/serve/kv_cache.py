"""Paged KV cache — the paper's pool allocator (§4.3) transplanted to serving.

BioDynaMo's NumaPoolAllocator: preallocated equal-sized elements, a central
free list, constant-time alloc/free, metadata at segment heads. The serving
analogue allocates *KV pages* (fixed ``page_size`` tokens × all layers) from a
preallocated pool with an array-based free-list stack:

  alloc  = pop from free stack      O(1)
  free   = push page ids back       O(1) per page (vectorized for a sequence)
  lookup = block_table[seq, token // page_size]

Like the paper's allocator, memory overhead is bounded (≤ page_size-1 wasted
slots per sequence) while fragmentation-free growth/shrink of sequences is
constant-time — exactly the property that lets continuous batching admit and
retire sequences every step (paper §3.2 parallel add/remove).

All state is a pytree; every operation is jit-compatible (fixed shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    n_layers: int
    n_kv_heads: int
    d_head: int
    page_size: int = 16
    n_pages: int = 1024
    max_seqs: int = 64
    max_pages_per_seq: int = 256
    dtype: str = "bfloat16"

    @property
    def _dt(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedCacheState:
    k_pages: jnp.ndarray       # (L, P, page, Hkv, Dh)
    v_pages: jnp.ndarray
    free_stack: jnp.ndarray    # (P,) page ids; valid entries [0, n_free)
    n_free: jnp.ndarray        # ()
    block_table: jnp.ndarray   # (max_seqs, max_pages_per_seq) int32, -1 empty
    seq_len: jnp.ndarray       # (max_seqs,) int32
    seq_active: jnp.ndarray    # (max_seqs,) bool


def init_cache(spec: PagedCacheSpec) -> PagedCacheState:
    dt = spec._dt
    shape = (spec.n_layers, spec.n_pages, spec.page_size, spec.n_kv_heads,
             spec.d_head)
    return PagedCacheState(
        k_pages=jnp.zeros(shape, dt),
        v_pages=jnp.zeros(shape, dt),
        free_stack=jnp.arange(spec.n_pages, dtype=jnp.int32),
        n_free=jnp.asarray(spec.n_pages, jnp.int32),
        block_table=jnp.full((spec.max_seqs, spec.max_pages_per_seq), -1,
                             jnp.int32),
        seq_len=jnp.zeros((spec.max_seqs,), jnp.int32),
        seq_active=jnp.zeros((spec.max_seqs,), bool),
    )


def admit_sequence(spec: PagedCacheSpec, st: PagedCacheState, slot: jnp.ndarray,
                   prompt_len: jnp.ndarray) -> Tuple[PagedCacheState, jnp.ndarray]:
    """Reserve pages for a prompt of ``prompt_len`` tokens in ``slot``.

    Returns (state, ok). ok=False (state unchanged) if the pool lacks pages —
    the caller queues the request (admission control).
    """
    need = (prompt_len + spec.page_size - 1) // spec.page_size
    ok = (need <= st.n_free) & ~st.seq_active[slot]

    def do(st):
        idx = jnp.arange(spec.max_pages_per_seq, dtype=jnp.int32)
        take = idx < need
        # pop `need` pages from the top of the stack
        stack_pos = st.n_free - 1 - idx
        pages = jnp.where(take, st.free_stack[jnp.maximum(stack_pos, 0)], -1)
        row = jnp.where(take, pages, st.block_table[slot])
        return dataclasses.replace(
            st,
            n_free=st.n_free - need,
            block_table=st.block_table.at[slot].set(row),
            seq_len=st.seq_len.at[slot].set(prompt_len),
            seq_active=st.seq_active.at[slot].set(True),
        )

    return jax.lax.cond(ok, do, lambda s: s, st), ok


def release_sequence(spec: PagedCacheSpec, st: PagedCacheState,
                     slot: jnp.ndarray) -> PagedCacheState:
    """Free all pages of a finished sequence (O(pages), fully vectorized)."""
    row = st.block_table[slot]
    held = row >= 0
    n_rel = jnp.sum(held.astype(jnp.int32))
    # push pages onto the stack: positions n_free .. n_free+n_rel-1
    dst = st.n_free + jnp.cumsum(held.astype(jnp.int32)) - 1
    dst = jnp.where(held, dst, spec.n_pages)      # parked → dropped
    stack = st.free_stack.at[dst].set(row, mode="drop")
    return dataclasses.replace(
        st,
        free_stack=stack,
        n_free=st.n_free + n_rel,
        block_table=st.block_table.at[slot].set(
            jnp.full((spec.max_pages_per_seq,), -1, jnp.int32)),
        seq_len=st.seq_len.at[slot].set(0),
        seq_active=st.seq_active.at[slot].set(False),
    )


def append_token(spec: PagedCacheSpec, st: PagedCacheState,
                 k_new: jnp.ndarray, v_new: jnp.ndarray
                 ) -> Tuple[PagedCacheState, jnp.ndarray]:
    """Write one token of KV for every active slot; grow pages when needed.

    k_new/v_new: (L, max_seqs, Hkv, Dh). Returns (state, grew_ok (max_seqs,)).
    """
    pos = st.seq_len                                   # (S,)
    page_idx = pos // spec.page_size
    off = pos % spec.page_size
    needs_page = (off == 0) & st.seq_active
    n_need = jnp.sum(needs_page.astype(jnp.int32))
    ok = n_need <= st.n_free

    # allocate one page per slot needing growth (prefix-sum slot reservation —
    # paper §3.2 again)
    order = jnp.cumsum(needs_page.astype(jnp.int32)) - 1     # rank among needers
    stack_pos = st.n_free - 1 - order
    new_pages = jnp.where(needs_page & ok,
                          st.free_stack[jnp.maximum(stack_pos, 0)], -1)
    bt = st.block_table.at[jnp.arange(spec.max_seqs), page_idx].set(
        jnp.where(needs_page & ok, new_pages,
                  st.block_table[jnp.arange(spec.max_seqs), page_idx]))
    n_free = st.n_free - jnp.where(ok, n_need, 0)

    phys = bt[jnp.arange(spec.max_seqs), page_idx]           # (S,)
    phys_safe = jnp.maximum(phys, 0)
    write = st.seq_active & (phys >= 0) & ok
    kp = st.k_pages.at[:, phys_safe, off].set(
        jnp.where(write[None, :, None, None], k_new, st.k_pages[:, phys_safe, off]))
    vp = st.v_pages.at[:, phys_safe, off].set(
        jnp.where(write[None, :, None, None], v_new, st.v_pages[:, phys_safe, off]))
    return dataclasses.replace(
        st, k_pages=kp, v_pages=vp, block_table=bt, n_free=n_free,
        seq_len=jnp.where(write, st.seq_len + 1, st.seq_len)), write


def gather_kv(spec: PagedCacheSpec, st: PagedCacheState, layer: jnp.ndarray,
              slot: jnp.ndarray, s_max: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize (s_max, Hkv, Dh) K/V for one sequence (attention view)."""
    n_pg = s_max // spec.page_size
    pages = st.block_table[slot, :n_pg]                      # (n_pg,)
    pages_safe = jnp.maximum(pages, 0)
    k = st.k_pages[layer, pages_safe].reshape(s_max, spec.n_kv_heads,
                                              spec.d_head)
    v = st.v_pages[layer, pages_safe].reshape(s_max, spec.n_kv_heads,
                                              spec.d_head)
    valid = jnp.arange(s_max) < st.seq_len[slot]
    return k, v, valid
