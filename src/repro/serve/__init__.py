"""repro.serve — paged KV pool (paper §4.3) + continuous batching (paper §3.2)."""
from .kv_cache import (PagedCacheSpec, PagedCacheState, admit_sequence,
                       append_token, gather_kv, init_cache, release_sequence)
from .batching import ContinuousBatcher, Request
from .sim_service import FinishedSim, SimRequest, SimService
