from . import analysis
