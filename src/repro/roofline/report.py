"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/*.json.

Usage: PYTHONPATH=src python -m repro.roofline.report [results_dir]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(results_dir: str) -> List[Dict]:
    recs = []
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    return f"{n / 2**30:.2f}"


def dryrun_table(recs: List[Dict], pod: str) -> str:
    rows = ["| cell | status | params | bytes/dev (GiB) | fits 16G | compile s | note |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r["cell"].endswith(pod):
            continue
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            rows.append(f"| {r['cell']} | {r['status']} | - | - | - | - | {reason} |")
            continue
        mem = r["memory"]["total_bytes_per_device"]
        fits = "yes" if mem <= r["memory"]["hbm_budget_bytes"] else "NO"
        rows.append(
            f"| {r['cell']} | ok | {r['n_params'] / 1e9:.2f}B "
            f"| {_fmt_bytes(mem)} | {fits} | {r['compile_s']:.0f} "
            f"| {r.get('note', '')} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], pod: str = "pod1") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant "
            "| MODEL/HLO flops | roofline frac | bottleneck note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r["cell"].endswith(pod) or r["status"] != "ok":
            continue
        rl = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        note = bottleneck_note(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} "
            f"| {rl['memory_s']:.4f} | {rl['collective_s']:.4f} "
            f"| **{rl['dominant']}** | {ratio:.2f} "
            f"| {r['roofline_fraction']:.3f} | {note} |")
    return "\n".join(rows)


def bottleneck_note(r: Dict) -> str:
    """One sentence on what would move the dominant term down."""
    rl = r["roofline"]
    dom = rl["dominant"]
    shape = r["shape"]
    if dom == "collective":
        top = max(rl["collective_breakdown"],
                  key=rl["collective_breakdown"].get) \
            if rl["collective_breakdown"] else "?"
        return (f"dominated by {top}; fuse/reshard to cut per-layer syncs "
                f"(bf16 sync, 2D-sharded activations)")
    if dom == "memory":
        if "decode" in shape or "long" in shape:
            return "KV/state reads dominate; shrink cache dtype or shard KV wider"
        return "activation traffic; raise arithmetic intensity (fusion, remat policy)"
    return "compute-bound: already near the right wall; tune MXU utilization"


def main() -> None:
    results_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    recs = load(results_dir)
    print("## §Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "pod1"))
    print("\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "pod2"))
    print("\n## §Roofline — per (arch x shape), single-pod baseline\n")
    print(roofline_table(recs, "pod1"))


if __name__ == "__main__":
    main()


def perf_table(perf_dir: str) -> str:
    """§Perf hillclimb log table from results/perf/*.json."""
    if not os.path.isdir(perf_dir):
        return "(no hillclimb records yet)"
    rows = ["| variant | hypothesis | compute s | memory s | collective s "
            "| bound s | useful-MFU |",
            "|---|---|---|---|---|---|---|"]
    for name in sorted(os.listdir(perf_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(perf_dir, name)) as f:
            r = json.load(f)
        rl = r["roofline"]
        rows.append(
            f"| {r['variant']} | {r['hypothesis'][:80]} "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f} | {r['step_time_bound_s']:.3f} "
            f"| {r['roofline_fraction']:.4f} |")
    return "\n".join(rows)
