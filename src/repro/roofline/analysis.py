"""Roofline analysis from compiled dry-run artifacts (assignment §Roofline).

Per (arch × shape × mesh) cell:
  compute term    = HLO_FLOPs_per_device / 197e12        (v5e bf16 peak)
  memory term     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
  collective term = wire_bytes_per_device / 50e9         (ICI per link)

``compiled.cost_analysis()`` reports **per-device** flops/bytes (verified
empirically in this repo). Collective bytes are NOT in cost_analysis: we parse
the post-SPMD optimized HLO, sum operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, apply a
ring-model wire factor per collective type, and multiply instructions inside
``while`` bodies (lax.scan over layers / microbatches) by their parsed trip
counts.

Wire model (ring algorithms, n = participating devices):
  all-reduce      2·(n-1)/n · bytes
  all-gather      (n-1)/n   · output bytes
  reduce-scatter  (n-1)/n   · input bytes
  all-to-all      (n-1)/n   · bytes
  collective-permute  1     · bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[128,4096]' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op: str
    bytes_payload: int        # raw operand/output bytes per device
    wire_bytes: float         # ring-model bytes on the wire per device
    count: int                # executions (trip-count multiplied)
    group_size: int


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name → its instruction lines.

    Headers are lines ending in '{' that carry a '->' signature, e.g.
      %region_0.1_spmd (arg: (s32[], f32[16,128])) -> (s32[], ...) {
      ENTRY %main.4_spmd (param: f32[16,128]) -> f32[] {
    """
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _find_trip_count(cond_lines: List[str]) -> Optional[int]:
    """Trip count from a while condition: compare(iv, constant), LT."""
    consts = {}
    for s in cond_lines:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)", s)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for s in cond_lines:
        if "compare(" in s and "direction=LT" in s:
            args = re.findall(r"%([\w\.\-]+)", s.split("compare(")[1])
            for a in args:
                if a in consts:
                    return consts[a]
    return None


def _call_targets(line: str) -> List[str]:
    """Computations invoked by an instruction line."""
    out = []
    for key in ("calls=", "to_apply=", "body=", "condition=", "branch_computations="):
        for m in re.finditer(key + r"\{?%?([\w\.\-]+)", line):
            out.append(m.group(1))
    return out


_WHILE_RE = re.compile(r"\)\s*while\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLL_RE = re.compile(
    r"=\s*[^=]*?\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")


def parse_collectives(hlo: str,
                      default_group: int = 1) -> List[CollectiveStats]:
    comps = _split_computations(hlo)
    if not comps:
        return []

    # multipliers: propagate trip counts from while ops down the call graph
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    called = set()
    for lines in comps.values():
        for s in lines:
            called.update(_call_targets(s))
    roots = [n for n in comps if n not in called] or [next(iter(comps))]

    import collections
    queue = collections.deque((r, 1.0) for r in roots)
    while queue:
        name, m = queue.popleft()
        if name not in comps or mult.get(name, 0.0) >= m:
            continue
        mult[name] = m
        for s in comps[name]:
            if _WHILE_RE.search(s):
                bm = re.search(r"body=%?([\w\.\-]+)", s)
                cm = re.search(r"condition=%?([\w\.\-]+)", s)
                tm = _TRIP_RE.search(s)
                if tm:
                    trip = float(tm.group(1))
                else:
                    tc = (_find_trip_count(comps.get(cm.group(1), []))
                          if cm else None)
                    trip = float(tc) if tc else 1.0
                if bm:
                    queue.append((bm.group(1), m * trip))
                if cm:
                    queue.append((cm.group(1), m))
                continue
            for t in _call_targets(s):
                queue.append((t, m))

    stats: List[CollectiveStats] = []
    for name, lines in comps.items():
        m = mult.get(name, 1.0) or 1.0
        for s in lines:
            cm_ = _COLL_RE.search(s)
            if cm_ is None or cm_.group(2) == "-done":
                continue   # -done pairs with -start; count once
            opname = cm_.group(1)
            lhs = s.split("=", 1)[1]
            shape_part = lhs[:cm_.start(1) - len(s.split("=", 1)[0]) - 1]
            payload = _shape_bytes(shape_part)
            gm = re.search(r"replica_groups=\{\{([\d,]+)\}", s)
            if gm:
                group = len(gm.group(1).split(","))
            else:
                gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", s)
                group = int(gm2.group(2)) if gm2 else default_group
            n = max(group, 1)
            ring = (n - 1) / n if n > 1 else 0.0
            if opname == "all-reduce":
                wire = 2.0 * ring * payload
            elif opname == "collective-permute":
                wire = float(payload)
            else:
                wire = ring * payload
            stats.append(CollectiveStats(opname, payload, wire * m, int(m), n))
    return stats


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collective_breakdown: Dict[str, float]

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(cost: Dict, hlo: str, default_group: int = 1) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo, default_group)
    wire = sum(c.wire_bytes for c in colls)
    breakdown: Dict[str, float] = {}
    for c in colls:
        breakdown[c.op] = breakdown.get(c.op, 0.0) + c.wire_bytes
    terms = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW,
             "collective": wire / ICI_BW}
    dominant = max(terms, key=terms.get)
    return Roofline(flops_per_device=flops, bytes_per_device=byts,
                    wire_bytes_per_device=wire,
                    compute_s=terms["compute"], memory_s=terms["memory"],
                    collective_s=terms["collective"], dominant=dominant,
                    collective_breakdown=breakdown)
