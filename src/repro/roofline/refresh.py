"""Recompute roofline terms in existing results/dryrun JSONs with the analytic
compute term + useful-MFU fraction (no recompiles — wire/memory bytes reuse the
recorded HLO-derived values).

Usage: PYTHONPATH=src python -m repro.roofline.refresh [results_dir]
"""

from __future__ import annotations

import json
import os
import sys

from ..configs import ARCHS, SHAPES
from ..launch.cells import analytic_step_flops
from . import analysis as A


def refresh_record(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return rec
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    analytic = analytic_step_flops(cfg, shape)
    rl = rec["roofline"]
    # keep memory/collective from the recorded HLO analysis
    mem_bytes = rec.get("hlo_probe", {}).get("bytes accessed",
                                             rl["bytes_per_device"])
    wire = rl["wire_bytes_per_device"]
    compute_s = analytic / n_dev / A.PEAK_FLOPS
    memory_s = mem_bytes / A.HBM_BW
    collective_s = wire / A.ICI_BW
    step = max(compute_s, memory_s, collective_s)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    rl.update(flops_per_device=analytic / n_dev, bytes_per_device=mem_bytes,
              compute_s=compute_s, memory_s=memory_s,
              collective_s=collective_s,
              dominant=max(terms, key=terms.get))
    rec["analytic_flops_global"] = analytic
    rec["useful_flops_ratio"] = rec["model_flops"] / analytic
    rec["roofline_fraction"] = (rec["model_flops"] / n_dev / A.PEAK_FLOPS
                                / step) if step else None
    rec["step_time_bound_s"] = step
    return rec


def main() -> None:
    results_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    for name in sorted(os.listdir(results_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(results_dir, name)
        with open(path) as f:
            rec = json.load(f)
        rec = refresh_record(rec)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print("refreshed", results_dir)


if __name__ == "__main__":
    main()
