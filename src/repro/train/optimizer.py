"""AdamW (+ global-norm clipping) — built in-house, shard-friendly.

Moments are stored in a configurable dtype: fp32 by default, bf16 for the
1T-param config so optimizer state fits the 512-chip footprint (DESIGN.md §7).
Optimizer state mirrors the parameter tree, so pjit shards it exactly like the
parameters (ZeRO-style: FSDP-sharded params ⇒ FSDP-sharded moments for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "bfloat16" for the 1T config
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay (standard LM schedule)."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params: Any) -> Dict[str, Any]:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: Dict[str, Any]
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mu_n / bc1
        vhat = nu_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mu.dtype), nu_n.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
