"""repro.train — in-house AdamW, train_step factory, fault-tolerant checkpoints."""
from .optimizer import AdamWConfig, apply_updates, init_state, schedule
from .train_step import make_decode_step, make_prefill_step, make_train_step
from . import checkpoint
