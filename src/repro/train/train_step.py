"""train_step / serve_step factories — what the dry-run lowers and drivers run.

``make_train_step`` returns a pure function (params, opt_state, batch, [rng])
→ (params, opt_state, metrics) with optional microbatch gradient accumulation
(a lax.scan over microbatches — activation memory ∝ 1/n_micro, FLOPs
unchanged; required to fit the 1T MoE config's dispatch buffers, DESIGN.md §7).

``make_prefill_step`` / ``make_decode_step`` are the serving entry points the
decode/prefill dry-run cells lower.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import optimizer as opt_mod


def make_train_step(model, opt_cfg: opt_mod.AdamWConfig,
                    n_microbatches: int = 1,
                    grad_sync_dtype: Optional[str] = None) -> Callable:
    """grad_sync_dtype='bfloat16' casts gradients before the data-parallel
    reduction — the DP all-reduce/reduce-scatter then moves half the wire
    bytes (gradient compression; measurable in the roofline collective term).
    Moments still accumulate the dequantized f32 value."""
    sync_dt = {None: None, "float32": None,
               "bfloat16": jnp.bfloat16}[grad_sync_dtype]

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compress(g):
        if sync_dt is None:
            return g
        return jax.tree.map(lambda x: x.astype(sync_dt), g)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = compress(grads)
        else:
            def micro(i, batch=batch):
                return jax.tree.map(
                    lambda x: x.reshape((n_microbatches,
                                         x.shape[0] // n_microbatches)
                                        + x.shape[1:])[i], batch)

            def body(carry, i):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, micro(i))
                g = compress(g)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, g)
                return (g_acc, l_acc + l), ()

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(n_microbatches))
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            metrics = {}
        params, opt_state, om = opt_mod.apply_updates(opt_cfg, params, grads,
                                                      opt_state)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        fe = batch.get("frontend_embeds")
        if fe is not None:
            logits, caches = model.prefill(params, batch["tokens"], fe)
        else:
            logits, caches = model.prefill(params, batch["tokens"])
        return logits, caches

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, token, caches, cur_len):
        return model.decode_step(params, token, caches, cur_len)

    return decode_step
