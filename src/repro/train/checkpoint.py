"""Checkpoint / restore — fault tolerance for long runs (DESIGN.md §7).

Design goals for 1000+ node runs:
  * **Atomic**: write to a tmp dir, fsync, rename — a preempted write never
    corrupts the latest checkpoint.
  * **Async**: ``save_async`` snapshots device arrays to host (cheap) and
    writes on a background thread — training continues immediately.
  * **Elastic**: restore() only needs the *tree*; arrays are ``device_put``
    with whatever sharding the *current* mesh prescribes, so a run checkpointed
    on 512 chips restarts on 256 (or 1 CPU) unchanged.
  * **Self-describing**: a manifest (step, tree structure, shapes/dtypes)
    travels with the data; restore validates structural compatibility.

Format: one .npz of flattened leaves + a JSON manifest. (numpy-only: no
external checkpoint dependency is available in this environment.)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"
_DATA = "arrays.npz"


def _flatten_with_paths(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat = {}
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         extras: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the checkpoint path.

    ``extras``: optional JSON-serializable dict stored in the manifest —
    side-band metadata the arrays alone cannot carry (the simulation
    checkpoints record rung/degradation knobs here; core/simcheck.py).
    """
    import ml_dtypes  # ships with jax

    flat = _flatten_with_paths(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}
    # npz cannot store ml_dtypes (bf16): persist as uint16 views, record the
    # true dtype in the manifest
    stored = {}
    for k, v in host.items():
        if v.dtype == ml_dtypes.bfloat16:
            stored[k] = v.view(np.uint16)
        else:
            stored[k] = v
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, _DATA), **stored)
    manifest = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()}}
    if extras is not None:
        manifest["extras"] = extras
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _update_latest(ckpt_dir, step)
    return path


def _update_latest(ckpt_dir: str, step: int) -> None:
    tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, os.path.join(ckpt_dir, "LATEST"))


class AsyncCheckpointer:
    """Snapshot-to-host then write on a daemon thread (off the critical path)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any,
                   extras: Optional[Dict] = None) -> None:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # device→host snapshot (blocking
        # only for the copy, not the write)

        def _write():
            save(self.ckpt_dir, step, host, extras=extras)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = list_steps(self.ckpt_dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)
        # stale .tmp dirs are crash debris from an interrupted save — never a
        # live write, since saves on one checkpointer are serialized by wait()
        for name in os.listdir(self.ckpt_dir):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.ckpt_dir, name),
                              ignore_errors=True)


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    # Any non-.tmp step dir is complete (atomic rename), and a crash between
    # the rename and the LATEST update leaves LATEST pointing one save back —
    # so the directory listing, not LATEST, is authoritative (LATEST stays
    # on disk as a human-readable hint only).
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_manifest(ckpt_dir: str, step: int) -> Dict:
    """The manifest of one checkpoint (step, leaves, optional extras)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}", _MANIFEST)
    with open(path) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; reshard onto the current mesh.

    ``shardings``: optional pytree (same structure) of NamedSharding — elastic
    restarts pass the *new* mesh's shardings here.
    """
    import ml_dtypes

    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    raw = np.load(os.path.join(path, _DATA))
    data = {}
    for k in raw.files:
        arr = raw[k]
        if manifest["leaves"][k]["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        data[k] = arr
    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(manifest["leaves"])
    extra = set(manifest["leaves"]) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint structure mismatch: missing={missing} "
                         f"extra={extra}")
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}

    out_flat = {}
    for key, leaf in flat_like.items():
        arr = data[key]
        want = tuple(np.asarray(leaf).shape) if not hasattr(leaf, "shape") \
            else tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != expected {want}")
        if key in flat_sh:
            out_flat[key] = jax.device_put(arr, flat_sh[key])
        else:
            out_flat[key] = jax.numpy.asarray(arr)
    # rebuild tree in like's structure
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for pth, _ in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        ordered.append(out_flat[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)
