"""Cell keys: row-major linear codes (grid indexing) + Morton codes (§4.2 sort).

Two distinct key families live here, and the distinction matters (DESIGN.md §3):

* **Linear keys** — row-major box ids ``(ix·dy + iy)·dz + iz`` — index the
  uniform grid (grid.py) and the Pallas column map (kernels/ops.py). The key
  space is exactly ``prod(dims)`` (no power-of-two padding), and the
  fastest-varying axis (z) makes each 3×3×3 neighborhood decompose into 9
  *contiguous* runs of 3 boxes — this is what BioDynaMo's row-major box
  indexing relies on, and what turns neighbor queries into range reads.

* **Morton (Z-order) keys** — paper §4.2 (Agent Sorting and Balancing) — are
  used *only* for the periodic agent-memory-layout sort (engine.sort_pool):
  agents close in 3-D space end up close in memory, improving cache hit rate
  and gather locality. They are deliberately NOT used as grid box ids: Morton
  box ids force the per-box table up to the next power-of-two cube and scatter
  the 27 stencil boxes across the code space (27 independent gathers).

The paper's gap-skipping quadtree traversal (to enumerate Morton codes of a
non-power-of-two grid in linear time without a sort) is a serial-CPU trick; on
TPU the fully-parallel XLA sort is faster, so we intentionally do not port it
(DESIGN.md §11). We keep the paper's choice of Morton over Hilbert (paper
measured only 0.54% difference, Morton decode is far cheaper).

Morton supports 10 bits per dimension in 3-D (grids up to 1024^3 boxes) and 16
bits per dimension in 2-D, using uint32 codes (no x64 requirement).
"""

from __future__ import annotations

import jax.numpy as jnp

# Maximum bits per coordinate for the uint32 3-D code.
MAX_BITS_3D = 10
MAX_BITS_2D = 16


def part1by2(x: jnp.ndarray) -> jnp.ndarray:
    """Spread the low 10 bits of ``x`` so there are two zero bits between each.

    Classic magic-number bit spread; input/output uint32.
    """
    x = x.astype(jnp.uint32) & jnp.uint32(0x3FF)
    x = (x | (x << 16)) & jnp.uint32(0x030000FF)
    x = (x | (x << 8)) & jnp.uint32(0x0300F00F)
    x = (x | (x << 4)) & jnp.uint32(0x030C30C3)
    x = (x | (x << 2)) & jnp.uint32(0x09249249)
    return x


def compact1by2(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`part1by2` (keeps every third bit)."""
    x = x.astype(jnp.uint32) & jnp.uint32(0x09249249)
    x = (x ^ (x >> 2)) & jnp.uint32(0x030C30C3)
    x = (x ^ (x >> 4)) & jnp.uint32(0x0300F00F)
    x = (x ^ (x >> 8)) & jnp.uint32(0x030000FF)
    x = (x ^ (x >> 16)) & jnp.uint32(0x000003FF)
    return x


def part1by1(x: jnp.ndarray) -> jnp.ndarray:
    """Spread the low 16 bits of ``x`` with one zero bit between each."""
    x = x.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def compact1by1(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`part1by1`."""
    x = x.astype(jnp.uint32) & jnp.uint32(0x55555555)
    x = (x ^ (x >> 1)) & jnp.uint32(0x33333333)
    x = (x ^ (x >> 2)) & jnp.uint32(0x0F0F0F0F)
    x = (x ^ (x >> 4)) & jnp.uint32(0x00FF00FF)
    x = (x ^ (x >> 8)) & jnp.uint32(0x0000FFFF)
    return x


def encode3(ix: jnp.ndarray, iy: jnp.ndarray, iz: jnp.ndarray) -> jnp.ndarray:
    """3-D Morton code from integer cell coordinates (each < 2**10). uint32."""
    return part1by2(ix) | (part1by2(iy) << 1) | (part1by2(iz) << 2)


def decode3(code: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Inverse of :func:`encode3` → (ix, iy, iz) uint32."""
    code = code.astype(jnp.uint32)
    return compact1by2(code), compact1by2(code >> 1), compact1by2(code >> 2)


def encode2(ix: jnp.ndarray, iy: jnp.ndarray) -> jnp.ndarray:
    """2-D Morton code from integer cell coordinates (each < 2**16). uint32."""
    return part1by1(ix) | (part1by1(iy) << 1)


def decode2(code: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    code = code.astype(jnp.uint32)
    return compact1by1(code), compact1by1(code >> 1)


def cell_of(position: jnp.ndarray, origin: jnp.ndarray, box_size: float,
            dims: tuple[int, int, int]) -> jnp.ndarray:
    """Integer cell coordinates of 3-D positions, clipped into the grid.

    position: (..., 3) float; origin: (3,) float; dims: static grid extents.
    Returns (..., 3) int32.
    """
    rel = (position - origin) / box_size
    cell = jnp.floor(rel).astype(jnp.int32)
    hi = jnp.asarray([dims[0] - 1, dims[1] - 1, dims[2] - 1], dtype=jnp.int32)
    return jnp.clip(cell, 0, hi)


def morton_keys(position: jnp.ndarray, origin: jnp.ndarray, box_size: float,
                dims: tuple[int, int, int]) -> jnp.ndarray:
    """Morton sort key (uint32) per agent — §4.2 memory-layout sort only.

    Agents in the same grid box share a key; sorting by this key orders boxes
    along the space-filling curve ('linked-list elements will be closer to
    each other'). Grid *indexing* uses :func:`linear_keys` instead
    (DESIGN.md §3).
    """
    cell = cell_of(position, origin, box_size, dims)
    return encode3(cell[..., 0], cell[..., 1], cell[..., 2])


def code_space_size(dims: tuple[int, int, int]) -> int:
    """Size of a dense Morton-indexed table covering grid ``dims``.

    The Morton code space is the cube of the next power of two of max(dims):
    2**(3*bits) — over-allocated for non-pow2/anisotropic grids (the paper's
    'gaps'). Kept for the §4.2 sort-key analysis; grid tables use
    :func:`linear_size` instead (exactly prod(dims), DESIGN.md §3).
    """
    m = max(dims)
    bits = max(1, (m - 1).bit_length())
    if bits > MAX_BITS_3D:
        raise ValueError(f"grid dim {m} needs {bits} bits/axis > {MAX_BITS_3D}")
    return 1 << (3 * bits)


# ---------------------------------------------------------------------------
# Row-major linear cell keys (grid indexing — DESIGN.md §3)
# ---------------------------------------------------------------------------

def linear_size(dims: tuple[int, int, int]) -> int:
    """Size of the dense linear-key table: exactly ``prod(dims)`` boxes."""
    n = dims[0] * dims[1] * dims[2]
    if n >= 2 ** 31:
        raise ValueError(f"grid {dims} has {n} boxes > int32 key space")
    return n


def linear_encode3(ix: jnp.ndarray, iy: jnp.ndarray, iz: jnp.ndarray,
                   dims: tuple[int, int, int]) -> jnp.ndarray:
    """Row-major box id with z fastest-varying (uint32).

    Adjacent-z boxes get adjacent ids, so a 3-box z-run of the 3×3×3 stencil
    is one contiguous key range.
    """
    ix = ix.astype(jnp.uint32)
    iy = iy.astype(jnp.uint32)
    iz = iz.astype(jnp.uint32)
    return (ix * jnp.uint32(dims[1]) + iy) * jnp.uint32(dims[2]) + iz


def linear_decode3(code: jnp.ndarray, dims: tuple[int, int, int]
                   ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Inverse of :func:`linear_encode3` → (ix, iy, iz) uint32."""
    code = code.astype(jnp.uint32)
    iz = code % jnp.uint32(dims[2])
    rest = code // jnp.uint32(dims[2])
    iy = rest % jnp.uint32(dims[1])
    ix = rest // jnp.uint32(dims[1])
    return ix, iy, iz


def linear_keys(position: jnp.ndarray, origin: jnp.ndarray, box_size: float,
                dims: tuple[int, int, int]) -> jnp.ndarray:
    """Row-major linear box id (uint32) per agent — the grid sort key.

    Sorting by this key groups agents by box and orders boxes row-major, so
    every box — and every 3-box z-run — is a contiguous span of the sorted
    pool (DESIGN.md §3).
    """
    cell = cell_of(position, origin, box_size, dims)
    return linear_encode3(cell[..., 0], cell[..., 1], cell[..., 2], dims)


# Dead slots carry the maximum key so any key sort doubles as compaction:
# live agents land in [0, n_live) in box order, dead slots sink to the tail.
DEAD_KEY = jnp.uint32(0xFFFFFFFF)


def grid_sort_keys(position: jnp.ndarray, alive: jnp.ndarray,
                   origin: jnp.ndarray, box_size: float,
                   dims: tuple[int, int, int]) -> jnp.ndarray:
    """The resident-layout sort key: linear box id, dead slots → DEAD_KEY.

    One argsort of this key is simultaneously the grid build order, the §4.2
    memory-locality sort, and dead-slot compaction (DESIGN.md §3.2) — the
    three reorderings the engine used to do separately compose into a single
    permutation.
    """
    keys = linear_keys(position, origin, box_size, dims)
    return jnp.where(alive, keys, DEAD_KEY)
