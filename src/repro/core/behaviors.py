"""Behavior system — paper §2: behaviors are per-agent actions; operations apply them.

Behaviors read the step context (pool, grid, diffusion, RNG) and return
*effects*: channel updates, staged births, death marks, substance secretion.
The engine merges effects and commits them in the iteration epilogue —
mirroring BioDynaMo's thread-local staging + end-of-iteration commit (§3.2).

**Ownership contract (DESIGN.md §7):** a behavior's base mask is
``ctx.owned``, never ``pool.alive``. Under the single-device engine the two
are identical; under the distributed engine ``pool.alive`` additionally
covers *ghost* rows — boundary agents copied in from neighboring slabs as
force/neighbor sources. Acting on a ghost (staging its division, marking its
death) would duplicate the effect its owning shard commits authoritatively.
Ghosts still appear as *neighbors* in ``ctx.neighbor_apply`` reductions,
which is exactly what makes cross-slab interactions exact.

All per-agent randomness is drawn through :mod:`rand` (capacity-stable
threefry streams): the value an agent sees depends on (key, slot, lane) but
never on the pool's capacity, so the capacity ladder (DESIGN.md §4.3) can
grow the pool mid-run without perturbing the trajectory — ``jax.random``'s
array draws do not have this property.

The catalogue below covers the paper's five benchmark simulations (Table 1):
  GrowDivide          cell proliferation / oncology (create agents)
  RandomWalk          epidemiology / oncology (agents move randomly)
  Infection+Recovery  epidemiology (SIR over spatial neighbors)
  Chemotaxis          cell clustering (move along substance gradient)
  Secretion           cell clustering / neuroscience (substance sources)
  RandomDeath         oncology (delete agents)
  NeuriteGrowth       neuroscience (growth cones + static trail + bifurcation)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import rand
from .agents import AgentPool
from .grid import PairKernel


def resolve(value, ctx):
    """Realize a behavior knob against the step context.

    Every numeric behavior parameter (``Infection.beta``, ``RandomWalk.sigma``,
    ``GrowDivide.rate``, ...) accepts either a plain number — the static,
    compiled-in value — or a *callable* ``ctx -> value`` evaluated at trace
    time against the :class:`~.engine.StepContext`. The callable form is how
    ensemble lanes get per-lane rates without recompiling: pass
    ``Infection(beta=lambda ctx: ctx.params["beta"])`` and feed the rate
    through ``ScenarioParams(rates={"beta": ...})`` — under
    ``make_ensemble_core`` the traced scalar differs per lane while the
    program stays one compilation.
    """
    return value(ctx) if callable(value) else value


@dataclasses.dataclass
class BehaviorEffects:
    """What a behavior wants to change. All optional; engine merges in order."""
    set_channels: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    birth_channels: Optional[Dict[str, jnp.ndarray]] = None   # (Q, ...) staged agents
    birth_valid: Optional[jnp.ndarray] = None                 # (Q,) bool
    death_mask: Optional[jnp.ndarray] = None                  # (C,) bool
    secretion: Optional[jnp.ndarray] = None                   # (C,) amounts


class Behavior:
    """Base class. Subclasses override extra_specs() and __call__().

    Neighbor-using behaviors additionally override :meth:`neighbor_kernels`
    to declare their pair kernels with an explicit channel footprint
    (grid.PairKernel). The engine registers every declared kernel into ONE
    fused sweep per step (together with the collision force) and hands the
    results back through ``ctx.neighbor_results[kernel.name]`` — the 9 z-runs
    are gathered once per block for all of them, pruned to the union of
    declared footprints (DESIGN.md §3.2). ``__call__`` should consume
    ``ctx.neighbor_results`` when its kernel name is present and fall back to
    ``ctx.neighbor_apply`` otherwise (sequential path: non-uniform-grid
    environments, or ``EngineConfig.fused_sweep=False``).
    """

    name: str = "behavior"

    def extra_specs(self) -> Dict[str, tuple]:
        """Channels this behavior needs: name → (shape_suffix, dtype, fill)."""
        return {}

    def neighbor_kernels(self) -> Tuple[PairKernel, ...]:
        """Pair kernels to register into the step's fused neighbor sweep."""
        return ()

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        raise NotImplementedError


class GrowDivide(Behavior):
    """Grow diameter at ``rate``; split once above ``threshold_diameter``.

    Division: mother shrinks to volume/2, daughter (staged birth) placed at a
    random direction at center distance = mother radius (BioDynaMo CellDivision).
    """

    name = "grow_divide"

    def __init__(self, rate: float = 1.0, threshold_diameter: float = 12.0,
                 applies_to: int | None = None):
        self.rate = rate
        self.threshold = threshold_diameter
        self.applies_to = applies_to

    def _mask(self, ctx, pool):
        m = ctx.owned
        if self.applies_to is not None:
            m &= pool.agent_type == self.applies_to
        return m

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        mask = self._mask(ctx, pool)
        rate = resolve(self.rate, ctx)
        threshold = resolve(self.threshold, ctx)
        new_dia = jnp.where(mask, pool.diameter + rate * ctx.dt, pool.diameter)
        divide = mask & (new_dia >= threshold)
        # halve the volume: d' = d / 2^(1/3)
        halved = new_dia * (0.5 ** (1.0 / 3.0))
        mother_dia = jnp.where(divide, halved, new_dia)
        # daughter placement (capacity-stable draw: ladder parity)
        direction = rand.normal_rows(rng, pool.capacity, 3)
        direction /= jnp.sqrt(
            jnp.sum(direction * direction, -1, keepdims=True) + 1e-12)
        d_pos = pool.position + direction * (mother_dia * 0.5)[:, None]
        return BehaviorEffects(
            set_channels={"diameter": mother_dia},
            birth_channels={"position": d_pos, "diameter": mother_dia,
                            "agent_type": pool.agent_type},
            birth_valid=divide,
        )


class RandomWalk(Behavior):
    """Brownian step of scale sigma (epidemiology/oncology random movement)."""

    name = "random_walk"

    def __init__(self, sigma: float = 1.0, applies_to: int | None = None):
        self.sigma = sigma
        self.applies_to = applies_to

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        mask = ctx.owned
        if self.applies_to is not None:
            mask &= pool.agent_type == self.applies_to
        step = resolve(self.sigma, ctx) * rand.normal_rows(rng, pool.capacity, 3)
        new_pos = jnp.where(mask[:, None], pool.position + step * ctx.dt,
                            pool.position)
        new_pos = jnp.clip(new_pos, ctx.domain_lo, ctx.domain_hi)
        return BehaviorEffects(set_channels={"position": new_pos})


# SIR agent_type encoding used by the epidemiology simulation.
SUSCEPTIBLE, INFECTED, RECOVERED = 0, 1, 2


class Infection(Behavior):
    """SIR infection over spatial neighbors (paper epidemiology use case).

    Susceptible agents with ≥1 infected neighbor within ``radius`` become
    infected with probability ``beta``; infected agents recover after
    ``recovery_time`` iterations (timer channel).
    """

    name = "infection"

    def __init__(self, radius: float = 2.0, beta: float = 0.3,
                 recovery_time: int = 50):
        self.radius = radius
        self.beta = beta
        self.recovery_time = recovery_time

    def extra_specs(self):
        return {"infect_timer": ((), jnp.int32, 0)}

    def _pair_fn(self):
        r = self.radius

        def pair_fn(q, nbr, valid, q_slot):
            d = nbr["position"] - q["position"][:, None, :]
            dist2 = jnp.sum(d * d, axis=-1)
            # NOTE the INCLUSIVE dist² ≤ r² test: the pair-list build filter
            # (grid.build_pairlist) is inclusive at (r+skin)² for exactly
            # this reason — an equality-distance infected neighbor must
            # survive the pruning. Out-of-range candidates contribute int 0
            # to the OR-count, so pruning/stale extras are exact no-ops.
            exposed = valid & nbr["alive"] & (nbr["agent_type"] == INFECTED) \
                & (dist2 <= r * r)
            # OR encoded as an additive count across the 9 streamed runs;
            # the consumer thresholds it (resident_apply output contract)
            return {"exposed": jnp.any(exposed, axis=-1).astype(jnp.int32)}

        return pair_fn

    def neighbor_kernels(self):
        return (PairKernel(name=self.name, pair_fn=self._pair_fn(),
                           out_specs={"exposed": ((), jnp.int32)},
                           reads=("position", "alive", "agent_type")),)

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        res = ctx.neighbor_results.get(self.name)
        if res is None:   # sequential path: its own sweep over the same
            res = ctx.neighbor_apply(self._pair_fn(),   # pre-force snapshot
                                     {"exposed": ((), jnp.int32)})
        exposed = res["exposed"] > 0
        u = rand.uniform_rows(rng, pool.capacity)
        newly = ctx.owned & (pool.agent_type == SUSCEPTIBLE) & exposed \
            & (u < resolve(self.beta, ctx))
        timer = pool.extra["infect_timer"]
        recovery = jnp.asarray(resolve(self.recovery_time, ctx), timer.dtype)
        timer = jnp.where(newly, recovery, timer)
        is_inf = pool.agent_type == INFECTED
        timer = jnp.where(is_inf, timer - 1, timer)
        recovered = is_inf & (timer <= 0)
        new_type = jnp.where(newly, INFECTED, pool.agent_type)
        new_type = jnp.where(recovered, RECOVERED, new_type)
        return BehaviorEffects(
            set_channels={"agent_type": new_type, "extra.infect_timer": timer})


class Chemotaxis(Behavior):
    """Move up the gradient of the diffusion substance (cell clustering)."""

    name = "chemotaxis"

    def __init__(self, speed: float = 0.5):
        self.speed = speed

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        g = ctx.substance_gradient(pool.position)           # (C, 3)
        norm = jnp.sqrt(jnp.sum(g * g, -1, keepdims=True) + 1e-12)
        step = resolve(self.speed, ctx) * ctx.dt * g / norm
        new_pos = jnp.where(ctx.owned[:, None], pool.position + step,
                            pool.position)
        new_pos = jnp.clip(new_pos, ctx.domain_lo, ctx.domain_hi)
        return BehaviorEffects(set_channels={"position": new_pos})


class Secretion(Behavior):
    """Secrete ``rate`` into the substance grid at the agent's voxel."""

    name = "secretion"

    def __init__(self, rate: float = 1.0, applies_to: int | None = None):
        self.rate = rate
        self.applies_to = applies_to

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        mask = ctx.owned
        if self.applies_to is not None:
            mask &= pool.agent_type == self.applies_to
        return BehaviorEffects(
            secretion=jnp.where(mask, resolve(self.rate, ctx) * ctx.dt, 0.0))


class RandomDeath(Behavior):
    """Remove agents with probability ``rate`` per iteration (oncology)."""

    name = "random_death"

    def __init__(self, rate: float = 0.001, applies_to: int | None = None):
        self.rate = rate
        self.applies_to = applies_to

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        mask = ctx.owned
        if self.applies_to is not None:
            mask &= pool.agent_type == self.applies_to
        u = rand.uniform_rows(rng, pool.capacity)
        return BehaviorEffects(death_mask=mask & (u < resolve(self.rate, ctx)))


# Neuroscience: growth cones extend and leave a static trail (paper §5:
# "neural development simulations might only have an active growth front,
# while the remaining part of the neuron is unchanged").
SOMA, NEURITE_SEGMENT, GROWTH_CONE = 10, 11, 12


class NeuriteGrowth(Behavior):
    """Growth cones elongate along a persistent direction with noise, deposit
    NEURITE_SEGMENT agents behind them, and occasionally bifurcate."""

    name = "neurite_growth"

    def __init__(self, speed: float = 1.0, noise: float = 0.15,
                 bifurcation_prob: float = 0.004, segment_every: float = 2.0):
        self.speed = speed
        self.noise = noise
        self.bif_prob = bifurcation_prob
        self.segment_every = segment_every

    def extra_specs(self):
        return {"direction": ((3,), jnp.float32, 0.0),
                "path_len": ((), jnp.float32, 0.0)}

    def __call__(self, ctx, pool: AgentPool, rng: jax.Array) -> BehaviorEffects:
        k1, k2, k3 = jax.random.split(rng, 3)
        cones = ctx.owned & (pool.agent_type == GROWTH_CONE)
        d = pool.extra["direction"]
        d = d + self.noise * rand.normal_rows(k1, pool.capacity, 3)
        d /= jnp.sqrt(jnp.sum(d * d, -1, keepdims=True) + 1e-12)
        step = self.speed * ctx.dt
        new_pos = jnp.where(cones[:, None], pool.position + d * step, pool.position)
        new_pos = jnp.clip(new_pos, ctx.domain_lo, ctx.domain_hi)
        path = jnp.where(cones, pool.extra["path_len"] + step, pool.extra["path_len"])

        # deposit a (soon static) segment agent at the old position
        deposit = cones & (path >= self.segment_every)
        path = jnp.where(deposit, 0.0, path)
        seg_type = jnp.full_like(pool.agent_type, NEURITE_SEGMENT)

        # bifurcation: stage a second cone with a rotated direction
        u = rand.uniform_rows(k2, pool.capacity)
        bif = cones & (u < self.bif_prob)
        rot = d + 0.8 * rand.normal_rows(k3, pool.capacity, 3)
        rot /= jnp.sqrt(jnp.sum(rot * rot, -1, keepdims=True) + 1e-12)
        cone_type = jnp.full_like(pool.agent_type, GROWTH_CONE)

        birth = {
            "position": jnp.concatenate([pool.position, new_pos], 0),
            "diameter": jnp.concatenate([pool.diameter, pool.diameter], 0),
            "agent_type": jnp.concatenate([seg_type, cone_type], 0),
            "extra.direction": jnp.concatenate([jnp.zeros_like(d), rot], 0),
            "extra.path_len": jnp.zeros((2 * pool.capacity,), path.dtype),
        }
        valid = jnp.concatenate([deposit, bif], 0)
        return BehaviorEffects(
            set_channels={"position": new_pos, "extra.direction": d,
                          "extra.path_len": path},
            birth_channels=birth, birth_valid=valid)
