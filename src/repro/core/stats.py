"""Per-iteration statistics — one dataclass shared by both engines.

The single-device engine used a plain dict and the distributed engine grew its
own ad-hoc per-shard dict; overflow observability (DESIGN.md §4.2 — the engine
never silently drops interactions) now flows through this one structure for
both. Fields the single-device engine cannot produce (halo/migration traffic)
are simply zero there, so monitoring code is engine-agnostic.

Shapes: scalars () in the single-device engine; (n_shards,) per-shard vectors
in the distributed engine (one entry per slab). Dict-style access
(``stats["n_live"]``) is kept so existing callers and tests read either engine
the same way.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StepStats:
    """Counters of one iteration (paper 'statistics' standalone operation).

    n_live:           live agents at iteration end
    n_active:         force-computed agents still alive at iteration end
                      (§5 static skipping makes this < n_live)
    births / deaths:  agents added / removed this iteration (§3.2)
    box_overflow:     grid run / hash bucket / Pallas column-map capacity
                      exceeded — possibly-missed neighbor pairs (§4.2)
    birth_overflow:   staged newborns that did not fit in capacity
    halo_overflow:    ghost-band agents that did not fit the halo buffer
                      (distributed only; §7)
    migrate_overflow: migrating agents dropped for buffer/capacity reasons
                      (distributed only; §7)
    in_flight:        owned agents still outside their slab after this step's
                      ring hop (displaced ≥2 slabs by a rebalance). Nothing
                      was dropped — they converge one hop per step — but
                      their next iteration runs with an incomplete
                      neighborhood, so the flag shares the never-silent
                      contract (distributed only; §7)
    thin_slab:        an interior slab is thinner than the ghost band, so the
                      one-hop ring cannot ship every cross-shard pair
                      (distributed only; §7). NOT fixable by growing a
                      buffer — kept separate from halo_overflow so the
                      capacity ladder knows the difference (§4.3)
    box_demand:       which-capacity provenance for box_overflow: the largest
                      observed 3-box z-run (uniform grid) or hash bucket
                      occupancy this step. The capacity ladder sizes the next
                      ``max_per_run`` / ``max_per_box`` rung directly from it
    capacity_demand:  slots the pool would have needed this step to commit
                      every staged agent (live + dropped); the ladder's
                      ``capacity`` / ``local_capacity`` rung target
    pair_overflow:    a Verlet pair-list row demanded more than
                      ``pairlist.max_pairs`` entries this build — truncated
                      candidates mean possibly-missed pairs (§4.2). The
                      ladder grows the ``max_pairs`` rung from pair_demand
    pair_demand:      which-capacity provenance for pair_overflow: the
                      largest observed per-agent in-range(+skin) candidate
                      count of the current pair list (0 when disabled)
    rebuilds:         1 if this step rebuilt its environment (grid build ran)
    rebuild_skips:    1 if this step reused a cached build instead
                      (RebuildPolicy mode='every_k'; grid.py). The two split
                      every step, so their running sums audit the skip rate
    health:           numerical-health bitmask (health.py: NONFINITE |
                      ESCAPE | DISPLACEMENT), evaluated in-graph by the
                      iteration core. Observability only — supervisors
                      (simcheck.SupervisedRunner) act on it; run() ignores it
    """

    n_live: jnp.ndarray
    n_active: jnp.ndarray
    births: jnp.ndarray
    deaths: jnp.ndarray
    box_overflow: jnp.ndarray
    birth_overflow: jnp.ndarray
    halo_overflow: jnp.ndarray
    migrate_overflow: jnp.ndarray
    in_flight: jnp.ndarray
    thin_slab: jnp.ndarray
    box_demand: jnp.ndarray
    capacity_demand: jnp.ndarray
    pair_overflow: jnp.ndarray
    pair_demand: jnp.ndarray
    rebuilds: jnp.ndarray
    rebuild_skips: jnp.ndarray
    health: jnp.ndarray

    FIELDS = ("n_live", "n_active", "births", "deaths", "box_overflow",
              "birth_overflow", "halo_overflow", "migrate_overflow",
              "in_flight", "thin_slab", "box_demand", "capacity_demand",
              "pair_overflow", "pair_demand",
              "rebuilds", "rebuild_skips", "health")

    # the §4.2 never-silent-loss flags (demands and health are not overflow)
    OVERFLOW_FIELDS = ("box_overflow", "birth_overflow", "halo_overflow",
                       "migrate_overflow", "in_flight", "thin_slab",
                       "pair_overflow")

    @classmethod
    def zeros(cls, shape: tuple = ()) -> "StepStats":
        return cls(**{f: jnp.zeros(shape, jnp.int32) for f in cls.FIELDS})

    # dict-style access so both engines' stats read identically
    def __getitem__(self, key: str) -> jnp.ndarray:
        if key not in self.FIELDS:
            raise KeyError(key)
        return getattr(self, key)

    def keys(self):
        return iter(self.FIELDS)

    def items(self):
        return ((f, getattr(self, f)) for f in self.FIELDS)

    def overflowed(self) -> jnp.ndarray:
        """Any never-silent-loss flag set (§4.2 contract, either engine).

        Demands (box_demand / capacity_demand) are provenance, not flags —
        they are excluded; thin_slab and in_flight are exactness flags and
        count. Traced form (usable in-graph); host code wanting a plain bool
        uses :meth:`any_overflow`."""
        total = sum((jnp.sum(getattr(self, f)) for f in self.OVERFLOW_FIELDS),
                    jnp.zeros((), jnp.int32))
        return total > 0

    def flags(self) -> Dict[str, int]:
        """Host-side: the nonzero never-silent flags, ``{field: total}``.

        Sums over shards (per-shard vectors in the distributed engine), so
        monitoring code never hand-enumerates the overflow fields again:
        ``if stats.flags(): ...`` / ``sum(stats.flags().values())``.
        """
        out = {}
        for f in self.OVERFLOW_FIELDS:
            v = int(np.asarray(jnp.sum(getattr(self, f))))
            if v:
                out[f] = v
        return out

    def any_overflow(self) -> bool:
        """Host-side bool form of :meth:`overflowed`."""
        return bool(np.asarray(self.overflowed()))

    def health_bits(self) -> int:
        """Host-side OR of the health bitmask across shards (health.py)."""
        return int(np.bitwise_or.reduce(
            np.asarray(self.health, np.int32).ravel(), initial=0))
