"""Agent pool — fixed-capacity SoA storage (paper ResourceManager + §4.3 pool allocator).

BioDynaMo's ResourceManager stores raw agent pointers per NUMA domain and its
pool allocator hands out fixed-size elements from preallocated blocks. Under
jit, XLA forbids dynamic allocation entirely, so the TPU-native endpoint of the
paper's idea is a *fully preallocated* structure-of-arrays pool with an ``alive``
mask: dead slots are the free list, and 'allocation' is slot reservation via a
prefix sum (compaction.py). One XLA program serves the whole simulation.

Invariant maintained by the engine (mirrors the paper's "disallow empty vector
elements in the ResourceManager"): live agents occupy slots ``[0, n_live)``;
slots ``[n_live, capacity)`` are free. This makes per-device partitioning and
the windowed force kernel's index math trivial.

Under the resident grid layout (grid.build_resident, DESIGN.md §3.2) the
engine strengthens this at every grid build: live agents sit in [0, n_live)
*in row-major grid-key order* — agents of the same box are adjacent, boxes
are adjacent along z. Slot ids are therefore stable only within an iteration;
anything tracking agents across steps must key on channel state, not slot
index (the permutation is returned by build_resident for callers that need
to re-map).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Per-channel storage dtypes — each capacity rung holds more agents/byte.

    Positions stay float32 unconditionally (force accuracy and grid keys
    depend on them); the policy only narrows *auxiliary* channels:

      aux_float:    dtype name for ``diameter`` and every float32 behavior
                    extra channel ('float32' | 'bfloat16' | 'float16').
                    Narrowing is a tolerance trade, not bit-exact — the
                    ladder parity contract is float32-policy only.
      compact_ints: store ``agent_type`` and ``force_nnz`` as int16.
                    Range-safe when type ids < 32768 and an agent's neighbor
                    count < 32768 (both hold for every paper scenario);
                    ``born_iter`` stays int32 (iteration counts don't fit).

    Strings (not dtypes) keep the policy hashable inside the frozen
    EngineConfig jit cache key.
    """

    aux_float: str = "float32"
    compact_ints: bool = False

    @property
    def aux_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.aux_float)

    @property
    def int_dtype(self) -> jnp.dtype:
        return jnp.dtype(jnp.int16 if self.compact_ints else jnp.int32)

    def extra_dtype(self, declared: Any) -> jnp.dtype:
        """Storage dtype for a behavior extra channel declared as ``declared``."""
        if jnp.dtype(declared) == jnp.dtype(jnp.float32):
            return self.aux_dtype
        return jnp.dtype(declared)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AgentPool:
    """Structure-of-arrays agent storage. All arrays have leading dim = capacity.

    Fields:
      position:   (C, 3) float — agent center.
      diameter:   (C,)   float — sphere diameter.
      agent_type: (C,)   int32 — user-defined type id (e.g. cell type, SIR state).
      alive:      (C,)   bool  — live mask; live agents are compacted to the front.
      static:     (C,)   bool  — static-region flag (paper §5); static agents skip
                                 the pairwise force computation.
      moved:      (C,)   bool  — condition (i) bookkeeping: displaced last iteration.
      grew:       (C,)   bool  — condition (ii): force-relevant attribute increased.
      born_iter:  (C,)   int32 — iteration of creation (condition (iii) support).
      force_nnz:  (C,)   int32 — count of non-zero neighbor forces last iteration
                                 (condition (iv)).
      extra:      dict of (C, ...) arrays — per-behavior state channels
                  (e.g. infection timer, growth rate, neurite direction).
    """

    position: jnp.ndarray
    diameter: jnp.ndarray
    agent_type: jnp.ndarray
    alive: jnp.ndarray
    static: jnp.ndarray
    moved: jnp.ndarray
    grew: jnp.ndarray
    born_iter: jnp.ndarray
    force_nnz: jnp.ndarray
    extra: Dict[str, jnp.ndarray]

    @property
    def capacity(self) -> int:
        return self.position.shape[0]

    @property
    def n_live(self) -> jnp.ndarray:
        """Number of live agents (traced scalar)."""
        return jnp.sum(self.alive.astype(jnp.int32))

    def channels(self) -> Dict[str, jnp.ndarray]:
        """Flat view of every per-agent channel (for reorder/compaction)."""
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.name != "extra"}
        for k, v in self.extra.items():
            out["extra." + k] = v
        return out

    def with_channels(self, ch: Dict[str, jnp.ndarray]) -> "AgentPool":
        return pool_from_channels(ch)


def pool_from_channels(ch: Dict[str, jnp.ndarray]) -> AgentPool:
    """Rebuild a pool from a flat channel dict (inverse of ``channels()``).

    The channel-name set *is* the pool's spec: the distributed engine derives
    its ghost/migration buffer layout from it (DESIGN.md §7), so behaviors'
    extra channels automatically cross shard boundaries.
    """
    base = {k: v for k, v in ch.items() if not k.startswith("extra.")}
    extra = {k[len("extra."):]: v for k, v in ch.items()
             if k.startswith("extra.")}
    return AgentPool(extra=extra, **base)


def make_pool(capacity: int,
              n_live: int = 0,
              position: jnp.ndarray | None = None,
              diameter: jnp.ndarray | None = None,
              agent_type: jnp.ndarray | None = None,
              extra_specs: Dict[str, Any] | None = None,
              dtype: jnp.dtype = jnp.float32,
              policy: DtypePolicy | None = None) -> AgentPool:
    """Allocate a pool of ``capacity`` slots; fill the first ``n_live`` from args.

    ``extra_specs`` maps channel name → (shape_suffix, dtype, fill_value) or an
    (n_live, ...) array of initial values. ``policy`` narrows auxiliary channel
    dtypes (DtypePolicy); positions keep ``dtype`` (float32) regardless.
    """
    policy = policy or DtypePolicy()
    if position is not None:
        n_live = position.shape[0]

    def pad(arr, fill, shape_suffix=(), dt=None):
        dt = dt or (arr.dtype if arr is not None else dtype)
        full = jnp.full((capacity, *shape_suffix), fill, dtype=dt)
        if arr is not None and n_live > 0:
            full = full.at[:n_live].set(arr.astype(dt))
        return full

    pos = pad(position, 0.0, (3,), dtype)
    dia = pad(diameter, 0.0, (), policy.aux_dtype) if diameter is not None \
        else pad(None, 10.0, (), policy.aux_dtype)
    if diameter is None and n_live > 0:
        dia = dia.at[:n_live].set(10.0)
    typ = pad(agent_type, 0, (), policy.int_dtype) if agent_type is not None \
        else jnp.zeros((capacity,), policy.int_dtype)
    alive = jnp.arange(capacity) < n_live

    extra = {}
    for name, spec in (extra_specs or {}).items():
        if isinstance(spec, tuple):
            shape_suffix, dt, fill = spec
            extra[name] = jnp.full((capacity, *shape_suffix), fill,
                                   dtype=policy.extra_dtype(dt))
        else:  # array of initial live values
            arr = jnp.asarray(spec)
            dt = policy.extra_dtype(arr.dtype)
            full = jnp.zeros((capacity, *arr.shape[1:]), dtype=dt)
            extra[name] = full.at[:n_live].set(arr.astype(dt))

    return AgentPool(
        position=pos,
        diameter=dia,
        agent_type=typ,
        alive=alive,
        static=jnp.zeros((capacity,), bool),
        moved=jnp.ones((capacity,), bool),   # everything "moved" at t=0: no static skips
        grew=jnp.zeros((capacity,), bool),
        born_iter=jnp.zeros((capacity,), jnp.int32),
        force_nnz=jnp.zeros((capacity,), policy.int_dtype),
        extra=extra,
    )
