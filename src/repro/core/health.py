"""Numerical health guards — in-graph watchdog for long runs (DESIGN.md §7.5).

A multi-hour run at paper scale (1.72e9 agents, and the TeraAgent successor's
half-trillion) cannot afford to discover a NaN at the end: one bad step
silently poisons every later one. The guard evaluates three predicates
*inside* the jitted iteration, over channels the step already produced, and
folds them into one bitmask reduction per step (``StepStats.health``):

  ``NONFINITE``     — a live agent's position (or its computed force) holds
                      NaN/Inf. Catches diverging force integration, bad
                      behavior arithmetic, and injected bit corruption.
  ``ESCAPE``        — a live agent sits outside the domain box (plus
                      ``domain_tol`` slack). The engine clips force
                      displacement to the box, so an escape means a behavior
                      wrote an out-of-domain position.
  ``DISPLACEMENT``  — an agent moved further in one step (per axis) than
                      ``max_step_displacement``, the force-stability bound:
                      forces cap at ``ForceParams.max_displacement``, and
                      ``RebuildPolicy`` every_k coverage assumes bounded
                      per-step motion, so exceeding it signals instability.

The flags are *observability*, exactly like the overflow flags: nothing in
the engine raises on them. Supervisors (simcheck.SupervisedRunner) read
``StepStats.health`` on the host and roll back / degrade; plain ``run`` calls
can ignore them.

The module also hosts the test-only **fault injection** hooks: deterministic
host-side corruption of a state between steps (NaN write, bit flip,
overflow-flag storm), so every recovery path can be exercised without waiting
for a real fault. They are ordinary pure functions over the state pytrees —
nothing in the engine references them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .agents import pool_from_channels

# health bitmask bits (StepStats.health)
NONFINITE = 1
ESCAPE = 2
DISPLACEMENT = 4

_FLAG_NAMES = ((NONFINITE, "nonfinite"), (ESCAPE, "domain_escape"),
               (DISPLACEMENT, "displacement"))


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Which health predicates the iteration evaluates (jit-static).

    check_finite:           NaN/Inf in live positions and computed forces.
    check_domain:           live position outside [domain_lo - domain_tol,
                            domain_hi + domain_tol].
    domain_tol:             slack beyond the box (behaviors clip to the box
                            exactly, so 0.0 is already safe; positive values
                            tolerate deliberate out-of-box behaviors).
    max_step_displacement:  per-axis per-step displacement bound (None =
                            predicate off). Sensible setting: a small
                            multiple of ForceParams.max_displacement plus
                            the largest behavior step.
    """

    check_finite: bool = True
    check_domain: bool = True
    domain_tol: float = 0.0
    max_step_displacement: Optional[float] = None

    @property
    def any_enabled(self) -> bool:
        return (self.check_finite or self.check_domain
                or self.max_step_displacement is not None)


def step_health(hcfg: HealthConfig, mask: jnp.ndarray, position: jnp.ndarray,
                domain_lo: jnp.ndarray, domain_hi: jnp.ndarray,
                force: Optional[jnp.ndarray] = None,
                move_d: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """() int32 bitmask over the enabled predicates, one fused reduction.

    mask: (C,) bool — rows the caller owns (ghost rows report on their owner
    shard). Every predicate is evaluated element-wise into one stacked (K, C)
    array reduced by a single ``jnp.any`` — the per-step cost is one pass
    over channels the step already materialized.
    """
    checks = []                                    # (bit, (C,) bool)
    if hcfg.check_finite:
        bad = ~jnp.all(jnp.isfinite(position), axis=-1)
        if force is not None:
            bad |= ~jnp.all(jnp.isfinite(force), axis=-1)
        checks.append((NONFINITE, bad))
    if hcfg.check_domain:
        tol = jnp.float32(hcfg.domain_tol)
        # NaN compares False on both sides — an escaped NaN is the finite
        # predicate's catch, not a spurious double flag here
        out = jnp.any((position < domain_lo - tol)
                      | (position > domain_hi + tol), axis=-1)
        checks.append((ESCAPE, out))
    if hcfg.max_step_displacement is not None and move_d is not None:
        limit = jnp.float32(hcfg.max_step_displacement)
        over = jnp.max(jnp.abs(move_d), axis=-1) > limit
        checks.append((DISPLACEMENT, over))
    if not checks:
        return jnp.zeros((), jnp.int32)
    stacked = jnp.stack([c & mask for _, c in checks])          # (K, C)
    fired = jnp.any(stacked, axis=1)                            # (K,)
    bits = jnp.asarray([b for b, _ in checks], jnp.int32)
    return jnp.sum(jnp.where(fired, bits, 0)).astype(jnp.int32)


def fault_bits(health) -> int:
    """Host-side OR over a step's health field (scalar or per-shard vector)."""
    return int(np.bitwise_or.reduce(np.asarray(health, np.int32).ravel(),
                                    initial=0))


def describe(bits: int) -> Tuple[str, ...]:
    """Names of the set health bits, e.g. (``'nonfinite'``,)."""
    return tuple(name for bit, name in _FLAG_NAMES if bits & bit)


class HealthFault(RuntimeError):
    """A health flag fired and the supervisor ran out of remedies.

    Carries the decoded flag names, the structured run report accumulated so
    far, and (when available) the last healthy state — the caller keeps the
    trajectory even when the run cannot continue.
    """

    def __init__(self, message: str, bits: int = 0, state=None, report=None):
        super().__init__(message)
        self.bits = bits
        self.flags = describe(bits)
        self.state = state
        self.report = report


# ---------------------------------------------------------------------------
# Fault injection (test-only): deterministic host-side corruption
# ---------------------------------------------------------------------------

def _state_channels(state):
    """(channels dict, rebuild(ch) -> state) for EngineState or DistState."""
    if hasattr(state, "pool"):                     # EngineState
        def rebuild(ch):
            return dataclasses.replace(state, pool=pool_from_channels(ch))
        return state.pool.channels(), rebuild
    if hasattr(state, "channels"):                 # DistState
        def rebuild(ch):
            return dataclasses.replace(state, channels=ch)
        return dict(state.channels), rebuild
    raise TypeError(f"not a simulation state: {type(state)!r}")


def inject_value(state, channel: str, slot: int, value) -> "state":
    """Overwrite one row (or one lane of a vector channel) with ``value``.

    ``inject_value(state, "position", 3, np.nan)`` is the canonical NaN
    injection: deterministic, detected by the NONFINITE guard on the next
    step. Works on EngineState and DistState alike.
    """
    ch, rebuild = _state_channels(state)
    arr = np.asarray(ch[channel]).copy()
    arr[slot] = value
    ch = dict(ch)
    ch[channel] = jnp.asarray(arr)
    return rebuild(ch)


def flip_bits(state, channel: str, slot: int, mask: int = 0x00400000):
    """XOR a bitmask into one float32 element — simulated memory corruption.

    The default mask flips a high mantissa bit: large but finite corruption,
    exercising the domain/displacement guards rather than the NaN path (use
    ``mask=0x7FC00000`` to forge a quiet NaN).
    """
    ch, rebuild = _state_channels(state)
    arr = np.asarray(ch[channel]).copy()
    if arr.dtype != np.float32:
        raise TypeError(f"flip_bits targets float32 channels, "
                        f"{channel} is {arr.dtype}")
    flat = arr.reshape(arr.shape[0], -1)
    bits = flat[slot].view(np.uint32) ^ np.uint32(mask)
    flat[slot] = bits.view(np.float32)
    ch = dict(ch)
    ch[channel] = jnp.asarray(flat.reshape(arr.shape))
    return rebuild(ch)


def storm_flags(state, field: str = "birth_overflow", count: int = 1):
    """Force a never-silent overflow flag on — an overflow-flag storm.

    Simulates a step whose stats report ``count`` dropped items on ``field``
    without any real drop, so ladder/supervisor reactions to overflow storms
    can be tested deterministically (e.g. a ladder diagnosing growth from a
    flag that never clears).
    """
    stats = state.stats
    cur = getattr(stats, field)
    stats = dataclasses.replace(stats, **{
        field: jnp.full_like(cur, count)})
    return dataclasses.replace(state, stats=stats)
