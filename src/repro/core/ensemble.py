"""Ensemble engine — one device steps L independent simulations in lockstep.

ABM users run *sweeps*, not single trajectories (calibration, uncertainty
quantification, epidemic what-ifs — ROADMAP "Simulation-as-a-service"), and a
sweep member is typically small: hundreds of lanes of a few hundred agents,
not one lane of millions. The C++ lineage schedules such sweeps as separate
processes; a JAX engine can do something structurally better — ``jax.vmap``
the *whole Algorithm-1 iteration core* over a leading lane axis, so one XLA
program advances every member per step (DESIGN.md §8):

  * **Per-lane everything.** RNG keys, ``ScenarioParams`` (traced dt / force
    constants / behavior rates — engine.py), iteration counters, and
    ``StepStats`` all carry a leading ``(L,)`` axis. Lane *i*'s trajectory is
    bit-exact vs a solo :class:`~.engine.Simulation` run with the same
    seed/params (tests/test_ensemble.py): the SIR core is elementwise float +
    integer/boolean reduction work, which XLA:CPU maps over the lane axis
    without reassociating per-lane arithmetic.

  * **Lane masking.** ``active`` is a ``(L,)`` bool mask. Inactive lanes
    still ride through the vmapped compute (dense batched math has no
    data-dependent skip), but every write is frozen via ``jnp.where`` and
    their stats are zeroed — a retired lane holds its final state bit-for-bit
    until the service overwrites it, exactly like an idle slot in
    ``serve/batching.py`` holds its KV rows. The economics are the same as
    continuous batching: an idle lane costs its batch slot, so the service's
    job is to keep lanes full, not to make idle lanes free.

  * **Shared-rung ladder.** Capacity knobs (pool capacity, run width,
    pair-list width) stay *shared* across lanes — one rung, one compiled
    program. :class:`EnsembleCapacityLadder` sizes the next rung off the
    worst per-lane demand and rewinds the overflowing tick, the same
    max-over-members + rewind argument the distributed ladder makes per
    shard (distributed.py): the overflowing execution dropped work, so its
    output is discarded and the tick re-runs at the new rung — bit-identical
    to a pre-sized ensemble.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import grid as grid_mod
from .agents import AgentPool
from .behaviors import Behavior
from .engine import (CapacityExhausted, EngineConfig, EngineState,
                     LadderConfig, LadderDriverBase, ScenarioParams,
                     Simulation, make_iteration_core, next_rung)
from .stats import StepStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EnsembleState:
    """State of L lanes advancing in lockstep. Leading axis of every array
    leaf is the lane axis; ``tick`` is the global ensemble step counter
    (per-lane ``iteration`` counters advance only while the lane is active,
    so they match the solo trajectory the lane reproduces)."""

    pool: AgentPool                      # channels (L, C, ...)
    conc: jnp.ndarray                    # (L, ...) diffusion grids
    rng: jax.Array                       # (L, 2) per-lane threefry keys
    iteration: jnp.ndarray               # (L,) int32 per-lane step index
    stats: StepStats                     # (L,) per-lane counters
    active: jnp.ndarray                  # (L,) bool lane mask
    params: Optional[ScenarioParams]     # per-lane knobs, leaves (L, ...)
    tick: jnp.ndarray                    # () int32 ensemble step counter
    env: Optional[grid_mod.RebuildState] = None
                                         # per-lane rebuild caches (L, ...)

    @property
    def n_lanes(self) -> int:
        return self.active.shape[0]


def make_ensemble_core(config: EngineConfig,
                       behaviors: Sequence[Behavior] = ()):
    """vmap of :func:`~.engine.make_iteration_core` over a leading lane axis.

    Returns ``ecore(pool, conc, rng, iteration, active, env, params) ->
    (pool, conc, rng, stats, env)`` where every argument/result carries a
    leading ``(L,)`` lane axis (``env``/``params`` may be None, matching the
    solo core). Lanes with ``active=False`` are frozen: their state passes
    through unchanged and their stats are zeroed, so a retired lane can
    neither drift nor trip the ladder/health machinery.
    """
    core = make_iteration_core(config, behaviors)

    def ecore(pool: AgentPool, conc: jnp.ndarray, rng: jax.Array,
              iteration: jnp.ndarray, active: jnp.ndarray,
              env: Optional[grid_mod.RebuildState] = None,
              params: Optional[ScenarioParams] = None):
        def one(pool, conc, rng, it, env, params):
            return core(pool, conc, rng, it, env, params)

        npool, nconc, nrng, stats, nenv = jax.vmap(one)(
            pool, conc, rng, iteration, env, params)

        def freeze(new, old):
            act = active.reshape(active.shape + (1,) * (new.ndim - 1))
            return jnp.where(act, new, old)

        tm = jax.tree_util.tree_map
        pool = tm(freeze, npool, pool)
        conc = tm(freeze, nconc, conc)
        rng = tm(freeze, nrng, rng)
        if env is not None:
            env = tm(freeze, nenv, env)
        stats = tm(lambda s: jnp.where(active, s, 0).astype(s.dtype), stats)
        return pool, conc, rng, stats, env

    return ecore


def grow_stacked_pool(pool: AgentPool, new_capacity: int) -> AgentPool:
    """Grow stacked ``(L, C, ...)`` pool channels to a larger capacity.

    Lane-axis analog of ``compaction.grow_channels``: new slots
    ``[C, new_capacity)`` are zero-filled (dead), exactly like the tail of a
    freshly staged pool, so the ladder's rewound trajectory matches a
    pre-sized ensemble bit for bit."""
    old = next(iter(pool.channels().values())).shape[1]
    if new_capacity < old:
        raise ValueError(f"cannot shrink pool {old} -> {new_capacity}")
    if new_capacity == old:
        return pool
    ch = {}
    for k, v in pool.channels().items():
        pad = jnp.zeros((v.shape[0], new_capacity - old) + v.shape[2:],
                        v.dtype)
        ch[k] = jnp.concatenate([v, pad], axis=1)
    return pool.with_channels(ch)


class EnsembleEngine:
    """L-lane ensemble of one EngineConfig — jitted lockstep step + lane IO.

    ``params_template`` fixes the per-lane :class:`ScenarioParams` pytree
    *structure* (key sets are static under jit); pass e.g.
    ``ScenarioParams.of(beta=0.0)`` and every admit supplies a same-structure
    instance. ``None`` means no per-lane knobs (all lanes share the static
    config — seeds still differ per lane).
    """

    def __init__(self, config: EngineConfig,
                 behaviors: Sequence[Behavior] = (), n_lanes: int = 1,
                 params_template: Optional[ScenarioParams] = None):
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        self.config = config
        self.behaviors = list(behaviors)
        self.n_lanes = n_lanes
        self.params_template = params_template
        self._solo = Simulation(config, self.behaviors)
        self._step_fn = jax.jit(self._build_step())
        self._write_fn = jax.jit(self._write_lane)
        self._retire_fn = jax.jit(self._set_active, static_argnums=2)

    # -- lane staging --------------------------------------------------------
    def stage_lane(self, position, diameter=None, agent_type=None,
                   extra_init: Optional[Dict[str, jnp.ndarray]] = None,
                   seed: int = 0) -> EngineState:
        """A solo-engine initial state, ready to admit into a lane."""
        return self._solo.init_state(position, diameter, agent_type,
                                     extra_init, seed=seed)

    def blank_lane(self) -> EngineState:
        """An idle lane: empty pool (no live agents), fresh dirty cache."""
        return self._solo.init_state(jnp.zeros((0, 3), jnp.float32))

    def init_state(self) -> EnsembleState:
        """All-idle ensemble: every lane blank and inactive."""
        L = self.n_lanes
        lane = self.blank_lane()
        bcast = lambda a: jnp.broadcast_to(a[None], (L,) + a.shape)
        tm = jax.tree_util.tree_map
        params = None
        if self.params_template is not None:
            params = tm(lambda a: bcast(jnp.asarray(a)),
                        self.params_template)
        return EnsembleState(
            pool=tm(bcast, lane.pool), conc=bcast(lane.conc),
            rng=bcast(lane.rng),
            iteration=jnp.zeros((L,), jnp.int32),
            stats=StepStats.zeros((L,)),
            active=jnp.zeros((L,), bool), params=params,
            tick=jnp.zeros((), jnp.int32),
            env=None if lane.env is None else tm(bcast, lane.env))

    # -- the lockstep iteration ---------------------------------------------
    def _build_step(self):
        ecore = make_ensemble_core(self.config, self.behaviors)

        def step(state: EnsembleState) -> EnsembleState:
            pool, conc, rng, stats, env = ecore(
                state.pool, state.conc, state.rng, state.iteration,
                state.active, state.env, state.params)
            return EnsembleState(
                pool=pool, conc=conc, rng=rng,
                iteration=jnp.where(state.active, state.iteration + 1,
                                    state.iteration),
                stats=stats, active=state.active, params=state.params,
                tick=state.tick + 1, env=env)

        return step

    def step(self, state: EnsembleState) -> EnsembleState:
        return self._step_fn(state)

    # -- lane admit / retire (jitted; lane index traced → one compile) ------
    def _write_lane(self, state: EnsembleState, lane: jnp.ndarray,
                    lane_state: EngineState,
                    params: Optional[ScenarioParams]) -> EnsembleState:
        tm = jax.tree_util.tree_map
        wr = lambda e, l: e.at[lane].set(l)
        new_params = state.params
        if params is not None:
            new_params = tm(wr, state.params, params)
        return EnsembleState(
            pool=tm(wr, state.pool, lane_state.pool),
            conc=wr(state.conc, lane_state.conc),
            rng=wr(state.rng, lane_state.rng),
            iteration=state.iteration.at[lane].set(lane_state.iteration),
            stats=state.stats,
            active=state.active.at[lane].set(True),
            params=new_params, tick=state.tick,
            env=(state.env if state.env is None
                 else tm(wr, state.env, lane_state.env)))

    def admit(self, state: EnsembleState, lane, lane_state: EngineState,
              params: Optional[ScenarioParams] = None) -> EnsembleState:
        """Write a solo state into lane ``lane`` and mark it active."""
        if (params is None) != (self.params_template is None):
            raise ValueError(
                "admit params must match the engine's params_template "
                f"(template {'set' if self.params_template is not None else 'None'}, "
                f"got {'params' if params is not None else 'None'})")
        return self._write_fn(state, jnp.asarray(lane, jnp.int32),
                              lane_state, params)

    def _set_active(self, state: EnsembleState, lane: jnp.ndarray,
                    value: bool) -> EnsembleState:
        return dataclasses.replace(
            state, active=state.active.at[lane].set(value))

    def retire(self, state: EnsembleState, lane) -> EnsembleState:
        """Deactivate lane ``lane`` — its state freezes (readable until the
        next admit overwrites it)."""
        return self._retire_fn(state, jnp.asarray(lane, jnp.int32), False)

    def read_lane(self, state: EnsembleState, lane: int) -> EngineState:
        """Lane ``lane``'s state as a solo EngineState (host-side readout)."""
        tm = jax.tree_util.tree_map
        take = lambda a: a[lane]
        return EngineState(
            pool=tm(take, state.pool), conc=state.conc[lane],
            rng=state.rng[lane], iteration=state.iteration[lane],
            stats=tm(take, state.stats),
            env=None if state.env is None else tm(take, state.env))


class EnsembleCapacityLadder(LadderDriverBase):
    """Capacity ladder over an ensemble: shared rungs, worst-lane demand.

    One compiled program serves every lane, so capacity knobs cannot differ
    per lane — the next rung is sized off ``max`` over the per-lane demand
    vectors (the distributed ladder's agreed-global-rung argument, one lane
    standing in for one shard) and the overflowing tick is re-run from its
    pre-step state at the new rung. Because the overflowing execution
    dropped work, discarding its output keeps every lane's trajectory
    bit-identical to a pre-sized ensemble.
    """

    def __init__(self, config: EngineConfig,
                 behaviors: Sequence[Behavior] = (), n_lanes: int = 1,
                 params_template: Optional[ScenarioParams] = None,
                 ladder: Optional[LadderConfig] = None):
        self.ladder = ladder or LadderConfig()
        self.behaviors = list(behaviors)
        self.config = config
        self.n_lanes = n_lanes
        self.params_template = params_template
        self.rungs: List[Dict] = []
        self.recompiles = 0
        self._sim = EnsembleEngine(config, self.behaviors, n_lanes,
                                   params_template)

    @property
    def engine(self) -> EnsembleEngine:
        """The current-rung EnsembleEngine (rebuilt at every grow)."""
        return self._sim

    def init_state(self) -> EnsembleState:
        return self._sim.init_state()

    def _iter_of(self, state: EnsembleState) -> int:
        return int(state.tick)

    # -- growth policy -------------------------------------------------------
    def _diagnose(self, stats: StepStats) -> Optional[EngineConfig]:
        cfg, lad = self.config, self.ladder
        tot = lambda f: int(np.asarray(jnp.sum(stats[f])))
        peak = lambda f: int(np.asarray(jnp.max(stats[f])))
        changes: Dict = {}
        if tot("pair_overflow"):
            changes["pairlist"] = dataclasses.replace(
                cfg.pairlist, max_pairs=next_rung(
                    cfg.pairlist.max_pairs, peak("pair_demand"),
                    lad.growth_factor))
        if tot("box_overflow"):
            demand = peak("box_demand")
            if cfg.environment == "hash_grid":
                need = -(-demand // grid_mod.HASH_K_MULT)
                changes["max_per_box"] = next_rung(
                    cfg.max_per_box, need, lad.growth_factor)
            else:
                changes["max_per_run"] = next_rung(
                    cfg.grid_spec.run_capacity, demand, lad.growth_factor)
        if tot("birth_overflow"):
            demand = peak("capacity_demand")
            new_cap = next_rung(cfg.capacity, demand, lad.growth_factor,
                                lad.round_to)
            if lad.max_capacity is not None and new_cap > lad.max_capacity:
                raise CapacityExhausted(
                    f"ensemble capacity ladder exhausted: worst-lane demand "
                    f"{demand} needs rung {new_cap} > "
                    f"max_capacity={lad.max_capacity}", demand=demand,
                    rung=new_cap, max_capacity=lad.max_capacity)
            changes["capacity"] = new_cap
        if not changes:
            return None
        return dataclasses.replace(cfg, **changes)

    def _grow(self, new_cfg: EngineConfig, prev: EnsembleState,
              iteration: int) -> EnsembleState:
        rungs = [(f, getattr(self.config, f), getattr(new_cfg, f))
                 for f in ("capacity", "max_per_box", "max_per_run")]
        if new_cfg.pairlist is not None and self.config.pairlist is not None:
            rungs.append(("max_pairs", self.config.pairlist.max_pairs,
                          new_cfg.pairlist.max_pairs))
        self._log_rungs(iteration, rungs)
        old_cfg, self.config = self.config, new_cfg
        self._sim = EnsembleEngine(new_cfg, self.behaviors, self.n_lanes,
                                   self.params_template)
        cap_grew = new_cfg.capacity != old_cfg.capacity
        pairs_grew = (new_cfg.pairlist is not None
                      and old_cfg.pairlist is not None
                      and (cap_grew or new_cfg.pairlist.max_pairs
                           != old_cfg.pairlist.max_pairs))
        if cap_grew or pairs_grew:
            env = prev.env
            if env is not None:
                # same rewind-parity argument as the solo/distributed
                # ladders: grow_grid_state / grow_pairlist pad trailing axes
                # only, so the (L, ...) lane caches extend exactly as L
                # pre-sized builds would have (grid.py)
                if cap_grew:
                    env = dataclasses.replace(
                        env, grid=grid_mod.grow_grid_state(env.grid,
                                                           new_cfg.capacity))
                if pairs_grew and env.pairs is not None:
                    env = dataclasses.replace(
                        env, pairs=grid_mod.grow_pairlist(
                            env.pairs, new_cfg.capacity,
                            new_cfg.pairlist.max_pairs))
            pool = (grow_stacked_pool(prev.pool, new_cfg.capacity)
                    if cap_grew else prev.pool)
            prev = dataclasses.replace(prev, pool=pool, env=env)
        return prev
