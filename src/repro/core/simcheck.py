"""Simulation checkpoint/resume and the supervised run loop (DESIGN.md §7.5).

The train side already had fault tolerance (train/checkpoint.py: atomic
tmp-then-rename saves, async writer, GC); this module puts the *simulation*
run state through the same writer and builds the recovery logic on top:

  * ``save_state`` / ``restore_state`` — the complete single-device run
    state (pool SoA channels, capacity-stable RNG key, RebuildPolicy cache,
    step index, stats) as one pytree checkpoint. Restores are **bit-exact**:
    every leaf round-trips through npz losslessly (binary float storage),
    the manifest records the rung and degradation knobs in effect so the
    resuming process rebuilds the *same* jit program, and the iteration core
    is deterministic — so a resumed run replays the uninterrupted
    trajectory byte for byte (the same argument the ladder rewind proves).

  * ``save_dist_state`` / ``restore_dist_state`` — the distributed
    counterpart. Channels are already global ``(n_shards·local, ...)``
    arrays, so one checkpoint holds every shard's slab; the manifest records
    the topology. Restoring onto the **same** shard count is bit-exact (and
    a differing ``local_capacity`` rung re-packs slabs via
    ``compaction.repack_slabs``, the ladder's own restage). Restoring onto a
    **different** shard count re-partitions live agents through the init
    path (quantile boundaries + ``partition_global``) — a valid state, but a
    different slab layout, so only same-topology resumes claim bit-exactness.

  * ``SupervisedRunner`` — the run loop that survives faults: checkpoints
    every ``checkpoint_every`` steps, reads the in-graph health bitmask
    (``StepStats.health``, core/health.py) after each step, and on a health
    fault or ladder exhaustion (``CapacityExhausted``) rolls back to the
    last checkpoint and retries under a ``DegradationPolicy`` — forcing
    every-step grid rebuilds, dropping the fused/Pallas sweep to the
    sequential XLA path (bit-exact per tests/test_fused.py, so recovery
    itself does not perturb the trajectory), and finally shrinking dt. Every
    intervention lands in a structured ``RunReport`` instead of a dead run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..train import checkpoint as ckpt_mod
from . import compaction, grid as grid_mod
from .behaviors import Behavior
from .distributed import (DistConfig, DistState, DistributedCapacityLadder,
                          DistributedSimulation, OWNED, partition_global,
                          quantile_boundaries)
from .engine import (CapacityExhausted, CapacityLadder, EngineConfig,
                     EngineState, ScenarioParams, Simulation, stage_pool)
from .ensemble import EnsembleEngine, EnsembleState
from .health import HealthFault, describe
from .stats import StepStats

_FORMAT = 1            # manifest extras schema version


# ---------------------------------------------------------------------------
# Knob snapshots — what the arrays alone cannot carry
# ---------------------------------------------------------------------------

def _engine_knobs(cfg: EngineConfig) -> Dict:
    """The config knobs a resume must reproduce: rung sizes (array shapes
    depend on them) and the degradation-ladder knobs (trajectory depends on
    them)."""
    return {"capacity": cfg.capacity,
            "max_per_box": cfg.max_per_box,
            "max_per_run": cfg.max_per_run,
            "dt": cfg.dt,
            "fused_sweep": cfg.fused_sweep,
            "force_impl": cfg.force_impl,
            "rebuild": {"mode": cfg.rebuild.mode, "k": cfg.rebuild.k,
                        "displacement_bound": cfg.rebuild.displacement_bound}}


def _apply_engine_knobs(cfg: EngineConfig, knobs: Dict,
                        mode: str) -> EngineConfig:
    """Apply recorded knobs onto ``cfg``.

    mode="all":   rungs + degradation knobs — a plain resume reproduces the
                  exact program the checkpoint ran under (bit-exact).
    mode="rungs": rung sizes only — the supervisor's rollback path, which
                  must keep its *degraded* dt/sweep/rebuild knobs rather
                  than have the checkpoint resurrect the faulty ones.
    """
    if mode not in ("all", "rungs"):
        raise ValueError(f"apply_knobs must be 'all' or 'rungs', got {mode!r}")
    changes: Dict[str, Any] = {k: knobs[k] for k in
                               ("capacity", "max_per_box", "max_per_run")}
    if mode == "all":
        changes.update(dt=knobs["dt"], fused_sweep=knobs["fused_sweep"],
                       force_impl=knobs["force_impl"],
                       rebuild=grid_mod.RebuildPolicy(**knobs["rebuild"]))
    return dataclasses.replace(cfg, **changes)


def _dist_knobs(dcfg: DistConfig) -> Dict:
    return {"n_shards": dcfg.n_shards,
            "local_capacity": dcfg.local_capacity,
            "halo_capacity": dcfg.halo_capacity,
            "migrate_capacity": dcfg.migrate_capacity,
            "rebalance_frequency": dcfg.rebalance_frequency,
            "engine": _engine_knobs(dcfg.engine)}


def _apply_dist_knobs(dcfg: DistConfig, knobs: Dict, mode: str) -> DistConfig:
    eng = _apply_engine_knobs(dcfg.engine, knobs["engine"], mode)
    return dataclasses.replace(
        dcfg, engine=eng, n_shards=knobs["n_shards"],
        local_capacity=knobs["local_capacity"],
        halo_capacity=knobs["halo_capacity"],
        migrate_capacity=knobs["migrate_capacity"])


# ---------------------------------------------------------------------------
# Templates — a zero state with the checkpoint's structure/shapes/dtypes
# ---------------------------------------------------------------------------

def _template_state(cfg: EngineConfig,
                    behaviors: Sequence[Behavior]) -> EngineState:
    """Structural twin of ``Simulation.init_state`` output (values unused)."""
    pool = stage_pool(cfg.capacity, list(behaviors),
                      jnp.zeros((1, 3), jnp.float32), policy=cfg.dtypes)
    dspec = cfg.diffusion
    conc = jnp.zeros(dspec.dims, jnp.float32) if dspec else jnp.zeros((1, 1, 1))
    env = None
    if cfg.rebuild.mode == "every_k":
        env = grid_mod.initial_rebuild_state(
            cfg.grid_spec, cfg.capacity,
            jnp.asarray(cfg.domain_lo, jnp.float32),
            jnp.asarray(cfg.cell_size, jnp.float32))
    return EngineState(pool=pool, conc=conc, rng=jax.random.PRNGKey(0),
                       iteration=jnp.zeros((), jnp.int32),
                       stats=StepStats.zeros(), env=env)


def _template_dist_state(dcfg: DistConfig,
                         behaviors: Sequence[Behavior]) -> DistState:
    """Structural twin of ``DistributedSimulation.init_state`` output."""
    cfg = dcfg.engine
    staging = stage_pool(1, list(behaviors), jnp.zeros((1, 3), jnp.float32),
                         extra_specs={OWNED: ((), jnp.bool_, True)},
                         policy=cfg.dtypes)
    n = dcfg.n_shards * dcfg.local_capacity
    channels = {k: jnp.zeros((n,) + v.shape[1:], v.dtype)
                for k, v in staging.channels().items()}
    dspec = cfg.diffusion
    conc = (jnp.zeros(dspec.dims, jnp.float32) if dspec
            else jnp.zeros((dcfg.n_shards, 1, 1)))
    env = None
    if cfg.rebuild.mode == "every_k":
        env0 = grid_mod.initial_rebuild_state(
            cfg.grid_spec, dcfg.total_capacity,
            jnp.asarray(cfg.domain_lo, jnp.float32),
            jnp.asarray(cfg.cell_size, jnp.float32))
        env = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (dcfg.n_shards,)
                                       + a.shape).copy(), env0)
    return DistState(channels=channels, conc=conc,
                     rng=jnp.zeros((dcfg.n_shards, 2), jnp.uint32),
                     boundaries=jnp.zeros((dcfg.n_shards + 1,), jnp.float32),
                     iteration=jnp.zeros((), jnp.int32),
                     stats=StepStats.zeros((dcfg.n_shards,)), env=env)


def _adapt_env(state, saved_mode: str, cfg: EngineConfig, template_fn):
    """Reconcile env presence when the target rebuild mode differs from the
    checkpoint's (a supervisor may have degraded every_k → every_step)."""
    if (cfg.rebuild.mode == "every_k") == (saved_mode == "every_k"):
        return state
    if cfg.rebuild.mode == "every_step":
        return dataclasses.replace(state, env=None)
    # target wants a cache the checkpoint lacks: start from a dirty initial
    # cache — the first step rebuilds, which is always correct
    return dataclasses.replace(state, env=template_fn().env)


# ---------------------------------------------------------------------------
# Single-device save / restore
# ---------------------------------------------------------------------------

def save_state(ckpt_dir: str, state: EngineState, cfg: EngineConfig,
               extras: Optional[Dict] = None) -> str:
    """Atomic checkpoint of a complete single-device run state."""
    meta = {"format": _FORMAT, "kind": "engine", "knobs": _engine_knobs(cfg)}
    if extras:
        meta.update(extras)
    return ckpt_mod.save(ckpt_dir, int(state.iteration), state, extras=meta)


def restore_state(ckpt_dir: str, cfg: EngineConfig,
                  behaviors: Sequence[Behavior], step: Optional[int] = None,
                  apply_knobs: str = "all"
                  ) -> Tuple[EngineState, EngineConfig]:
    """Restore ``(state, config)``; resume by building Simulation(config).

    ``step=None`` restores the latest checkpoint. ``apply_knobs`` decides
    which recorded knobs overwrite ``cfg`` (see ``_apply_engine_knobs``) —
    with "all", stepping the returned state under the returned config is
    bit-exact with the uninterrupted run.
    """
    if step is None:
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    meta = ckpt_mod.load_manifest(ckpt_dir, step).get("extras", {})
    knobs = meta.get("knobs")
    if knobs is None:
        raise ValueError(f"{ckpt_dir} step {step}: not a simulation "
                         f"checkpoint (no knobs in manifest extras)")
    cfg = _apply_engine_knobs(cfg, knobs, apply_knobs)
    saved_mode = knobs["rebuild"]["mode"]
    # the restore template mirrors the config the checkpoint was SAVED
    # under (env presence / grid shapes), then adapts to the target config
    tmpl_cfg = cfg
    if (cfg.rebuild.mode == "every_k") != (saved_mode == "every_k"):
        tmpl_cfg = dataclasses.replace(
            cfg, rebuild=grid_mod.RebuildPolicy(**knobs["rebuild"]))
    state = ckpt_mod.restore(ckpt_dir, step,
                             _template_state(tmpl_cfg, behaviors))
    state = _adapt_env(state, saved_mode, cfg,
                       lambda: _template_state(cfg, behaviors))
    return state, cfg


# ---------------------------------------------------------------------------
# Ensemble save / restore
# ---------------------------------------------------------------------------

def save_ensemble_state(ckpt_dir: str, state: EnsembleState,
                        cfg: EngineConfig,
                        extras: Optional[Dict] = None) -> str:
    """Atomic checkpoint of a whole ensemble — every lane's state, the
    active mask, per-lane params, and the tick, as one pytree. The step
    index is the ensemble ``tick`` (per-lane iterations travel as arrays).
    Callers with host-side lane bookkeeping (serve/sim_service.py's request
    table) record it through ``extras``."""
    meta = {"format": _FORMAT, "kind": "ensemble",
            "knobs": _engine_knobs(cfg), "n_lanes": state.n_lanes}
    if extras:
        meta.update(extras)
    return ckpt_mod.save(ckpt_dir, int(state.tick), state, extras=meta)


def restore_ensemble_state(ckpt_dir: str, cfg: EngineConfig,
                           behaviors: Sequence[Behavior],
                           params_template: Optional[ScenarioParams] = None,
                           step: Optional[int] = None,
                           apply_knobs: str = "all"
                           ) -> Tuple[EnsembleState, EngineConfig, Dict]:
    """Restore ``(state, config, manifest_extras)`` for an ensemble run.

    Same bit-exactness contract as :func:`restore_state`: with
    ``apply_knobs="all"`` the restored config rebuilds the exact jit program
    the checkpoint ran under, so stepping the restored ensemble replays the
    uninterrupted trajectory byte for byte on every lane.
    ``params_template`` must match the structure the run was saved with
    (the restore template is built from it). The returned extras dict gives
    services their lane table back.
    """
    if step is None:
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    meta = ckpt_mod.load_manifest(ckpt_dir, step).get("extras", {})
    knobs = meta.get("knobs")
    if knobs is None or meta.get("kind") != "ensemble":
        raise ValueError(f"{ckpt_dir} step {step}: not an ensemble "
                         f"simulation checkpoint")
    cfg = _apply_engine_knobs(cfg, knobs, apply_knobs)
    n_lanes = meta["n_lanes"]
    saved_mode = knobs["rebuild"]["mode"]
    tmpl_cfg = cfg
    if (cfg.rebuild.mode == "every_k") != (saved_mode == "every_k"):
        tmpl_cfg = dataclasses.replace(
            cfg, rebuild=grid_mod.RebuildPolicy(**knobs["rebuild"]))
    tmpl = EnsembleEngine(tmpl_cfg, behaviors, n_lanes,
                          params_template).init_state()
    state = ckpt_mod.restore(ckpt_dir, step, tmpl)
    state = _adapt_env(
        state, saved_mode, cfg,
        lambda: EnsembleEngine(cfg, behaviors, n_lanes,
                               params_template).init_state())
    return state, cfg, meta


# ---------------------------------------------------------------------------
# Distributed save / restore
# ---------------------------------------------------------------------------

def save_dist_state(ckpt_dir: str, state: DistState, dcfg: DistConfig,
                    extras: Optional[Dict] = None) -> str:
    """Atomic checkpoint of a distributed run (all shards' slabs at once:
    the channel arrays are already the global sharded buffers)."""
    meta = {"format": _FORMAT, "kind": "dist", "knobs": _dist_knobs(dcfg)}
    if extras:
        meta.update(extras)
    return ckpt_mod.save(ckpt_dir, int(state.iteration), state, extras=meta)


def restore_dist_state(ckpt_dir: str, dcfg: DistConfig,
                       behaviors: Sequence[Behavior],
                       step: Optional[int] = None, apply_knobs: str = "all",
                       seed: int = 0) -> Tuple[DistState, DistConfig]:
    """Restore ``(state, dist_config)`` — elastic across shard counts.

    Same ``n_shards`` as the checkpoint: exact restore (bit-exact resume;
    a larger ``local_capacity`` rung in ``dcfg`` re-packs slabs through the
    ladder's own restage). Different ``n_shards``: live agents are gathered
    and re-partitioned through the init path (fresh quantile boundaries,
    fresh per-shard RNG folded from ``seed``) — a valid state with the same
    population, but a different layout/stream, so not bit-exact.
    """
    if step is None:
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    meta = ckpt_mod.load_manifest(ckpt_dir, step).get("extras", {})
    knobs = meta.get("knobs")
    if knobs is None or meta.get("kind") != "dist":
        raise ValueError(f"{ckpt_dir} step {step}: not a distributed "
                         f"simulation checkpoint")
    saved_mode = knobs["engine"]["rebuild"]["mode"]
    if dcfg.n_shards == knobs["n_shards"]:
        target = _apply_dist_knobs(dcfg, knobs, apply_knobs)
        grow_local = max(dcfg.local_capacity, target.local_capacity)
        tmpl_cfg = target
        if (target.engine.rebuild.mode == "every_k") != (saved_mode
                                                         == "every_k"):
            tmpl_cfg = dataclasses.replace(
                target, engine=dataclasses.replace(
                    target.engine, rebuild=grid_mod.RebuildPolicy(
                        **knobs["engine"]["rebuild"])))
        state = ckpt_mod.restore(ckpt_dir, step,
                                 _template_dist_state(tmpl_cfg, behaviors))
        state = _adapt_env(state, saved_mode, target.engine,
                           lambda: _template_dist_state(target, behaviors))
        if grow_local > target.local_capacity:
            # caller's rung outgrew the checkpoint's: repack, keep the rung
            state = dataclasses.replace(state, channels=compaction.repack_slabs(
                state.channels, target.n_shards, target.local_capacity,
                grow_local))
            target = dataclasses.replace(target, local_capacity=grow_local)
        return state, target

    # --- reshard: restore at the saved topology, re-partition live agents
    saved_dcfg = _apply_dist_knobs(dcfg, knobs, "all")
    tmpl = _template_dist_state(saved_dcfg, behaviors)
    state = ckpt_mod.restore(ckpt_dir, step, tmpl)
    target = dcfg if apply_knobs == "rungs" else dataclasses.replace(
        dcfg, engine=_apply_engine_knobs(dcfg.engine, knobs["engine"], "all"))
    cfg = target.engine
    ch = {k: jnp.asarray(np.asarray(v)) for k, v in state.channels.items()}
    boundaries = quantile_boundaries(ch["position"][:, 0], ch["alive"],
                                     target.n_shards,
                                     float(cfg.domain_lo[0]),
                                     float(cfg.domain_hi[0]))
    n_live = int(np.asarray(ch["alive"]).sum())
    channels = partition_global(ch, boundaries, target)
    kept = int(np.asarray(channels["alive"]).sum())
    if kept != n_live:
        raise ValueError(
            f"reshard onto n_shards={target.n_shards} drops "
            f"{n_live - kept} agents (a slab exceeds local_capacity="
            f"{target.local_capacity}); raise local_capacity")
    dspec = cfg.diffusion
    conc = (state.conc if dspec
            else jnp.zeros((target.n_shards, 1, 1)))
    rng = jax.vmap(lambda s: jax.random.fold_in(
        jax.random.PRNGKey(seed), s))(
            jnp.arange(target.n_shards, dtype=jnp.uint32))
    env = _template_dist_state(target, behaviors).env   # dirty: rebuilds
    return DistState(channels=channels, conc=conc, rng=rng,
                     boundaries=boundaries, iteration=state.iteration,
                     stats=StepStats.zeros((target.n_shards,)),
                     env=env), target


class SimCheckpointer:
    """Async simulation checkpointer: snapshot-to-host, background write.

    One object per run; saves are serialized (a new save waits for the
    previous write). Dispatches on state type, records the knobs alongside.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self._async = ckpt_mod.AsyncCheckpointer(ckpt_dir, keep=keep)

    def save_async(self, state, config, extras: Optional[Dict] = None) -> int:
        step = int(state.iteration)
        if isinstance(config, DistConfig):
            meta = {"format": _FORMAT, "kind": "dist",
                    "knobs": _dist_knobs(config)}
        else:
            meta = {"format": _FORMAT, "kind": "engine",
                    "knobs": _engine_knobs(config)}
        if extras:
            meta.update(extras)
        self._async.save_async(step, state, extras=meta)
        return step

    def wait(self) -> None:
        self._async.wait()


# ---------------------------------------------------------------------------
# Degradation policy + run report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Ordered remedies the supervisor tries after a rollback.

    The order is by trajectory impact: (1) drop the every_k rebuild cache —
    stale-superset candidates contribute exactly zero force, so positions
    are unchanged and only the skip schedule resets; (2) drop the
    fused/Pallas sweep to the sequential XLA path — bit-exact by
    construction (tests/test_fused.py); (3) shrink dt — the only remedy
    that changes the trajectory, tried last and at most
    ``max_dt_shrinks`` times.
    """

    dt_shrink: float = 0.5
    max_dt_shrinks: int = 2

    def next_remedy(self, cfg: EngineConfig, applied: Sequence[str]
                    ) -> Optional[Tuple[str, EngineConfig]]:
        """(name, degraded config) — or None when out of remedies."""
        if cfg.rebuild.mode == "every_k":
            return "rebuild_every_step", dataclasses.replace(
                cfg, rebuild=grid_mod.RebuildPolicy())
        if cfg.fused_sweep or cfg.force_impl != "xla":
            return "sequential_sweep", dataclasses.replace(
                cfg, fused_sweep=False, force_impl="xla")
        if sum(1 for a in applied if a == "shrink_dt") < self.max_dt_shrinks:
            return "shrink_dt", dataclasses.replace(
                cfg, dt=cfg.dt * self.dt_shrink)
        return None


@dataclasses.dataclass
class RunReport:
    """Structured record of everything the supervisor did to keep the run
    alive — the contract is that no intervention is silent."""

    interventions: List[Dict] = dataclasses.field(default_factory=list)
    checkpoints: List[int] = dataclasses.field(default_factory=list)
    rungs: List[Dict] = dataclasses.field(default_factory=list)
    retries: int = 0
    completed: bool = False
    final_iteration: int = 0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# The supervised run loop
# ---------------------------------------------------------------------------

class SupervisedRunner:
    """Fault-tolerant driver around a capacity ladder (§7.5).

    Wraps a ``CapacityLadder`` (or ``DistributedCapacityLadder``): runs it
    step by step, checkpoints every ``checkpoint_every`` iterations (plus
    once up front, so there is always a rollback target), and reads the
    in-graph health bitmask after every step. On a health fault or
    ``CapacityExhausted``:

      1. the failing state is discarded (for capacity exhaustion, the
         last-good pre-step state carried by the exception is first
         emergency-checkpointed — no progress is lost);
      2. the engine config is degraded one remedy down the
         ``DegradationPolicy`` ladder;
      3. the run rolls back to the latest checkpoint (rung knobs from the
         checkpoint, degraded knobs kept) and continues.

    When remedies run out the original fault is re-raised with the
    ``RunReport`` attached — the trajectory up to the last checkpoint is on
    disk either way.

    ``fault_hook(iteration, state) -> state | None`` is a test-only
    injection point, called on the *input* state of each iteration, so
    injected corruption flows through the jitted step and is caught by the
    in-graph guard exactly like real corruption would be.
    """

    def __init__(self, driver, ckpt_dir: str, checkpoint_every: int = 50,
                 keep: int = 3, policy: Optional[DegradationPolicy] = None,
                 max_retries: int = 8,
                 fault_hook: Optional[Callable] = None):
        self.driver = driver
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = checkpoint_every
        self.policy = policy or DegradationPolicy()
        self.max_retries = max_retries
        self.fault_hook = fault_hook
        self.report = RunReport()
        self._ckpt = SimCheckpointer(ckpt_dir, keep=keep)
        self._applied: List[str] = []

    # -- driver plumbing (CapacityLadder vs DistributedCapacityLadder) ------
    def _is_dist(self) -> bool:
        return isinstance(self.driver, DistributedCapacityLadder)

    def _config(self):
        return self.driver.dcfg if self._is_dist() else self.driver.config

    def _engine_cfg(self) -> EngineConfig:
        c = self._config()
        return c.engine if self._is_dist() else c

    def _reconfigure(self, new_cfg) -> None:
        if self._is_dist():
            self.driver.dcfg = new_cfg
            self.driver._sim = DistributedSimulation(
                new_cfg, self.driver.behaviors, self.driver._mesh,
                self.driver.axis)
        else:
            self.driver.config = new_cfg
            self.driver._sim = Simulation(new_cfg, self.driver.behaviors)

    def _save(self, state) -> None:
        step = self._ckpt.save_async(state, self._config())
        if step not in self.report.checkpoints:
            self.report.checkpoints.append(step)

    def _rollback(self):
        """Latest checkpoint under the current (possibly degraded) config."""
        self._ckpt.wait()
        if self._is_dist():
            state, cfg = restore_dist_state(
                self.ckpt_dir, self._config(), self.driver.behaviors,
                apply_knobs="rungs")
        else:
            state, cfg = restore_state(
                self.ckpt_dir, self._config(), self.driver.behaviors,
                apply_knobs="rungs")
        self._reconfigure(cfg)
        return state

    def _handle_fault(self, kind: str, detail: Dict, fault) -> Any:
        self.report.retries += 1
        if self.report.retries > self.max_retries:
            fault.report = self.report
            raise fault
        remedy = self.policy.next_remedy(self._engine_cfg(), self._applied)
        if remedy is None:
            fault.report = self.report
            raise fault
        name, new_eng = remedy
        self._applied.append(name)
        new_cfg = (dataclasses.replace(self._config(), engine=new_eng)
                   if self._is_dist() else new_eng)
        self._reconfigure(new_cfg)
        state = self._rollback()
        self.report.interventions.append(
            {"kind": kind, "remedy": name,
             "rolled_back_to": int(state.iteration), **detail})
        return state

    # -- the loop -----------------------------------------------------------
    def run(self, state, n_iterations: int):
        """Returns ``(final_state, RunReport)``."""
        target = int(state.iteration) + n_iterations
        self._save(state)                       # always a rollback target
        while int(state.iteration) < target:
            it = int(state.iteration)
            if self.fault_hook is not None:
                injected = self.fault_hook(it, state)
                if injected is not None:
                    state = injected
            try:
                nxt = self.driver.step(state)
                bits = nxt.stats.health_bits()
                if bits:
                    raise HealthFault(
                        f"iteration {it}: health guard fired "
                        f"{describe(bits)}", bits=bits)
            except HealthFault as e:
                state = self._handle_fault(
                    "health", {"iteration": it, "flags": list(e.flags)}, e)
                continue
            except CapacityExhausted as e:
                if e.state is not None:
                    # emergency checkpoint of the last-good pre-step state:
                    # rollback loses nothing
                    self._ckpt.wait()
                    self._save(e.state)
                state = self._handle_fault(
                    "capacity_exhausted",
                    {"iteration": it, "demand": e.demand,
                     "max_capacity": e.max_capacity}, e)
                continue
            state = nxt
            if int(state.iteration) % self.checkpoint_every == 0:
                self._save(state)
        self._save(state)
        self._ckpt.wait()
        self.report.completed = True
        self.report.final_iteration = int(state.iteration)
        self.report.rungs = list(self.driver.rungs)
        return state, self.report
