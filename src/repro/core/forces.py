"""Pairwise mechanical interaction force — paper §5 / Cortex3D default force.

BioDynaMo's default ``InteractionForce`` follows Zubler & Douglas (Cortex3D,
2009): spheres in overdamped media exert a repulsive force when they
interpenetrate and (optionally) a short-range adhesive force. We implement the
same functional form:

  δ     = r_i + r_j − |x_j − x_i|                  (overlap; negative = gap)
  F_rep = k_rep · √(r_eff) · δ^{3/2}               (Hertz contact, δ > 0)
  F_adh = −μ(type_i, type_j) · √(r_eff · max(δ+a, 0))  (adhesion band width a)

with r_eff = r_i·r_j/(r_i+r_j). The type-dependent adhesion matrix μ enables
the Biocellion cell-sorting model (differential adhesion hypothesis, paper
§6.5 / Fig 7a). Displacement uses overdamped dynamics dx = F·dt/ζ capped at
``max_displacement`` per step (BioDynaMo's simulation_max_displacement).

The exact constants differ from BioDynaMo's C++ (which is itself a port of
Cortex3D's Java); what the paper's claims depend on is the *cost shape* —
pairwise, short-range, dominant in tissue models — which is preserved.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ForceParams:
    k_rep: float = 2.0               # repulsion stiffness
    adhesion_band: float = 0.4       # δ offset within which adhesion acts
    zeta: float = 1.0                # drag coefficient (overdamped)
    max_displacement: float = 3.0    # per-iteration displacement cap
    force_eps: float = 1e-7          # |F| below this counts as zero (cond. iv)
    move_eps: float = 1e-9           # |dx| below this counts as not-moved


def pair_force(q_pos: jnp.ndarray, q_dia: jnp.ndarray, q_type: jnp.ndarray,
               n_pos: jnp.ndarray, n_dia: jnp.ndarray, n_type: jnp.ndarray,
               valid: jnp.ndarray, params: ForceParams,
               adhesion: jnp.ndarray | None = None) -> jnp.ndarray:
    """Force exerted on q by each candidate neighbor.

    q_*: (B, ...) query channels; n_*: (B, M, ...) neighbor candidates;
    valid: (B, M). Returns (B, M, 3) forces (zero where invalid / out of range).
    adhesion: (T, T) type-adhesion matrix or None (no adhesion).

    Exact-zero-outside-reach contract (grid.PairList relies on it): a pair
    farther apart than (d_i + d_j)/2 + adhesion_band contributes exactly
    +0.0 to every output component and does not count as ``interacting`` —
    so pruning such candidates out of the stream, or carrying stale extras
    under skin reuse, cannot change the accumulated force by even one ulp.
    The reach is ≤ interaction_radius (the same bound the 3×3×3 grid stencil
    already assumes), hence ≤ the pair-list filter radius r + skin.
    """
    d = n_pos - q_pos[:, None, :]                      # (B, M, 3)
    dist2 = jnp.sum(d * d, axis=-1)
    dist = jnp.sqrt(jnp.maximum(dist2, 1e-18))
    r_q = q_dia[:, None] * 0.5
    r_n = n_dia * 0.5
    delta = r_q + r_n - dist                           # overlap
    r_eff = jnp.maximum(r_q * r_n / jnp.maximum(r_q + r_n, 1e-12), 1e-12)

    f_rep = params.k_rep * jnp.sqrt(r_eff) * jnp.power(jnp.maximum(delta, 0.0), 1.5)
    if adhesion is not None:
        mu = adhesion[q_type[:, None], n_type]         # (B, M)
        band = jnp.maximum(delta + params.adhesion_band, 0.0)
        in_band = delta + params.adhesion_band > 0.0
        f_adh = jnp.where(in_band, mu * jnp.sqrt(r_eff * band), 0.0)
    else:
        f_adh = 0.0

    f_mag = f_rep - f_adh                              # >0 pushes apart
    direction = d / dist[..., None]                    # unit q→n
    interacting = valid & (delta + params.adhesion_band > 0.0)
    force = jnp.where(interacting[..., None], -f_mag[..., None] * direction, 0.0)
    return force


# Channel footprint of the force pair kernel (grid.PairKernel.reads): the
# fused sweep prunes its single gather to the union of registered footprints,
# so a forces-only run streams exactly these four channels and nothing else.
FORCE_READS = ("position", "diameter", "agent_type", "alive")
FORCE_OUT_SPECS = {"force": ((3,), jnp.float32),
                   "force_nnz": ((), jnp.int32)}


def make_force_pair_fn(params: ForceParams, adhesion: jnp.ndarray | None = None):
    """pair_fn for grid.neighbor_apply computing (force, nnz count) per agent."""

    def pair_fn(q: Dict[str, jnp.ndarray], nbr: Dict[str, jnp.ndarray],
                valid: jnp.ndarray, q_slot: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        f = pair_force(q["position"], q["diameter"], q["agent_type"],
                       nbr["position"], nbr["diameter"], nbr["agent_type"],
                       valid & nbr["alive"], params, adhesion)
        nnz = jnp.sum(jnp.sum(f * f, axis=-1) > params.force_eps ** 2, axis=-1)
        return {"force": jnp.sum(f, axis=1), "force_nnz": nnz.astype(jnp.int32)}

    return pair_fn


def displacement(force: jnp.ndarray, params: ForceParams, dt: float) -> jnp.ndarray:
    """Overdamped integration with per-step displacement cap."""
    dx = force * (dt / params.zeta)
    norm = jnp.sqrt(jnp.maximum(jnp.sum(dx * dx, axis=-1, keepdims=True), 1e-30))
    scale = jnp.minimum(1.0, params.max_displacement / norm)
    return dx * scale
