"""Capacity-stable per-row random draws (capacity-ladder bit-parity support).

``jax.random.uniform(key, (C,))`` is NOT prefix-stable in ``C``: threefry
counter pairing splits the flattened size in half, so the value at row ``i``
depends on the total array length. Under the capacity ladder (DESIGN.md §4.3)
the pool's ``C`` changes at every rung while the *live* agents stay in slots
``[0, n_live)`` — a behavior drawing capacity-shaped randomness the stock way
would therefore diverge from a pre-sized run the moment the pool grows,
breaking the ladder's bit-identical-trajectory contract.

This module provides draws where the value at ``[i, j]`` is a pure function of
``(key, i, j)`` and never of the array length: one threefry-2x32 block per
element, counter = (row, column). Behaviors use these for all per-agent
randomness (behaviors.py), which is what makes growing the pool mid-run
invisible to the trajectory.

The threefry-2x32 implementation below is the standard 20-round ARX cipher
(Salmon et al. 2011), vectorized in jnp (uint32 wrap-around arithmetic). It is
deliberately independent of jax's internal PRNG plumbing: the bit streams are
stable across jax versions, and both raw ``(2,)`` uint32 keys and new-style
typed keys are accepted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = jnp.uint32(0x1BD11BDA)


def _key_halves(key: jax.Array) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(k0, k1) uint32 scalars from a raw (2,) uint32 key or a typed key."""
    if jnp.issubdtype(key.dtype, jnp.integer):
        data = key.astype(jnp.uint32)
    else:                                   # new-style typed PRNG key
        data = jax.random.key_data(key).astype(jnp.uint32)
    return data[..., 0], data[..., 1]


def _rotl(x: jnp.ndarray, r: int) -> jnp.ndarray:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0: jnp.ndarray, k1: jnp.ndarray,
                 x0: jnp.ndarray, x1: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One threefry-2x32 block per lane: counters (x0, x1) → two uint32 streams."""
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _row_col_bits(key: jax.Array, rows: int, cols: int
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(rows, cols) pairs of uint32 streams, element = f(key, row, col) only."""
    k0, k1 = _key_halves(key)
    r = jnp.arange(rows, dtype=jnp.uint32)[:, None]
    c = jnp.arange(cols, dtype=jnp.uint32)[None, :]
    return threefry2x32(k0, k1, jnp.broadcast_to(r, (rows, cols)),
                        jnp.broadcast_to(c, (rows, cols)))


def _to_unit(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 → float32 in [0, 1) with 24 bits of mantissa entropy."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24)


def uniform_rows(key: jax.Array, rows: int, cols: int | None = None
                 ) -> jnp.ndarray:
    """Uniform [0, 1) draws of shape (rows,) or (rows, cols).

    The value at row ``i`` (column ``j``) depends only on ``(key, i, j)`` —
    growing ``rows`` extends the array without changing existing entries
    (the property ``jax.random.uniform`` does not have).
    """
    b0, _ = _row_col_bits(key, rows, 1 if cols is None else cols)
    u = _to_unit(b0)
    return u[:, 0] if cols is None else u


def normal_rows(key: jax.Array, rows: int, cols: int | None = None
                ) -> jnp.ndarray:
    """Standard-normal draws of shape (rows,) or (rows, cols), capacity-stable.

    Box–Muller over the two streams of one threefry block per element (u1 is
    mapped to (0, 1] so the log is finite).
    """
    b0, b1 = _row_col_bits(key, rows, 1 if cols is None else cols)
    u1 = jnp.float32(1.0) - _to_unit(b0)           # (0, 1]
    u2 = _to_unit(b1)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(jnp.float32(2.0 * jnp.pi) * u2)
    return z[:, 0] if cols is None else z
