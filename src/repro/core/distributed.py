"""Distributed ABM engine — the paper's §8 'future work' (multi-node), realized.

This module contains **no force/query/behavior logic of its own**: each slab
runs the SAME Algorithm-1 iteration body as the single-device engine
(engine.make_iteration_core — resident grid build, run-streaming or Pallas
forces, behaviors, effects merge, death compaction + birth commit, statics
bookkeeping, diffusion). The wrapper's job is purely distribution
(DESIGN.md §7):

  * **1-D slab domain decomposition** along x over mesh axis ``data``: each
    device owns agents with x ∈ [b_i, b_{i+1}). Slab boundaries come from
    population *quantiles* — the paper's §4.2 balancing (equal agents per NUMA
    domain) lifted to devices — and are re-derived every
    ``rebalance_frequency`` steps *inside* the jitted program.
  * **Ring halo exchange**: interaction radius r ≤ slab width ⇒ every cross-
    shard interaction partner lives in the adjacent slab; one
    ``ppermute`` left + one right per step ships the boundary band as *ghost*
    rows appended to the local pool. The ghost buffer layout is derived from
    the pool's channel spec (agents.pool_from_channels) — every channel,
    including behavior-owned extras like infection timers, crosses the
    boundary; ghosts are gather sources only (engine core ``owned`` mask).
    With ``detect_static`` the band widens to 2·r so box-granular disturbance
    (statics.py) stays a conservative superset across shard lines.
  * **Ring migration**: agents whose post-step x leaves the slab ship to the
    adjacent shard with the same channel packing and are appended through the
    §3.2 *birth-commit* path (compaction.commit_births) — newborn agents of
    this very step migrate like any other, preserving born_iter and all
    behavior state. Fixed-capacity buffers with overflow flags (never silent
    loss; stats.StepStats).
  * **Sharded diffusion**: the substance grid is split into x-slabs; each
    FTCS substep exchanges one-voxel face halos alongside the agent ghosts
    (_ShardedDiffusionOps / diffusion.step_slab). Agent coupling (secretion
    scatter, gradient/value sampling) routes through psum_scatter/all_gather
    so quantile agent slabs need not align with the fixed voxel slabs.

Everything runs under one ``shard_map`` program: the whole distributed step is
a single XLA executable per device.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import compaction, diffusion as diff_mod, grid as grid_mod
from .agents import AgentPool, make_pool, pool_from_channels
from .behaviors import Behavior
from .engine import (CapacityExhausted, EngineConfig, LadderConfig,
                     LadderDriverBase, next_rung, make_iteration_core,
                     stage_pool)
from .stats import StepStats

OWNED = "owned"          # bool extra channel: local agent (True) vs ghost


class SlabCapacityError(ValueError):
    """An initial slab population exceeds local_capacity (init-time §4.2
    never-silent check). Typed so the distributed capacity ladder can catch
    exactly this condition and grow, rather than matching error prose."""


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Static distributed-run configuration.

    local_capacity:      slots per shard (live agents per slab must fit)
    halo_capacity:       ghost rows shipped per face per step
    migrate_capacity:    migrating agents shipped per face per step
    rebalance_frequency: re-derive quantile slab boundaries every this many
                         steps inside the jitted program (0 = keep the
                         boundaries fixed after init)
    """
    engine: EngineConfig
    n_shards: int
    local_capacity: int
    halo_capacity: int = 1024
    migrate_capacity: int = 256
    rebalance_frequency: int = 0

    @property
    def halo_width(self) -> float:
        """Ghost band thickness: r, or 2·r under detect_static (statics.py);
        plus the rebuild policy's cell slack so the band stays a conservative
        superset when every_k widens the grid cells (grid.RebuildPolicy), and
        plus the pair-list skin so a list built at radius r + skin still sees
        every cross-shard candidate (grid.PairListConfig)."""
        skin = (self.engine.pairlist.skin
                if self.engine.pairlist is not None else 0.0)
        return self.engine.interaction_radius * (
            2.0 if self.engine.detect_static else 1.0
        ) + self.engine.rebuild.cell_slack + skin

    @property
    def total_capacity(self) -> int:
        """Local pool width inside the step: owned slots + two ghost bands."""
        return self.local_capacity + 2 * self.halo_capacity


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistState:
    """Sharded simulation state. ``channels`` hold every pool channel as a
    global (n_shards·local_capacity, ...) array sharded on dim 0; shard i's
    agents live in slice [i·C, i·C + n_i)."""
    channels: Dict[str, jnp.ndarray]
    conc: jnp.ndarray               # diffusion slabs, sharded on x (dummy if unused)
    rng: jax.Array                  # (n_shards, 2) per-shard key
    boundaries: jnp.ndarray         # (n_shards + 1,) slab edges (replicated)
    iteration: jnp.ndarray          # () int32
    stats: StepStats                # per-shard (n_shards,) counters
    env: Optional[grid_mod.RebuildState] = None
                                    # per-shard cached grid build (RebuildPolicy
                                    # every_k): every leaf carries a leading
                                    # (n_shards,) axis; None under every_step


def quantile_boundaries(x: jnp.ndarray, alive: jnp.ndarray, n_shards: int,
                        lo: float, hi: float) -> jnp.ndarray:
    """Equal-population slab boundaries (paper §4.2 balancing).

    Robust to degenerate populations: with no live agents the inner
    boundaries collapse to ``hi`` (all-empty slabs are valid), and a heavily
    skewed distribution (single cluster) yields clamped, non-decreasing
    boundaries — possibly empty slabs, never an inverted or out-of-domain
    one.
    """
    big = jnp.where(alive, x, jnp.inf)
    xs = jnp.sort(big)
    n = jnp.sum(alive.astype(jnp.int32))
    qs = (jnp.arange(1, n_shards) * n) // n_shards
    inner = xs[jnp.clip(qs, 0, x.shape[0] - 1)]
    inner = jnp.clip(inner, lo, hi)            # n == 0 → inf → hi
    if n_shards > 1:
        inner = jax.lax.cummax(inner)          # monotone under skew/ties
    return jnp.concatenate([jnp.asarray([lo], inner.dtype), inner,
                            jnp.asarray([hi], inner.dtype)])


def partition_global(pool_channels: Dict[str, jnp.ndarray],
                     boundaries: jnp.ndarray, dcfg: DistConfig
                     ) -> Dict[str, jnp.ndarray]:
    """Host-side: scatter agents into per-shard slots [shard, local_capacity].

    Returns channels with leading dim n_shards*local_capacity, agents of shard
    i in slice [i*C, i*C + n_i). (Used at init; in-loop rebalancing moves
    agents through the migration path instead.) Agents beyond a slab's
    local_capacity are dropped — size capacity for the post-balance maximum.
    """
    x = pool_channels["position"][:, 0]
    alive = pool_channels["alive"]
    shard = jnp.clip(jnp.searchsorted(boundaries[1:-1], x, side="right"),
                     0, dcfg.n_shards - 1)
    out = {}
    c = dcfg.local_capacity
    # rank within shard via stable sort by (shard, index); dead rows sort
    # (and stay) at key n_shards so live rows need NOT form a prefix —
    # checkpoint restore re-partitions global buffers with dead gaps
    order = jnp.argsort(jnp.where(alive, shard, dcfg.n_shards),
                        stable=True)
    sorted_shard = jnp.where(alive[order], shard[order], dcfg.n_shards)
    first = jnp.searchsorted(sorted_shard, jnp.arange(dcfg.n_shards))
    rank_in_shard = jnp.arange(x.shape[0]) - first[jnp.clip(sorted_shard, 0,
                                                            dcfg.n_shards - 1)]
    dst = sorted_shard * c + rank_in_shard
    ok = alive[order] & (rank_in_shard < c)
    dst = jnp.where(ok, dst, dcfg.n_shards * c)          # parked → dropped
    for k, v in pool_channels.items():
        buf = jnp.zeros((dcfg.n_shards * c,) + v.shape[1:], v.dtype)
        out[k] = buf.at[dst].set(v[order], mode="drop")
    # alive additionally masks the unpacked tail of every slab
    out["alive"] = jnp.zeros((dcfg.n_shards * c,), bool).at[dst].set(
        ok, mode="drop")
    return out


def pack_channels(mask: jnp.ndarray, channels: Dict[str, jnp.ndarray],
                  cap: int) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Pack masked agents into fixed (cap, ...) buffers, one per channel.

    The buffer layout IS the pool's channel spec — whatever channels the pool
    carries (behavior extras included) are shipped, dtype-preserving. The
    packed ``alive`` doubles as the lane-validity mask (mask ⊆ alive; invalid
    lanes are zeroed). Returns (buffers, overflow_count).
    """
    idx, n = compaction.active_index_list(mask)
    take = idx[:cap]
    lane_ok = jnp.arange(cap) < jnp.minimum(n, cap)
    buf = {}
    for k, v in channels.items():
        g = v[take]
        keep = lane_ok.reshape((cap,) + (1,) * (g.ndim - 1))
        buf[k] = jnp.where(keep, g, jnp.zeros_like(g))
    buf["alive"] = lane_ok & channels["alive"][take]
    return buf, jnp.maximum(n - cap, 0)


def _ppermute_tree(tree, axis: str, perm):
    return jax.tree_util.tree_map(
        lambda a: jax.lax.ppermute(a, axis, perm), tree)


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (new API, else experimental)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


class _ShardedDiffusionOps(diff_mod.DiffusionOps):
    """diffusion.DiffusionOps over x-slabs of the substance grid.

    ``step`` is the genuinely sharded compute: each substep exchanges
    one-voxel face halos with the ring neighbors (Neumann edges at the global
    faces) and runs the same FTCS arithmetic as the single-device grid
    (diffusion.step_slab — bit-identical per voxel). Agent coupling crosses
    slab lines through collectives, because quantile *agent* slabs need not
    align with the fixed *voxel* slabs: secretion scatters into a global-dims
    buffer reduced back to slabs with psum_scatter; sampling gathers the full
    grid (only traced when a behavior actually samples).
    """

    def __init__(self, spec: diff_mod.DiffusionSpec, origin, axis: str,
                 n_shards: int, fwd, bwd):
        super().__init__(spec, origin)
        self.axis, self.n_shards, self.fwd, self.bwd = axis, n_shards, fwd, bwd

    def step(self, conc, dt):
        recv_l = jax.lax.ppermute(conc[-1], self.axis, self.fwd)
        recv_r = jax.lax.ppermute(conc[0], self.axis, self.bwd)
        i = jax.lax.axis_index(self.axis)
        x_lo = jnp.where(i == 0, conc[0], recv_l)              # Neumann edge
        x_hi = jnp.where(i == self.n_shards - 1, conc[-1], recv_r)
        return diff_mod.step_slab(self.spec, conc, dt, x_lo, x_hi)

    def _gathered(self, conc):
        return jax.lax.all_gather(conc, self.axis, tiled=True)

    def sample(self, conc, position):
        return diff_mod.sample(self.spec, self._gathered(conc), position,
                               self.origin)

    def gradient(self, conc, position):
        return diff_mod.gradient(self.spec, self._gathered(conc), position,
                                 self.origin)

    def add_sources(self, conc, position, amount):
        g = jnp.zeros(self.spec.dims, jnp.float32)
        g = diff_mod.add_sources(self.spec, g, position, amount, self.origin)
        return conc + jax.lax.psum_scatter(g, self.axis,
                                           scatter_dimension=0, tiled=True)


def _channel_template(dcfg: DistConfig, behaviors: Sequence[Behavior]
                      ) -> AgentPool:
    """Zero pool defining the channel spec (ghost layout, state layout)."""
    specs: Dict[str, tuple] = {}
    for b in behaviors:
        specs.update(b.extra_specs())
    specs[OWNED] = ((), jnp.bool_, False)
    return make_pool(dcfg.total_capacity, extra_specs=specs,
                     policy=dcfg.engine.dtypes)


def make_distributed_step(dcfg: DistConfig, mesh, behaviors: Sequence[Behavior]
                          = (), axis: str = "data"):
    """Build the jitted shard_map step: DistState → DistState.

    Per shard and per step: halo exchange (spec-derived ghost rows appended
    to the local pool with owned=False) → the SHARED iteration core →
    optional in-loop quantile rebalance → ring migration through the
    birth-commit path → repack to local_capacity.
    """
    cfg = dcfg.engine
    n_shards = dcfg.n_shards
    c_local = dcfg.local_capacity
    hcap, mcap = dcfg.halo_capacity, dcfg.migrate_capacity
    if not 0 < hcap <= c_local or not 0 < mcap <= c_local:
        raise ValueError("halo/migrate capacity must be in (0, local_capacity]")
    if cfg.diffusion is not None and cfg.diffusion.dims[0] % n_shards:
        raise ValueError(f"diffusion dims[0]={cfg.diffusion.dims[0]} must be "
                         f"divisible by n_shards={n_shards} (x-slab sharding)")
    x_lo_dom = float(cfg.domain_lo[0])
    x_hi_dom = float(cfg.domain_hi[0])
    fwd = [(i, i + 1) for i in range(n_shards - 1)]
    bwd = [(i + 1, i) for i in range(n_shards - 1)]

    diff_ops = None
    if cfg.diffusion is not None:
        diff_ops = _ShardedDiffusionOps(cfg.diffusion,
                                        jnp.asarray(cfg.domain_lo, jnp.float32),
                                        axis, n_shards, fwd, bwd)
    core = make_iteration_core(cfg, behaviors, owned_channel=OWNED,
                               pvary_axes=(axis,), diff_ops=diff_ops)
    template = _channel_template(dcfg, behaviors)
    names = list(template.channels().keys())
    use_cache = cfg.rebuild.mode == "every_k"

    def step_shard(channels: Dict[str, jnp.ndarray], conc: jnp.ndarray,
                   rng: jax.Array, boundaries: jnp.ndarray,
                   iteration: jnp.ndarray,
                   env: Optional[grid_mod.RebuildState]):
        i = jax.lax.axis_index(axis)
        my_lo = boundaries[i]
        my_hi = boundaries[i + 1]
        alive = channels["alive"]
        x = channels["position"][:, 0]
        hw = jnp.float32(dcfg.halo_width)

        # ---- halo exchange: boundary bands → ghost rows of the neighbors ----
        band_l, ovf_hl = pack_channels(alive & (x < my_lo + hw), channels, hcap)
        band_r, ovf_hr = pack_channels(alive & (x > my_hi - hw), channels, hcap)
        ghosts_l = _ppermute_tree(band_r, axis, fwd)     # from shard i-1
        ghosts_r = _ppermute_tree(band_l, axis, bwd)     # from shard i+1
        # edge shards pack bands the ring never ships (no neighbor beyond the
        # domain face) — a pile-up against the wall must not flag overflow
        ovf_hl = jnp.where(i > 0, ovf_hl, 0)
        ovf_hr = jnp.where(i < n_shards - 1, ovf_hr, 0)
        # ring halo exactness also needs every *interior* slab to be at least
        # one band wide: a thinner one (quantile collapse against a pile-up —
        # even an empty slab) puts its two neighbors within r of each other
        # but two ring hops apart, so their pairs would be missed. The first/
        # last slabs may be arbitrarily thin (no shard beyond them). Flagged
        # on the same never-silent channel as the buffer overflows.
        thin = ((my_hi - my_lo < hw) & (i > 0)
                & (i < n_shards - 1)).astype(jnp.int32)

        full = {k: jnp.concatenate([channels[k], ghosts_l[k], ghosts_r[k]], 0)
                for k in names}
        full["extra." + OWNED] = jnp.concatenate(
            [jnp.ones((c_local,), bool), jnp.zeros((2 * hcap,), bool)], 0)
        pool = pool_from_channels(full)

        # ---- the shared Algorithm-1 iteration (engine.make_iteration_core) --
        n_ghosts = jnp.zeros((), jnp.int32)
        if use_cache:
            # a cached slab build is only valid over the layout it was built
            # on — which had every ghost slot dead (a build that saw live
            # ghosts marks itself dirty below, because next step's band holds
            # different agents). Live ghosts arriving NOW therefore force a
            # rebuild: the stale tables think their slots are empty.
            n_ghosts = (jnp.sum(ghosts_l["alive"].astype(jnp.int32))
                        + jnp.sum(ghosts_r["alive"].astype(jnp.int32)))
            env = dataclasses.replace(env, dirty=env.dirty | (n_ghosts > 0))
        pool, conc, rng, stats, env = core(pool, conc, rng, iteration, env)
        ch = pool.channels()
        owned = ch["extra." + OWNED].astype(bool)
        alive2 = ch["alive"] & owned
        x2 = ch["position"][:, 0]

        # ---- in-loop quantile rebalance (paper §4.2 balancing) ----
        if dcfg.rebalance_frequency > 0:
            def rebal(_):
                xg = jax.lax.all_gather(x2, axis, tiled=True)
                ag = jax.lax.all_gather(alive2, axis, tiled=True)
                return quantile_boundaries(xg, ag, n_shards, x_lo_dom,
                                           x_hi_dom)
            boundaries = jax.lax.cond(
                (iteration + 1) % dcfg.rebalance_frequency == 0,
                rebal, lambda b: b, boundaries)
            my_lo = boundaries[i]
            my_hi = boundaries[i + 1]

        # ---- ring migration: leavers append via the §3.2 birth-commit path --
        go_l = alive2 & (x2 < my_lo) & (i > 0)
        go_r = alive2 & (x2 >= my_hi) & (i < n_shards - 1)
        mig_l, ovf_ml = pack_channels(go_l, ch, mcap)
        mig_r, ovf_mr = pack_channels(go_r, ch, mcap)
        arrivals_l = _ppermute_tree(mig_r, axis, fwd)
        arrivals_r = _ppermute_tree(mig_l, axis, bwd)

        ch["alive"] = alive2 & ~go_l & ~go_r       # drop ghosts + leavers
        pool = compaction.compact(pool_from_channels(ch))
        ovf_in = jnp.zeros((), jnp.int32)
        n_arrive = jnp.zeros((), jnp.int32)
        for arr in (arrivals_l, arrivals_r):
            valid = arr["alive"]
            ovf_in += compaction.birth_overflow(pool, valid)
            n_arrive += jnp.sum(valid.astype(jnp.int32))
            # commit_births preserves every shipped channel (born_iter, owned,
            # behavior extras) — agents born this step migrate intact
            pool = compaction.commit_births(pool, arr, valid, iteration)

        if use_cache:
            # distribution events that reorder the slab on top of the core's
            # own deaths/births: live ghosts this step (their slots churn),
            # leavers (the end-of-step compact permutes), and arrivals
            # (append through slots the tables call dead). Any of them → the
            # cached tables no longer describe the next step's layout.
            n_leave = jnp.sum((go_l | go_r).astype(jnp.int32))
            env = dataclasses.replace(
                env, dirty=(env.dirty | (n_ghosts > 0) | (n_leave > 0)
                            | (n_arrive > 0)))

        n_final = pool.n_live
        ovf_cap = jnp.maximum(n_final - c_local, 0)     # clipped on repack
        out_ch = {k: v[:c_local] for k, v in pool.channels().items()}
        # an owned agent still outside its slab after this step's one ring
        # hop (displaced ≥2 slabs by a rebalance) begins the next iteration
        # with an incomplete neighborhood — nothing is dropped (it converges
        # one hop per step), so it gets its own never-silent counter rather
        # than polluting migrate_overflow's raise-the-buffer remediation
        xf = out_ch["position"][:, 0]
        in_flight = jnp.sum((out_ch["alive"]
                             & (((xf < my_lo) & (i > 0))
                                | ((xf >= my_hi) & (i < n_shards - 1)))
                             ).astype(jnp.int32))
        # which-capacity provenance (§4.3): each flag names exactly one
        # growable knob — halo_overflow → halo_capacity, migrate_overflow →
        # migrate_capacity, birth_overflow (staged newborns + arrivals +
        # repack clipping) → local_capacity with capacity_demand its rung
        # target; thin_slab is NOT growable (quantile geometry, not a buffer)
        stats = dataclasses.replace(
            stats,
            n_live=jnp.sum(out_ch["alive"].astype(jnp.int32)),
            halo_overflow=(ovf_hl + ovf_hr).astype(jnp.int32),
            migrate_overflow=(ovf_ml + ovf_mr).astype(jnp.int32),
            birth_overflow=(stats.birth_overflow + ovf_in
                            + ovf_cap).astype(jnp.int32),
            capacity_demand=(n_final + ovf_in
                             + stats.birth_overflow).astype(jnp.int32),
            thin_slab=thin.astype(jnp.int32),
            in_flight=in_flight.astype(jnp.int32))
        stats = jax.tree_util.tree_map(lambda v: v.reshape(1), stats)
        return out_ch, conc, rng.reshape(1, -1), boundaries, stats, env

    ch_specs = {k: P(axis) for k in names}
    # the env cache shards like the pool: every RebuildState leaf gains a
    # leading (n_shards,) axis (None under every_step — an empty pytree, so
    # the spec position is None too)
    env_specs = None
    if use_cache:
        env_specs = jax.tree_util.tree_map(
            lambda _: P(axis),
            grid_mod.initial_rebuild_state(
                cfg.grid_spec, dcfg.total_capacity,
                jnp.asarray(cfg.domain_lo, jnp.float32),
                jnp.asarray(cfg.cell_size, jnp.float32),
                pairlist=cfg.pairlist))
    in_specs = (ch_specs, P(axis), P(axis), P(), P(), env_specs)
    out_specs = (ch_specs, P(axis), P(axis), P(),
                 StepStats(**{f: P(axis) for f in StepStats.FIELDS}),
                 env_specs)

    def _shard_body(channels, conc, rng, boundaries, iteration, env):
        # per-shard env leaves arrive with a leading axis of 1; the core works
        # on unsharded shapes, so squeeze in and restore on the way out
        env_in = (None if env is None
                  else jax.tree_util.tree_map(lambda a: a[0], env))
        out_ch, conc2, rng2, boundaries2, stats, env_out = step_shard(
            channels, conc, rng.reshape(-1), boundaries, iteration, env_in)
        if env_out is not None:
            env_out = jax.tree_util.tree_map(lambda a: a[None], env_out)
        return out_ch, conc2, rng2, boundaries2, stats, env_out

    sharded = _shard_map(_shard_body, mesh, in_specs, out_specs)

    def step(state: DistState) -> DistState:
        ch, conc, rng, boundaries, stats, env = sharded(
            state.channels, state.conc, state.rng, state.boundaries,
            state.iteration, state.env)
        return DistState(channels=ch, conc=conc, rng=rng,
                         boundaries=boundaries,
                         iteration=state.iteration + 1, stats=stats, env=env)

    return jax.jit(step)


class DistributedSimulation:
    """Drop-in distributed counterpart of engine.Simulation.

    Same config + behaviors; state is sharded over ``dcfg.n_shards`` devices
    of ``mesh`` (default: the first n_shards of jax.devices()). Because every
    slab runs the shared iteration core, any scenario that runs on
    `Simulation` runs here unchanged — forces, behaviors, births/deaths,
    statics, and diffusion included.
    """

    def __init__(self, dcfg: DistConfig, behaviors: Sequence[Behavior] = (),
                 mesh=None, axis: str = "data"):
        self.dcfg = dcfg
        self.behaviors = list(behaviors)
        self.axis = axis
        if mesh is None:
            devices = jax.devices()
            if len(devices) < dcfg.n_shards:
                raise ValueError(
                    f"n_shards={dcfg.n_shards} > {len(devices)} devices "
                    f"(set XLA_FLAGS=--xla_force_host_platform_device_count)")
            mesh = jax.sharding.Mesh(np.array(devices[:dcfg.n_shards]),
                                     (axis,))
        self.mesh = mesh
        self._step_fn = make_distributed_step(dcfg, mesh, self.behaviors,
                                              axis)

    # -- state construction -------------------------------------------------
    def init_state(self, position, diameter=None, agent_type=None,
                   extra_init: Dict[str, jnp.ndarray] | None = None,
                   seed: int = 0) -> DistState:
        dcfg, cfg = self.dcfg, self.dcfg.engine
        position = jnp.asarray(position)
        staging = stage_pool(position.shape[0], self.behaviors, position,
                             diameter, agent_type, extra_init,
                             extra_specs={OWNED: ((), jnp.bool_, True)},
                             policy=cfg.dtypes)
        ch = staging.channels()
        boundaries = quantile_boundaries(ch["position"][:, 0], ch["alive"],
                                         dcfg.n_shards,
                                         float(cfg.domain_lo[0]),
                                         float(cfg.domain_hi[0]))
        # never-silent contract at init too: partition_global drops agents
        # past a slab's local_capacity, so refuse instead (host-side check —
        # heavy ties can pile a whole cluster into one quantile slab)
        b = np.asarray(boundaries)
        shard = np.clip(np.searchsorted(b[1:-1], np.asarray(ch["position"][:, 0]),
                                        side="right"), 0, dcfg.n_shards - 1)
        per_shard = np.bincount(shard[np.asarray(ch["alive"])],
                                minlength=dcfg.n_shards)
        if per_shard.max(initial=0) > dcfg.local_capacity:
            raise SlabCapacityError(
                f"slab populations {per_shard.tolist()} exceed "
                f"local_capacity={dcfg.local_capacity}; raise it (heavy ties "
                f"in x can defeat quantile balancing)")
        channels = partition_global(ch, boundaries, dcfg)
        dspec = cfg.diffusion
        conc = (jnp.zeros(dspec.dims, jnp.float32) if dspec
                else jnp.zeros((dcfg.n_shards, 1, 1)))
        rng = jax.vmap(lambda s: jax.random.fold_in(jax.random.PRNGKey(seed),
                                                    s))(
            jnp.arange(dcfg.n_shards, dtype=jnp.uint32))
        env = None
        if cfg.rebuild.mode == "every_k":
            # one empty-dirty cache per shard, stacked on a leading axis
            env0 = grid_mod.initial_rebuild_state(
                cfg.grid_spec, dcfg.total_capacity,
                jnp.asarray(cfg.domain_lo, jnp.float32),
                jnp.asarray(cfg.cell_size, jnp.float32),
                pairlist=cfg.pairlist)
            env = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (dcfg.n_shards,)
                                           + a.shape).copy(), env0)
        return DistState(channels=channels, conc=conc, rng=rng,
                         boundaries=boundaries,
                         iteration=jnp.zeros((), jnp.int32),
                         stats=StepStats.zeros((dcfg.n_shards,)), env=env)

    # -- public API ----------------------------------------------------------
    def step(self, state: DistState) -> DistState:
        return self._step_fn(state)

    def run(self, state: DistState, n_iterations: int,
            check_overflow: bool = False) -> DistState:
        """Run ``n_iterations``; with ``check_overflow`` the host enforces the
        §4.2 never-silent-loss contract over every per-shard flag."""
        for i in range(n_iterations):
            state = self._step_fn(state)
            if check_overflow:
                flags = state.stats.flags()   # only nonzero §4.2 flags
                if flags:
                    s = state.stats
                    remediation = {
                        "halo_overflow": (
                            f"halo overflow (ghost band exceeded "
                            f"halo_capacity={self.dcfg.halo_capacity}); "
                            f"raise halo_capacity"),
                        "thin_slab": (
                            f"an interior slab is thinner than the "
                            f"{self.dcfg.halo_width:.3g} ghost band (one-hop "
                            f"ring cannot ship every cross-shard pair); "
                            f"revisit boundaries / fewer shards"),
                        "migrate_overflow": (
                            f"migration overflow (ring buffer "
                            f"migrate_capacity={self.dcfg.migrate_capacity} "
                            f"exceeded)"),
                        "in_flight": (
                            f"{flags.get('in_flight', 0)} agents in flight "
                            f"across >1 slab (a rebalance moved a boundary "
                            f"further than one slab width; their next step "
                            f"sees an incomplete neighborhood) — lower "
                            f"rebalance_frequency or accept the transient by "
                            f"polling stats.in_flight instead of "
                            f"check_overflow"),
                        "box_overflow": (
                            "grid run overflow on a shard; raise "
                            "EngineConfig.max_per_run / max_per_box"),
                        "pair_overflow": (
                            f"pair-list overflow on a shard (an agent has "
                            f"more in-range(+skin) candidates than "
                            f"max_pairs; per-shard demand "
                            f"{np.asarray(s.pair_demand).tolist()}); raise "
                            f"PairListConfig.max_pairs"),
                        "birth_overflow": (
                            f"local pool overflow on a shard (staged "
                            f"newborns / migration arrivals / repack "
                            f"exceeded local_capacity="
                            f"{self.dcfg.local_capacity}; per-shard demand "
                            f"{np.asarray(s.capacity_demand).tolist()}); "
                            f"raise DistConfig.local_capacity"),
                    }
                    # report in severity order, not dict order
                    for f in ("halo_overflow", "thin_slab",
                              "migrate_overflow", "in_flight",
                              "box_overflow", "pair_overflow",
                              "birth_overflow"):
                        if f in flags:
                            raise RuntimeError(
                                f"iteration {i}: {remediation[f]}")
        return state

    def gather_channels(self, state: DistState) -> Dict[str, np.ndarray]:
        """Host-side: fetch the global channel arrays (live agents only are
        meaningful; order is arbitrary across shards)."""
        return {k: np.asarray(v) for k, v in state.channels.items()}


# ---------------------------------------------------------------------------
# Distributed capacity ladder (DESIGN.md §4.3) — agreed global rungs
# ---------------------------------------------------------------------------

class DistributedCapacityLadder(LadderDriverBase):
    """`DistributedSimulation.run` with automatic growth, one global rung.

    Every capacity knob (local pool slots, halo band, migration ring,
    max_per_run) is *static and shared* across shards — a single shard's
    overflow therefore grows the knob for the whole mesh ("agreed global
    rung"): rung targets are the max of the per-shard demand provenance in
    StepStats, so one recompile serves every slab and the shard_map program
    stays homogeneous. Like the single-device CapacityLadder, the
    overflowing iteration is re-run from its pre-step state, which keeps
    trajectories bit-identical to a pre-sized run.

    Non-buffer exactness flags (thin_slab, in_flight) are not growable —
    they raise with remediation guidance instead of looping forever.
    """

    def __init__(self, dcfg: DistConfig, behaviors: Sequence[Behavior] = (),
                 ladder=None, mesh=None, axis: str = "data"):
        self.ladder = ladder or LadderConfig()
        self.dcfg = dcfg
        self.behaviors = list(behaviors)
        self.axis = axis
        self._mesh = mesh
        self.rungs: list = []
        self.recompiles = 0
        self._sim = DistributedSimulation(dcfg, self.behaviors, mesh, axis)

    @property
    def sim(self) -> DistributedSimulation:
        return self._sim

    def init_state(self, *args, **kwargs) -> DistState:
        """init with ladder semantics: an initial population too big for a
        slab grows local_capacity instead of raising (bounded retries)."""
        for _ in range(self.ladder.max_grows_per_step):
            try:
                return self._sim.init_state(*args, **kwargs)
            except SlabCapacityError:
                d = self.dcfg
                new_local = next_rung(d.local_capacity, d.local_capacity + 1,
                                      self.ladder.growth_factor,
                                      self.ladder.round_to)
                self._rebuild(dataclasses.replace(d, local_capacity=new_local),
                              iteration=-1)
        raise RuntimeError("init_state: local_capacity growth did not "
                           "converge (pathological initial distribution)")

    # -- growth policy -------------------------------------------------------
    def _diagnose(self, stats: StepStats) -> Optional[DistConfig]:
        d, lad = self.dcfg, self.ladder
        tot = lambda f: int(np.asarray(jnp.sum(stats[f])))
        if tot("thin_slab"):
            raise RuntimeError(
                "thin interior slab (quantile geometry, not a buffer size) — "
                "the ladder cannot grow past it; use fewer shards or a wider "
                "domain")
        if tot("in_flight"):
            raise RuntimeError(
                "agents in flight across >1 slab after a rebalance — lower "
                "rebalance_frequency (not a capacity problem)")
        changes = {}
        eng = d.engine
        if tot("box_overflow"):
            demand = int(np.asarray(jnp.max(stats["box_demand"])))
            if eng.environment == "hash_grid":
                need = -(-demand // grid_mod.HASH_K_MULT)
                eng = dataclasses.replace(eng, max_per_box=next_rung(
                    eng.max_per_box, need, lad.growth_factor))
            else:
                cur = eng.grid_spec.run_capacity
                eng = dataclasses.replace(eng, max_per_run=next_rung(
                    cur, demand, lad.growth_factor))
        if tot("pair_overflow"):
            # agreed global rung: one max_pairs for every shard, sized off
            # the worst per-shard demand
            demand = int(np.asarray(jnp.max(stats["pair_demand"])))
            eng = dataclasses.replace(eng, pairlist=dataclasses.replace(
                eng.pairlist, max_pairs=next_rung(
                    eng.pairlist.max_pairs, demand, lad.growth_factor)))
        if eng is not d.engine:
            changes["engine"] = eng
        if tot("halo_overflow"):
            demand = d.halo_capacity + int(np.asarray(
                jnp.max(stats["halo_overflow"])))
            changes["halo_capacity"] = next_rung(
                d.halo_capacity, demand, lad.growth_factor, lad.round_to)
        if tot("migrate_overflow"):
            demand = d.migrate_capacity + int(np.asarray(
                jnp.max(stats["migrate_overflow"])))
            changes["migrate_capacity"] = next_rung(
                d.migrate_capacity, demand, lad.growth_factor, lad.round_to)
        if tot("birth_overflow"):
            demand = int(np.asarray(jnp.max(stats["capacity_demand"])))
            new_local = next_rung(d.local_capacity, demand,
                                  lad.growth_factor, lad.round_to)
            if (lad.max_capacity is not None
                    and new_local * d.n_shards > lad.max_capacity):
                raise CapacityExhausted(
                    f"capacity ladder exhausted: per-shard demand {demand} "
                    f"needs {new_local}×{d.n_shards} slots > "
                    f"max_capacity={lad.max_capacity}",
                    demand=demand, rung=new_local * d.n_shards,
                    max_capacity=lad.max_capacity)
            changes["local_capacity"] = new_local
        if not changes:
            return None
        new_d = dataclasses.replace(d, **changes)
        # static contract: halo/migrate buffers never exceed local_capacity
        if new_d.local_capacity < max(new_d.halo_capacity,
                                      new_d.migrate_capacity):
            new_d = dataclasses.replace(
                new_d, local_capacity=max(new_d.halo_capacity,
                                          new_d.migrate_capacity))
        return new_d

    def _rebuild(self, new_d: DistConfig, iteration: int) -> None:
        self._log_rungs(
            iteration,
            [(f, getattr(self.dcfg, f), getattr(new_d, f))
             for f in ("local_capacity", "halo_capacity", "migrate_capacity")]
            + [(f, getattr(self.dcfg.engine, f), getattr(new_d.engine, f))
               for f in ("max_per_box", "max_per_run")]
            + ([("max_pairs", self.dcfg.engine.pairlist.max_pairs,
                 new_d.engine.pairlist.max_pairs)]
               if (new_d.engine.pairlist is not None
                   and self.dcfg.engine.pairlist is not None) else []))
        self.dcfg = new_d
        self._sim = DistributedSimulation(new_d, self.behaviors, self._mesh,
                                          self.axis)

    def _restage(self, state: DistState, old_local: int, new_local: int
                 ) -> DistState:
        """Host-side re-pack of every shard's slab into the new local width.

        Each shard's live prefix is preserved verbatim; new tail slots are
        zero (dead) — the distributed analog of compaction.grow_channels
        (compaction.repack_slabs, shared with checkpoint restore).
        """
        ch = compaction.repack_slabs(state.channels, self.dcfg.n_shards,
                                     old_local, new_local)
        return dataclasses.replace(state, channels=ch)

    def _grow(self, new_d: DistConfig, prev: DistState,
              iteration: int) -> DistState:
        old_local = self.dcfg.local_capacity
        old_total = self.dcfg.total_capacity
        old_pl = self.dcfg.engine.pairlist
        self._rebuild(new_d, iteration)
        if new_d.local_capacity != old_local:
            prev = self._restage(prev, old_local, new_d.local_capacity)
        new_pl = new_d.engine.pairlist
        if (prev.env is not None and prev.env.pairs is not None
                and new_pl is not None and old_pl is not None
                and (new_d.total_capacity != old_total
                     or new_pl.max_pairs != old_pl.max_pairs)):
            # (S, C, P) tables: grow_pairlist pads the trailing axes only —
            # an overflowed cached list never survives a kept step (the
            # rewind discards the overflowing step's output), so the zero
            # padding is exactly what a pre-sized build would hold
            prev = dataclasses.replace(
                prev, env=dataclasses.replace(
                    prev.env, pairs=grid_mod.grow_pairlist(
                        prev.env.pairs, new_d.total_capacity,
                        new_pl.max_pairs)))
        if prev.env is not None and new_d.total_capacity != old_total:
            # the cached grid spans the in-step pool (owned + ghost bands);
            # grow it alongside. grow_grid_state's dead-key/iota padding is
            # exactly what a pre-sized build over the wider pool would have
            # produced (live slots form a prefix whenever the cache is
            # clean), so the rewound trajectory stays bit-identical — no
            # dirty-forcing needed, which would instead reshuffle the skip
            # schedule
            prev = dataclasses.replace(
                prev, env=dataclasses.replace(
                    prev.env, grid=grid_mod.grow_grid_state(
                        prev.env.grid, new_d.total_capacity)))
        return prev
