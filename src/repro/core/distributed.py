"""Distributed ABM engine — the paper's §8 'future work' (multi-node), realized.

Design (DESIGN.md §7):
  * **1-D slab domain decomposition** along x over mesh axis ``data``: each
    device owns agents with x ∈ [b_i, b_{i+1}). Slab boundaries come from
    population *quantiles* — the paper's §4.2 balancing (equal agents per NUMA
    domain) lifted to devices. Within a slab, the Morton sort still provides
    memory locality (§4.2) — the two mechanisms compose.
  * **Ring halo exchange**: interaction radius r ≤ slab width ⇒ every cross-
    shard interaction partner lives in the adjacent slab; one
    ``collective_permute`` left + one right per step ships the boundary layer
    (ghost agents, force *sources* only). O(surface) bytes, independent of the
    number of shards — the property that scales to 1000+ nodes.
  * **Ring migration**: agents that cross a slab boundary are shipped to the
    neighbor with the same prefix-sum packing as §3.2 and appended via the
    birth-commit path; leavers are compacted out. Fixed-capacity buffers with
    overflow flags (never silent loss).

Everything runs under one ``shard_map`` program: the whole distributed step is
a single XLA executable per device, with exactly 4 collective-permutes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compaction, grid as grid_mod, morton
from .agents import AgentPool, make_pool
from .engine import EngineConfig
from .forces import displacement, make_force_pair_fn

# ghost/migration channel layout: x, y, z, diameter, type, alive
_GHOST_CH = 6


@dataclasses.dataclass(frozen=True)
class DistConfig:
    engine: EngineConfig
    n_shards: int
    local_capacity: int
    halo_capacity: int = 1024
    migrate_capacity: int = 256


def quantile_boundaries(x: jnp.ndarray, alive: jnp.ndarray, n_shards: int,
                        lo: float, hi: float) -> jnp.ndarray:
    """Equal-population slab boundaries (paper §4.2 balancing)."""
    big = jnp.where(alive, x, jnp.inf)
    xs = jnp.sort(big)
    n = jnp.sum(alive.astype(jnp.int32))
    qs = (jnp.arange(1, n_shards) * n) // n_shards
    inner = xs[jnp.clip(qs, 0, x.shape[0] - 1)]
    return jnp.concatenate([jnp.asarray([lo]), inner, jnp.asarray([hi])])


def partition_global(pool_channels: Dict[str, jnp.ndarray],
                     boundaries: jnp.ndarray, dcfg: DistConfig
                     ) -> Dict[str, jnp.ndarray]:
    """Host-side: scatter agents into per-shard slots [shard, local_capacity].

    Returns channels with leading dim n_shards*local_capacity, agents of shard
    i in slice [i*C, i*C + n_i). (Used at init and at rebalance epochs.)"""
    x = pool_channels["position"][:, 0]
    alive = pool_channels["alive"]
    shard = jnp.clip(jnp.searchsorted(boundaries[1:-1], x, side="right"),
                     0, dcfg.n_shards - 1)
    out = {}
    c = dcfg.local_capacity
    # rank within shard via stable sort by (shard, index)
    order = jnp.argsort(jnp.where(alive, shard, dcfg.n_shards),
                        stable=True)
    sorted_shard = shard[order]
    first = jnp.searchsorted(sorted_shard, jnp.arange(dcfg.n_shards))
    rank_in_shard = jnp.arange(x.shape[0]) - first[jnp.clip(sorted_shard, 0,
                                                            dcfg.n_shards - 1)]
    dst = sorted_shard * c + rank_in_shard
    ok = alive[order] & (rank_in_shard < c)
    dst = jnp.where(ok, dst, dcfg.n_shards * c)
    for k, v in pool_channels.items():
        buf_shape = (dcfg.n_shards * c,) + v.shape[1:]
        if k == "alive":
            buf = jnp.zeros(buf_shape, v.dtype)
        else:
            buf = jnp.zeros(buf_shape, v.dtype)
        out[k] = buf.at[dst].set(v[order], mode="drop")
    # fix alive: only packed slots alive
    out["alive"] = jnp.zeros((dcfg.n_shards * c,), bool).at[dst].set(
        alive[order], mode="drop")
    return out


def _pack(mask: jnp.ndarray, channels: Dict[str, jnp.ndarray], cap: int
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack masked agents into a fixed (cap, _GHOST_CH) buffer. Returns
    (buffer, overflow_count)."""
    idx, n = compaction.active_index_list(mask)
    take = idx[:cap]
    lane_ok = jnp.arange(cap) < jnp.minimum(n, cap)
    buf = jnp.stack([
        channels["position"][take, 0], channels["position"][take, 1],
        channels["position"][take, 2], channels["diameter"][take],
        channels["agent_type"][take].astype(jnp.float32),
        lane_ok.astype(jnp.float32),
    ], axis=-1)
    buf = jnp.where(lane_ok[:, None], buf, 0.0)
    return buf, jnp.maximum(n - cap, 0)


def make_distributed_step(dcfg: DistConfig, mesh, axis: str = "data"):
    """Build the jitted shard_map step: (channels, boundaries, iteration) →
    (channels, stats). Channels are the global SoA arrays sharded on dim 0."""
    cfg = dcfg.engine
    spec = cfg.grid_spec
    n_shards = dcfg.n_shards
    c_local = dcfg.local_capacity
    hcap, mcap = dcfg.halo_capacity, dcfg.migrate_capacity
    origin = jnp.asarray(cfg.domain_lo, jnp.float32)
    dlo = jnp.asarray(cfg.domain_lo, jnp.float32)
    dhi = jnp.asarray(cfg.domain_hi, jnp.float32)
    box = jnp.asarray(cfg.interaction_radius, jnp.float32)
    pair_fn = make_force_pair_fn(cfg.force,
                                 jnp.asarray(cfg.adhesion, jnp.float32)
                                 if cfg.adhesion is not None else None)
    fwd = [(i, i + 1) for i in range(n_shards - 1)]
    bwd = [(i + 1, i) for i in range(n_shards - 1)]

    def step_shard(channels: Dict[str, jnp.ndarray], boundaries: jnp.ndarray):
        i = jax.lax.axis_index(axis)
        my_lo = boundaries[i]
        my_hi = boundaries[i + 1]
        alive = channels["alive"]
        x = channels["position"][:, 0]
        r = cfg.interaction_radius

        # ---- halo exchange: boundary layers to ring neighbors ----
        left_b, ovf_l = _pack(alive & (x < my_lo + r), channels, hcap)
        right_b, ovf_r = _pack(alive & (x > my_hi - r), channels, hcap)
        ghosts_from_left = jax.lax.ppermute(right_b, axis, fwd)   # i-1 → i
        ghosts_from_right = jax.lax.ppermute(left_b, axis, bwd)   # i+1 → i
        ghosts = jnp.concatenate([ghosts_from_left, ghosts_from_right], 0)

        # ---- combined view: local agents + ghost force-sources ----
        comb = {
            "position": jnp.concatenate(
                [channels["position"], ghosts[:, 0:3]], 0),
            "diameter": jnp.concatenate([channels["diameter"], ghosts[:, 3]], 0),
            "agent_type": jnp.concatenate(
                [channels["agent_type"], ghosts[:, 4].astype(jnp.int32)], 0),
            "alive": jnp.concatenate([alive, ghosts[:, 5] > 0.5], 0),
        }
        pool_like = make_pool(comb["position"].shape[0])
        pool_like = dataclasses.replace(
            pool_like, position=comb["position"], diameter=comb["diameter"],
            agent_type=comb["agent_type"], alive=comb["alive"])
        genv = grid_mod.build(spec, pool_like, origin, box)

        n_local_live = jnp.sum(alive.astype(jnp.int32))
        idx, _ = compaction.active_index_list(
            jnp.concatenate([alive, jnp.zeros((2 * hcap,), bool)], 0))
        res = grid_mod.neighbor_apply(
            spec, genv, comb, idx, n_local_live, pair_fn,
            {"force": ((3,), jnp.float32), "force_nnz": ((), jnp.int32)},
            pvary_axes=(axis,))
        dx = displacement(res["force"][:c_local], cfg.force, cfg.dt)
        new_pos = jnp.clip(channels["position"] + dx, dlo, dhi)
        new_pos = jnp.where(alive[:, None], new_pos, channels["position"])
        channels = {**channels, "position": new_pos}

        # ---- migration: leavers to ring neighbors ----
        x2 = channels["position"][:, 0]
        go_left = alive & (x2 < my_lo) & (i > 0)
        go_right = alive & (x2 >= my_hi) & (i < n_shards - 1)
        mig_l, ovf_ml = _pack(go_left, channels, mcap)
        mig_r, ovf_mr = _pack(go_right, channels, mcap)
        arrive_from_left = jax.lax.ppermute(mig_r, axis, fwd)
        arrive_from_right = jax.lax.ppermute(mig_l, axis, bwd)
        arrivals = jnp.concatenate([arrive_from_left, arrive_from_right], 0)

        # remove leavers, compact, append arrivals (paper §3.2 machinery)
        stay = alive & ~go_left & ~go_right
        perm, n_stay = compaction.compaction_permutation(stay)
        packed = {k: jnp.take(v, perm, axis=0) for k, v in channels.items()}
        packed["alive"] = jnp.take(stay, perm)

        arr_valid = arrivals[:, 5] > 0.5
        dst = n_stay + jnp.cumsum(arr_valid.astype(jnp.int32)) - 1
        ok = arr_valid & (dst < c_local)
        dst = jnp.where(ok, dst, c_local)
        ovf_in = jnp.sum(arr_valid.astype(jnp.int32)) - jnp.sum(
            ok.astype(jnp.int32))
        packed["position"] = packed["position"].at[dst].set(
            arrivals[:, 0:3], mode="drop")
        packed["diameter"] = packed["diameter"].at[dst].set(
            arrivals[:, 3], mode="drop")
        packed["agent_type"] = packed["agent_type"].at[dst].set(
            arrivals[:, 4].astype(jnp.int32), mode="drop")
        packed["alive"] = packed["alive"].at[dst].set(ok, mode="drop")

        stats = {
            "n_live": jnp.sum(packed["alive"].astype(jnp.int32)),
            "halo_overflow": ovf_l + ovf_r,
            "migrate_overflow": ovf_ml + ovf_mr + ovf_in,
            "box_overflow": (genv.max_run_count > spec.run_capacity
                             ).astype(jnp.int32),
        }
        stats = {k: v.reshape(1) for k, v in stats.items()}   # (1,) per shard
        return packed, stats

    in_specs = ({k: P(axis) for k in ("position", "diameter", "agent_type",
                                      "alive")}, P())
    out_specs = ({k: P(axis) for k in ("position", "diameter", "agent_type",
                                       "alive")},
                 {k: P(axis) for k in ("n_live", "halo_overflow",
                                       "migrate_overflow", "box_overflow")})
    if hasattr(jax, "shard_map"):
        sharded = jax.shard_map(step_shard, mesh=mesh,
                                in_specs=in_specs, out_specs=out_specs)
    else:   # jax < 0.6: experimental namespace, no varying-axis checking
        from jax.experimental.shard_map import shard_map
        sharded = shard_map(step_shard, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
    return jax.jit(sharded)
