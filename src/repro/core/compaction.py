"""Parallel agent addition/removal — paper §3.2, as prefix-sum stream compaction.

The paper parallelizes removal with swap-with-last bookkeeping (to_right /
not_to_left auxiliary arrays + prefix sums) so that holes never exist in the
ResourceManager. The TPU-native equivalent of the same idea is data-parallel
stream compaction: one ``cumsum`` over the alive mask yields every surviving
agent's destination slot, and a scatter moves all channels at once. Work is
O(capacity) fully parallel (the paper's is O(removed) on a PRAM; under SPMD/XLA
the masked full-width scan is the faster realization because it is a single
vectorized pass with no data-dependent control flow).

Additions mirror the paper's thread-local queues: behaviors stage newborn agents
in a fixed-capacity *birth queue*; the commit reserves contiguous slots at the
tail ``[n_live, n_live + n_new)`` via the same prefix sum.

The per-step resident reorder (grid.build_resident) routes through
:func:`apply_permutation` with the grid sort key's argsort: dead slots carry
the maximum key (morton.DEAD_KEY), so the one permutation simultaneously
grid-orders the live agents and compacts the dead to the tail — composing the
paper's §3.2 removal with its §4.2 memory-layout sort.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .agents import AgentPool


def compaction_permutation(alive: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Permutation placing live slots first (stable), dead after (stable).

    Returns (perm, n_live): ``new[i] = old[perm[i]]``.
    """
    c = alive.shape[0]
    alive_i = alive.astype(jnp.int32)
    n_live = jnp.sum(alive_i)
    # destination of each old slot
    dst_live = jnp.cumsum(alive_i) - 1                      # valid where alive
    dst_dead = n_live + jnp.cumsum(1 - alive_i) - 1         # valid where dead
    dst = jnp.where(alive, dst_live, dst_dead)              # (C,) a permutation
    # invert: perm[dst[i]] = i
    perm = jnp.zeros((c,), jnp.int32).at[dst].set(jnp.arange(c, dtype=jnp.int32))
    return perm, n_live


def apply_permutation(pool: AgentPool, perm: jnp.ndarray) -> AgentPool:
    """Gather-reorder every SoA channel by ``perm``."""
    ch = pool.channels()
    return pool.with_channels({k: jnp.take(v, perm, axis=0) for k, v in ch.items()})


def compact(pool: AgentPool) -> AgentPool:
    """Remove dead agents: live agents move (stably) to slots [0, n_live)."""
    perm, _ = compaction_permutation(pool.alive)
    return apply_permutation(pool, perm)


def commit_births(pool: AgentPool, queue: Dict[str, jnp.ndarray],
                  queue_valid: jnp.ndarray, iteration: jnp.ndarray) -> AgentPool:
    """Append staged newborn agents at the tail of the live region.

    queue: dict of (Q, ...) channel arrays (same channel names as the pool,
           missing channels default to zeros / sensible flags).
    queue_valid: (Q,) bool — which queue slots hold a real newborn.
    Newborns whose destination exceeds capacity are dropped (counted by the
    engine as overflow; capacity sizing is a config responsibility).

    Queue-provided channels always win over the defaults below — which is
    what lets the distributed engine append migration *arrivals* through
    this same path (DESIGN.md §7.2): a migrating agent ships every channel
    (born_iter, moved/grew bookkeeping, behavior extras, owned flag) and
    lands on the destination shard bit-identical, including agents that were
    themselves born earlier in the same iteration.
    """
    c = pool.capacity
    n_live = pool.n_live
    qv = queue_valid.astype(jnp.int32)
    dst = n_live + jnp.cumsum(qv) - 1                      # (Q,) destination slots
    ok = queue_valid & (dst < c)
    dst = jnp.where(ok, dst, c)                            # parked writes go to c (dropped)

    ch = pool.channels()
    out = {}
    for k, v in ch.items():
        if k in queue:
            src = queue[k]
        elif k == "alive":
            src = jnp.ones(queue_valid.shape, bool)
        elif k == "static":
            src = jnp.zeros(queue_valid.shape, bool)
        elif k == "moved":
            src = jnp.ones(queue_valid.shape, bool)        # newborns wake neighborhoods
        elif k == "grew":
            src = jnp.ones(queue_valid.shape, bool)
        elif k == "born_iter":
            src = jnp.full(queue_valid.shape, iteration, jnp.int32)
        elif k == "force_nnz":
            src = jnp.zeros(queue_valid.shape, jnp.int32)
        else:
            src = jnp.zeros(queue_valid.shape + v.shape[1:], v.dtype)
        # scatter with drop semantics for parked index c
        out[k] = v.at[dst].set(src.astype(v.dtype), mode="drop")
    return pool.with_channels(out)


def birth_overflow(pool: AgentPool, queue_valid: jnp.ndarray) -> jnp.ndarray:
    """Number of staged newborns that will not fit in capacity."""
    n_new = jnp.sum(queue_valid.astype(jnp.int32))
    free = pool.capacity - pool.n_live
    return jnp.maximum(n_new - free, 0)


# ---------------------------------------------------------------------------
# Capacity-ladder restage (DESIGN.md §4.3)
# ---------------------------------------------------------------------------
#
# Growing a rung cannot resize arrays in place (XLA shapes are static): the
# restage allocates the larger fixed-shape channels and copies the old pool
# into the prefix. The old buffers are *donated* — XLA may reuse their memory
# for the output, so peak footprint during a grow is new + O(1) channels, not
# old + new. (Donation is a no-op on backends that don't implement it, e.g.
# CPU; correctness never depends on it.)

_GROW_CACHE: dict = {}


def _grow_fn(new_capacity: int, donate: bool):
    key = (new_capacity, donate)
    if key not in _GROW_CACHE:
        def grow(ch: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
            out = {}
            for k, v in ch.items():
                pad = jnp.zeros((new_capacity - v.shape[0], *v.shape[1:]),
                                v.dtype)
                out[k] = jnp.concatenate([v, pad], axis=0)
            return out
        _GROW_CACHE[key] = jax.jit(grow, donate_argnums=(0,) if donate else ())
    return _GROW_CACHE[key]


def grow_channels(ch: Dict[str, jnp.ndarray], new_capacity: int,
                  donate: bool | None = None) -> Dict[str, jnp.ndarray]:
    """Re-stage a channel dict into ``new_capacity`` slots (dtype-preserving).

    Slots ``[old_capacity, new_capacity)`` are zero-filled — dead (``alive``
    False), exactly like the tail of a freshly made pool — so live-trajectory
    parity vs a pre-sized pool holds (dead-slot content never reaches a live
    agent; DESIGN.md §4.3). ``donate`` defaults to on wherever the backend
    implements buffer donation.
    """
    cap = next(iter(ch.values())).shape[0]
    if new_capacity < cap:
        raise ValueError(f"cannot shrink pool {cap} -> {new_capacity}")
    if new_capacity == cap:
        return ch
    if donate is None:
        donate = jax.default_backend() not in ("cpu",)
    return _grow_fn(new_capacity, donate)(ch)


def grow_pool(pool: AgentPool, new_capacity: int,
              donate: bool | None = None) -> AgentPool:
    """Re-stage a pool into a larger fixed-shape pool (capacity-ladder rung)."""
    return pool.with_channels(grow_channels(pool.channels(), new_capacity,
                                            donate))


def repack_slabs(channels: Dict[str, jnp.ndarray], n_shards: int,
                 old_local: int, new_local: int) -> Dict[str, jnp.ndarray]:
    """Host-side re-pack of sharded slab channels into a new local width.

    Channels are global ``(n_shards·old_local, ...)`` arrays with shard i's
    agents in slice ``[i·old_local, i·old_local + n_i)``. Each shard's slab is
    preserved verbatim and padded with zero (dead) tail slots — the
    distributed analog of :func:`grow_channels`. Shared by the distributed
    capacity ladder's rung restage and checkpoint restore onto a run whose
    ``local_capacity`` rung differs (core/simcheck.py).
    """
    if new_local < old_local:
        raise ValueError(f"cannot shrink slabs {old_local} -> {new_local}")
    out = {}
    for k, v in channels.items():
        a = np.asarray(v).reshape((n_shards, old_local) + v.shape[1:])
        pad = np.zeros((n_shards, new_local - old_local) + v.shape[1:],
                       a.dtype)
        out[k] = jnp.asarray(
            np.concatenate([a, pad], axis=1).reshape(
                (n_shards * new_local,) + v.shape[1:]))
    return out


def active_index_list(active: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compact the indices of active agents to the front (static-region support).

    Returns (idx, n_active): ``idx[:n_active]`` are the active slots in order,
    the tail is padded with the last active index (safe to compute, ignored).
    Used to run the force computation over ⌈n_active/B⌉ blocks only (§5 / O6).
    """
    c = active.shape[0]
    a = active.astype(jnp.int32)
    n_active = jnp.sum(a)
    dst = jnp.where(active, jnp.cumsum(a) - 1, c)          # parked for inactive
    idx = jnp.zeros((c,), jnp.int32).at[dst].set(
        jnp.arange(c, dtype=jnp.int32), mode="drop")
    # pad the tail with a safe index (0 if none active)
    pad_val = jnp.where(n_active > 0, idx[jnp.maximum(n_active - 1, 0)], 0)
    idx = jnp.where(jnp.arange(c) < n_active, idx, pad_val)
    return idx, n_active


def active_block_list(active: jnp.ndarray, block: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Ids of ``block``-sized slot ranges containing ≥1 active agent.

    The block-granular form of :func:`active_index_list` (paper §5 / O6 on a
    vector machine): the resident layout keeps queries contiguous, so the
    force loop slices whole blocks and skips fully-inactive ones outright via
    a dynamic trip count. ``active.shape[0]`` need not divide ``block``; the
    trailing partial range counts as one block. Returns (blk_idx, n_blocks)
    with the tail of ``blk_idx`` padded safely (see active_index_list).
    """
    c = active.shape[0]
    n_blk = (c + block - 1) // block
    pad = n_blk * block - c
    blk_any = jnp.any(jnp.pad(active, (0, pad)).reshape(n_blk, block), axis=1)
    return active_index_list(blk_any)
