"""Static-region detection — paper §5 (omit collision force calculation).

An agent is *static* next iteration iff, in the last iteration (paper
conditions i–iv):
  (i)   the agent and none of its neighbors moved,
  (ii)  neither the agent's nor any neighbor's force-relevant attributes grew
        (e.g. larger diameter),
  (iii) no new agent was added within the interaction radius, and
  (iv)  at most one neighbor force was non-zero (so removals cannot release a
        previously-cancelled force).

Per-agent flags (moved / grew / born_iter / force_nnz) are maintained by the
engine. The neighborhood conditions (i–iii) are evaluated at **box
granularity** (DESIGN.md §5): one scatter-add folds per-agent disturbance
into the dense box table, a 3×3×3 windowed OR spreads it to each box's
neighborhood, and one per-agent lookup reads the result — O(C + M) table
work, *no pairwise sweep*. Because the box edge is ≥ the interaction radius,
every agent within the radius lies inside the 3×3×3 box neighborhood, so the
box-level aggregate is a conservative superset of the paper's radius test:
an agent flagged static is static under the exact test too (never a wrong
skip); a disturbed box merely wakes a slightly larger neighborhood.

Static agents are then excluded from the force computation at *block*
granularity — on TPU per-lane predication saves nothing, so the resident
layout's query loop drops whole fully-static blocks via a dynamic trip count
(grid.resident_apply / compaction.active_block_list), and the Pallas kernel
gives fully-static row blocks an empty column list (kernels/ops).

Under the distributed engine (DESIGN.md §7) the same functions run per slab:
ghost rows ship their owner's moved/grew/born_iter/force_nnz bookkeeping, so
boundary disturbance wakes agents across shard lines. Because a disturbance
up to *two* box widths away can flip an agent's flag (the disturbed box plus
one windowed-OR spread), the distributed wrapper widens its ghost band to
2·r when ``detect_static`` is on — keeping the never-wrong-skip guarantee.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .agents import AgentPool
from .grid import GridSpec, GridState


def _window_or(a: jnp.ndarray, axis: int) -> jnp.ndarray:
    """OR of each cell with its two neighbors along ``axis`` (edge-clipped)."""
    pad = [(1, 1) if ax == axis else (0, 0) for ax in range(a.ndim)]
    p = jnp.pad(a, pad)
    n = a.shape[axis]
    lo = jax.lax.slice_in_dim(p, 0, n, axis=axis)
    mid = jax.lax.slice_in_dim(p, 1, n + 1, axis=axis)
    hi = jax.lax.slice_in_dim(p, 2, n + 2, axis=axis)
    return lo | mid | hi


def neighborhood_disturbed(spec: GridSpec, grid: GridState, pool: AgentPool,
                           iteration: jnp.ndarray) -> jnp.ndarray:
    """(M,) bool per box: any agent in its 3×3×3 neighborhood was disturbed.

    'Disturbed' = moved or grew last iteration, or was born this iteration
    (newborns also carry moved=True from the birth commit, which covers the
    cross-iteration case). Works for both resident and non-resident grids:
    ``grid.keys`` is per-slot either way. Dead slots carry DEAD_KEY, which as
    int32 is -1 and would *wrap* to the last box, not drop — clamp to the
    out-of-range sentinel ``m`` first so mode="drop" really discards them
    (belt to the ``pool.alive`` mask's suspenders).
    """
    disturbed = pool.alive & (pool.moved | pool.grew
                              | (pool.born_iter == iteration))
    m = spec.table_size
    box = jnp.minimum(grid.keys, jnp.uint32(m)).astype(jnp.int32)
    per_box = jnp.zeros((m,), jnp.int32).at[box].add(
        disturbed.astype(jnp.int32), mode="drop")
    d3 = (per_box > 0).reshape(spec.dims)
    d3 = _window_or(_window_or(_window_or(d3, 0), 1), 2)
    return d3.reshape(-1)


def update_static_flags(pool: AgentPool, spec: GridSpec, grid: GridState,
                        iteration: jnp.ndarray) -> jnp.ndarray:
    """Recompute ``static`` for every live agent (paper §5 conditions i–iv).

    Conditions i–iii via the box-granular neighborhood aggregate (conservative
    superset of the radius test, see module docstring); condition iv from the
    per-agent ``force_nnz`` bookkeeping. Cost is one scatter-add over the box
    table plus three windowed ORs — static detection no longer costs a second
    neighbor sweep, which is what makes ``detect_static=True`` a measured win
    instead of pure overhead (BENCH_statics.json).
    """
    nbh = neighborhood_disturbed(spec, grid, pool, iteration)
    box = jnp.minimum(grid.keys, jnp.uint32(spec.table_size - 1)).astype(jnp.int32)
    neigh_disturbed = nbh[box]
    self_ok = ~pool.moved & ~pool.grew & (pool.born_iter != iteration)
    cond_iv = pool.force_nnz <= 1
    return pool.alive & self_ok & ~neigh_disturbed & cond_iv
