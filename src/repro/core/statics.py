"""Static-region detection — paper §5 (omit collision force calculation).

An agent is *static* next iteration iff, in the last iteration (paper
conditions i–iv):
  (i)   the agent and none of its neighbors moved,
  (ii)  neither the agent's nor any neighbor's force-relevant attributes grew
        (e.g. larger diameter),
  (iii) no new agent was added within the interaction radius, and
  (iv)  at most one neighbor force was non-zero (so removals cannot release a
        previously-cancelled force).

Per-agent flags (moved / grew / born_iter / force_nnz) are maintained by the
engine; this module computes the neighborhood aggregates with one pass of the
same grid machinery and combines them. Static agents are excluded from the
force computation via active-index compaction — on TPU, per-lane predication
saves nothing, so compute is skipped at *block* granularity
(compaction.active_index_list + dynamic trip count in grid.neighbor_apply;
DESIGN.md §2/O6).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from .agents import AgentPool


def statics_pair_fn(interaction_radius: jnp.ndarray, iteration: jnp.ndarray):
    """pair_fn aggregating neighborhood disturbance within the interaction radius."""

    def pair_fn(q: Dict[str, jnp.ndarray], nbr: Dict[str, jnp.ndarray],
                valid: jnp.ndarray, q_slot: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        d = nbr["position"] - q["position"][:, None, :]
        dist2 = jnp.sum(d * d, axis=-1)
        in_r = valid & nbr["alive"] & (dist2 <= interaction_radius ** 2)
        nbr_moved = jnp.any(in_r & nbr["moved"], axis=-1)
        nbr_grew = jnp.any(in_r & nbr["grew"], axis=-1)
        nbr_new = jnp.any(in_r & (nbr["born_iter"] == iteration), axis=-1)
        disturbed = nbr_moved | nbr_grew | nbr_new
        return {"neigh_disturbed": disturbed.astype(jnp.int32)}

    return pair_fn


def update_static_flags(pool: AgentPool,
                        interaction_radius: jnp.ndarray,
                        iteration: jnp.ndarray,
                        neighbor_apply: Callable) -> jnp.ndarray:
    """Recompute ``static`` for every live agent (paper §5 conditions i–iv).

    ``neighbor_apply`` is the engine's per-step closure — the candidate list
    and sorted channels it caches are shared with the force sweep, so this
    pass costs one extra sweep but zero extra candidate derivation
    (DESIGN.md §3.4).
    """
    res = neighbor_apply(
        statics_pair_fn(interaction_radius, iteration),
        {"neigh_disturbed": ((), jnp.int32)},
    )
    neigh_disturbed = res["neigh_disturbed"] > 0
    self_ok = ~pool.moved & ~pool.grew & (pool.born_iter != iteration)
    cond_iv = pool.force_nnz <= 1
    return pool.alive & self_ok & ~neigh_disturbed & cond_iv
