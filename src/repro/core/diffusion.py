"""Extracellular diffusion grid — paper Table 1 'diffusion volumes' substrate.

BioDynaMo couples agents to continuum substances (e.g. chemoattractants) on a
regular grid. We implement the same explicit FTCS scheme BioDynaMo uses
(central-difference Laplacian, decay term), with agent sources via scatter-add
and trilinear-free nearest-voxel sampling of values and gradients (matching
BioDynaMo's default EulerGrid + nearest lookup).

Stability: dt ≤ h²/(6·D) for the 3-D explicit scheme; ``stable_dt`` exposes it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiffusionSpec:
    dims: Tuple[int, int, int]      # voxels per axis
    coefficient: float = 0.1        # D
    decay: float = 0.0              # μ
    voxel: float = 1.0              # h


def stable_dt(spec: DiffusionSpec) -> float:
    return spec.voxel ** 2 / (6.0 * max(spec.coefficient, 1e-12))


def step(spec: DiffusionSpec, conc: jnp.ndarray, dt: float) -> jnp.ndarray:
    """One FTCS diffusion-decay step with zero-flux (Neumann) boundaries."""
    c = conc
    pad = jnp.pad(c, 1, mode="edge")
    lap = (pad[2:, 1:-1, 1:-1] + pad[:-2, 1:-1, 1:-1]
           + pad[1:-1, 2:, 1:-1] + pad[1:-1, :-2, 1:-1]
           + pad[1:-1, 1:-1, 2:] + pad[1:-1, 1:-1, :-2]
           - 6.0 * c) / (spec.voxel ** 2)
    return c + dt * (spec.coefficient * lap - spec.decay * c)


def voxel_of(spec: DiffusionSpec, position: jnp.ndarray, origin: jnp.ndarray
             ) -> jnp.ndarray:
    v = jnp.floor((position - origin) / spec.voxel).astype(jnp.int32)
    hi = jnp.asarray([d - 1 for d in spec.dims], jnp.int32)
    return jnp.clip(v, 0, hi)


def add_sources(spec: DiffusionSpec, conc: jnp.ndarray, position: jnp.ndarray,
                amount: jnp.ndarray, origin: jnp.ndarray) -> jnp.ndarray:
    """Scatter-add per-agent secretion into the voxel grid."""
    v = voxel_of(spec, position, origin)
    return conc.at[v[:, 0], v[:, 1], v[:, 2]].add(amount)


def sample(spec: DiffusionSpec, conc: jnp.ndarray, position: jnp.ndarray,
           origin: jnp.ndarray) -> jnp.ndarray:
    v = voxel_of(spec, position, origin)
    return conc[v[:, 0], v[:, 1], v[:, 2]]


def gradient(spec: DiffusionSpec, conc: jnp.ndarray, position: jnp.ndarray,
             origin: jnp.ndarray) -> jnp.ndarray:
    """Central-difference gradient sampled at agent voxels. (N, 3)."""
    pad = jnp.pad(conc, 1, mode="edge")
    gx = (pad[2:, 1:-1, 1:-1] - pad[:-2, 1:-1, 1:-1]) / (2 * spec.voxel)
    gy = (pad[1:-1, 2:, 1:-1] - pad[1:-1, :-2, 1:-1]) / (2 * spec.voxel)
    gz = (pad[1:-1, 1:-1, 2:] - pad[1:-1, 1:-1, :-2]) / (2 * spec.voxel)
    v = voxel_of(spec, position, origin)
    return jnp.stack([g[v[:, 0], v[:, 1], v[:, 2]] for g in (gx, gy, gz)], axis=-1)
