"""Extracellular diffusion grid — paper Table 1 'diffusion volumes' substrate.

BioDynaMo couples agents to continuum substances (e.g. chemoattractants) on a
regular grid. We implement the same explicit FTCS scheme BioDynaMo uses
(central-difference Laplacian, decay term), with agent sources via scatter-add
and trilinear-free nearest-voxel sampling of values and gradients (matching
BioDynaMo's default EulerGrid + nearest lookup).

Stability: dt ≤ h²/(6·D) for the 3-D explicit scheme; ``stable_dt`` exposes it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiffusionSpec:
    dims: Tuple[int, int, int]      # voxels per axis
    coefficient: float = 0.1        # D
    decay: float = 0.0              # μ
    voxel: float = 1.0              # h


def stable_dt(spec: DiffusionSpec) -> float:
    return spec.voxel ** 2 / (6.0 * max(spec.coefficient, 1e-12))


def step_slab(spec: DiffusionSpec, conc: jnp.ndarray, dt: float,
              x_lo: jnp.ndarray, x_hi: jnp.ndarray) -> jnp.ndarray:
    """FTCS step on an x-slab whose face neighbors are supplied externally.

    conc: (nx, ny, nz) local slab; x_lo / x_hi: (ny, nz) concentration planes
    just outside the slab's low/high x face — the one-voxel halos a
    distributed run exchanges with adjacent slabs (DESIGN.md §7). Passing the
    slab's own edge planes reproduces the zero-flux (Neumann) boundary, which
    is how :func:`step` is defined; y/z boundaries stay Neumann either way.
    """
    cx = jnp.concatenate([x_lo[None], conc, x_hi[None]], axis=0)
    pad = jnp.pad(cx, ((0, 0), (1, 1), (1, 1)), mode="edge")
    lap = (pad[2:, 1:-1, 1:-1] + pad[:-2, 1:-1, 1:-1]
           + pad[1:-1, 2:, 1:-1] + pad[1:-1, :-2, 1:-1]
           + pad[1:-1, 1:-1, 2:] + pad[1:-1, 1:-1, :-2]
           - 6.0 * conc) / (spec.voxel ** 2)
    return conc + dt * (spec.coefficient * lap - spec.decay * conc)


def step(spec: DiffusionSpec, conc: jnp.ndarray, dt: float) -> jnp.ndarray:
    """One FTCS diffusion-decay step with zero-flux (Neumann) boundaries."""
    return step_slab(spec, conc, dt, conc[0], conc[-1])


def voxel_of(spec: DiffusionSpec, position: jnp.ndarray, origin: jnp.ndarray
             ) -> jnp.ndarray:
    v = jnp.floor((position - origin) / spec.voxel).astype(jnp.int32)
    hi = jnp.asarray([d - 1 for d in spec.dims], jnp.int32)
    return jnp.clip(v, 0, hi)


def add_sources(spec: DiffusionSpec, conc: jnp.ndarray, position: jnp.ndarray,
                amount: jnp.ndarray, origin: jnp.ndarray) -> jnp.ndarray:
    """Scatter-add per-agent secretion into the voxel grid."""
    v = voxel_of(spec, position, origin)
    return conc.at[v[:, 0], v[:, 1], v[:, 2]].add(amount)


def sample(spec: DiffusionSpec, conc: jnp.ndarray, position: jnp.ndarray,
           origin: jnp.ndarray) -> jnp.ndarray:
    v = voxel_of(spec, position, origin)
    return conc[v[:, 0], v[:, 1], v[:, 2]]


def gradient(spec: DiffusionSpec, conc: jnp.ndarray, position: jnp.ndarray,
             origin: jnp.ndarray) -> jnp.ndarray:
    """Central-difference gradient sampled at agent voxels. (N, 3)."""
    pad = jnp.pad(conc, 1, mode="edge")
    gx = (pad[2:, 1:-1, 1:-1] - pad[:-2, 1:-1, 1:-1]) / (2 * spec.voxel)
    gy = (pad[1:-1, 2:, 1:-1] - pad[1:-1, :-2, 1:-1]) / (2 * spec.voxel)
    gz = (pad[1:-1, 1:-1, 2:] - pad[1:-1, 1:-1, :-2]) / (2 * spec.voxel)
    v = voxel_of(spec, position, origin)
    return jnp.stack([g[v[:, 0], v[:, 1], v[:, 2]] for g in (gx, gy, gz)], axis=-1)


class DiffusionOps:
    """Substance-grid operations as the iteration core consumes them.

    The core (engine.make_iteration_core) never touches the grid layout
    directly — it calls these four methods. This default implementation works
    on the full in-memory grid; the distributed engine substitutes a sharded
    implementation (distributed._ShardedDiffusionOps) whose ``step`` exchanges
    one-voxel face halos between x-slabs and whose agent coupling routes
    through collectives, so the *same* core serves both (DESIGN.md §7).
    """

    def __init__(self, spec: DiffusionSpec, origin: jnp.ndarray):
        self.spec = spec
        self.origin = origin

    def step(self, conc: jnp.ndarray, dt: float) -> jnp.ndarray:
        return step(self.spec, conc, dt)

    def sample(self, conc: jnp.ndarray, position: jnp.ndarray) -> jnp.ndarray:
        return sample(self.spec, conc, position, self.origin)

    def gradient(self, conc: jnp.ndarray, position: jnp.ndarray) -> jnp.ndarray:
        return gradient(self.spec, conc, position, self.origin)

    def add_sources(self, conc: jnp.ndarray, position: jnp.ndarray,
                    amount: jnp.ndarray) -> jnp.ndarray:
        return add_sources(self.spec, conc, position, amount, self.origin)
