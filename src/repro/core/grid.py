"""Uniform-grid neighbor search — paper §3.1, adapted sort-based for TPU.

BioDynaMo's grid stores each box's agents in an array-based linked list and
avoids zeroing boxes with a timestamp trick. Pointer chasing and per-box
timestamps are CPU idioms; the TPU-native formulation is:

  build:  box key per agent (Morton code of its cell) → parallel sort by key →
          per-box (start, count) via vectorized ``searchsorted`` over the dense
          Morton-indexed table. O(#agents log #agents) fully parallel work and
          O(#boxes) *vector* memset equivalents — no serial O(#boxes) pass, which
          is what the paper's timestamp trick was avoiding (DESIGN.md §2).
  query:  the 27 surrounding boxes (3×3×3, paper §3.1) are contiguous runs in
          sorted order; gather up to K candidates per box and mask by radius.

The sort is shared with the memory-layout optimization (§4.2): when the pool was
just Morton-sorted, ``order`` is near-identity and gathers stream linearly.

Alternative environments (paper Fig 11 comparison, DESIGN.md §10.5):
  * BruteForceEnvironment — exact O(N²) masked sweep (small N oracle).
  * ScatterGridEnvironment — 'standard' grid materializing a dense (boxes × K)
    table by scatter; models the cost of touching O(#boxes) memory that the
    paper's timestamp trick addresses.
  * HashGridEnvironment — fixed-bucket spatial hash (collisions filtered by the
    radius mask); models a memory-capped alternative.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import morton
from .agents import AgentPool

# 27 neighbor offsets of the 3x3x3 cube (static python constant).
_OFFSETS = np.array([(dx, dy, dz)
                     for dx in (-1, 0, 1)
                     for dy in (-1, 0, 1)
                     for dz in (-1, 0, 1)], dtype=np.int32)   # (27, 3)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static grid configuration (hashable; part of the jit cache key)."""
    dims: Tuple[int, int, int]          # boxes per axis
    max_per_box: int = 16               # K: query gather capacity per box
    query_chunk: int = 2048             # agents per neighbor-apply chunk

    @property
    def table_size(self) -> int:
        return morton.code_space_size(self.dims)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GridState:
    """Per-iteration neighbor index (rebuilt every step, paper Algorithm 1 L3-5)."""
    origin: jnp.ndarray        # (3,) float — grid origin (traced: domain may move)
    box_size: jnp.ndarray      # ()   float — box edge = interaction radius
    keys: jnp.ndarray          # (C,) uint32 — Morton box code per slot (dead → MAX)
    order: jnp.ndarray         # (C,) int32 — slot ids sorted by key (dead at end)
    rank: jnp.ndarray          # (C,) int32 — inverse of order
    starts: jnp.ndarray        # (M,) int32 — first sorted position of each box
    counts: jnp.ndarray        # (M,) int32 — agents in each box
    max_count: jnp.ndarray     # ()   int32 — max agents in any box (overflow check)


_DEAD_KEY = jnp.uint32(0xFFFFFFFF)


def build(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
          box_size: jnp.ndarray) -> GridState:
    """Build the grid index. O(#agents) parallel work + one parallel sort."""
    keys = morton.morton_keys(pool.position, origin, box_size, spec.dims)
    keys = jnp.where(pool.alive, keys, _DEAD_KEY)
    order = jnp.argsort(keys).astype(jnp.int32)              # stable radix-ish sort
    sorted_keys = keys[order]
    rank = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=jnp.int32))
    box_ids = jnp.arange(spec.table_size, dtype=jnp.uint32)
    starts = jnp.searchsorted(sorted_keys, box_ids, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(sorted_keys, box_ids, side="right").astype(jnp.int32)
    counts = ends - starts
    return GridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                     keys=keys, order=order, rank=rank, starts=starts,
                     counts=counts, max_count=jnp.max(counts))


def neighbor_candidates(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbor slot ids for each query position.

    query_pos: (Q, 3). Returns (ids, valid): (Q, 27*K) int32 slot ids and bool
    mask. Candidates are *box-level*; callers apply the radius test.
    """
    k = spec.max_per_box
    cell = morton.cell_of(query_pos, grid.origin, grid.box_size, spec.dims)  # (Q,3)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]             # (Q,27,3)
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)                 # (Q,27)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    codes = morton.encode3(ncell_c[..., 0], ncell_c[..., 1], ncell_c[..., 2])
    s = grid.starts[codes]                                                   # (Q,27)
    n = jnp.where(inside, grid.counts[codes], 0)
    lane = jnp.arange(k, dtype=jnp.int32)                                    # (K,)
    sorted_pos = s[..., None] + lane                                         # (Q,27,K)
    valid = lane < jnp.minimum(n, k)[..., None]                              # (Q,27,K)
    sorted_pos = jnp.where(valid, sorted_pos, 0)
    ids = grid.order[sorted_pos]                                             # (Q,27,K)
    q = query_pos.shape[0]
    return ids.reshape(q, 27 * k), valid.reshape(q, 27 * k)


def neighbor_apply(spec: GridSpec,
                   grid: GridState,
                   channels: Dict[str, jnp.ndarray],
                   query_idx: jnp.ndarray,
                   n_query: jnp.ndarray,
                   pair_fn: Callable[[Dict[str, jnp.ndarray],
                                      Dict[str, jnp.ndarray],
                                      jnp.ndarray, jnp.ndarray], Dict[str, jnp.ndarray]],
                   out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                   pvary_axes: Tuple[str, ...] = (),
                   ) -> Dict[str, jnp.ndarray]:
    """Apply ``pair_fn`` over each query agent's candidate neighborhood, chunked.

    The chunk loop has a *dynamic* trip count ⌈n_query / chunk⌉ — with
    static-region detection on, compute really does shrink with the active set
    (paper §5 / O6; DESIGN.md §2).

    channels: full per-slot SoA dict (what pair_fn may read).
    query_idx: (C,) int32 — compacted active slots (tail padded, see
      compaction.active_index_list); n_query: traced count.
    pair_fn(q, nbr, valid, q_slot) -> dict of per-query reductions; q entries are
      (B, ...) chunk slices, nbr entries are (B, 27K, ...) gathers, valid is
      (B, 27K) bool, q_slot is (B,) the query slot ids.
    out_specs: name → (shape_suffix, dtype) of per-agent outputs; results are
      scattered back to slot positions, zeros elsewhere.
    """
    c = channels["position"].shape[0]
    b = min(spec.query_chunk, c)
    n_chunks_max = (c + b - 1) // b
    # pad so dynamic_slice never clamps (clamping would desync q_slot vs lane_ok)
    qi = jnp.pad(query_idx, (0, n_chunks_max * b - c))
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {k: jax.lax.pcast(v, pvary_axes, to="varying")
                for k, v in outs.items()}

    def body(i, outs):
        sl = i * b
        q_slot = jax.lax.dynamic_slice(qi, (sl,), (b,))                     # (B,)
        lane_ok = (sl + jnp.arange(b)) < n_query                            # (B,)
        q = {k: v[q_slot] for k, v in channels.items()}
        ids, valid = neighbor_candidates(spec, grid, q["position"])
        valid &= lane_ok[:, None]
        valid &= ids != q_slot[:, None]                                     # exclude self
        nbr = {k: v[ids] for k, v in channels.items()}
        res = pair_fn(q, nbr, valid, q_slot)
        new_outs = {}
        for name, val in res.items():
            val = jnp.where(
                lane_ok.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = outs[name].at[q_slot].add(val.astype(outs[name].dtype),
                                                       mode="drop")
        for name in outs:
            if name not in res:
                new_outs[name] = outs[name]
        return new_outs

    n_chunks = jnp.minimum((n_query + b - 1) // b, n_chunks_max)
    return jax.lax.fori_loop(0, n_chunks, body, outs)


# ---------------------------------------------------------------------------
# Alternative environments (Fig 11 comparison)
# ---------------------------------------------------------------------------

def brute_force_apply(channels: Dict[str, jnp.ndarray],
                      alive: jnp.ndarray,
                      radius: jnp.ndarray,
                      pair_fn,
                      out_specs,
                      chunk: int = 512) -> Dict[str, jnp.ndarray]:
    """Exact O(N²) neighbor apply (oracle + Fig-11 baseline).

    pair_fn has the same signature as in neighbor_apply; candidates are *all*
    agents (validity = alive & within radius is left to pair_fn via ``valid``
    carrying alive & not-self; radius masking is pair_fn's own distance test,
    identical to the grid path).
    """
    c = channels["position"].shape[0]
    chunk = min(chunk, c)
    n_chunks = (c + chunk - 1) // chunk
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}

    def body(i, outs):
        sl = i * chunk
        q_slot = sl + jnp.arange(chunk, dtype=jnp.int32)
        q_slot = jnp.minimum(q_slot, c - 1)
        lane_ok = (sl + jnp.arange(chunk)) < c
        q = {k: v[q_slot] for k, v in channels.items()}
        ids = jnp.arange(c, dtype=jnp.int32)
        valid = alive[None, :] & lane_ok[:, None]
        valid &= ids[None, :] != q_slot[:, None]
        nbr = {k: jnp.broadcast_to(v[None], (chunk, *v.shape)) for k, v in channels.items()}
        res = pair_fn(q, nbr, valid, q_slot)
        new_outs = dict(outs)
        for name, val in res.items():
            val = jnp.where(lane_ok.reshape((chunk,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = outs[name].at[q_slot].add(val.astype(outs[name].dtype),
                                                       mode="drop")
        return new_outs

    return jax.lax.fori_loop(0, n_chunks, body, outs)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScatterGridState:
    """'Standard implementation' grid: dense (boxes × K) member table via scatter.

    Models BioDynaMo's *unoptimized* path: the table is re-zeroed and re-scattered
    every iteration, touching O(#boxes · K) memory — the cost the paper's
    timestamp trick (and our sort-based build) avoids.
    """
    origin: jnp.ndarray
    box_size: jnp.ndarray
    table: jnp.ndarray         # (M, K) int32 slot ids, -1 = empty
    counts: jnp.ndarray        # (M,)


def build_scatter_grid(spec: GridSpec, pool: AgentPool, origin, box_size
                       ) -> ScatterGridState:
    m, k = spec.table_size, spec.max_per_box
    keys = morton.morton_keys(pool.position, origin, box_size, spec.dims)
    keys = jnp.where(pool.alive, keys, m)  # park dead at row m (dropped)
    # slot-within-box via sort (the CPU version uses sequential insertion;
    # the data-parallel equivalent needs a sort or atomics — we sort).
    order = jnp.argsort(keys)
    sorted_keys = keys[order]
    first = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    slot_in_box = jnp.arange(keys.shape[0]) - first                  # rank within box
    table = jnp.full((m + 1, k), -1, jnp.int32)
    sk = jnp.minimum(slot_in_box, k - 1)
    table = table.at[sorted_keys.astype(jnp.int32), sk].set(order.astype(jnp.int32),
                                                            mode="drop")
    counts = jnp.zeros((m + 1,), jnp.int32).at[keys.astype(jnp.int32)].add(
        pool.alive.astype(jnp.int32), mode="drop")
    return ScatterGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                            table=table[:m], counts=counts[:m])


def scatter_grid_candidates(spec: GridSpec, g: ScatterGridState, query_pos
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = spec.max_per_box
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    codes = morton.encode3(ncell_c[..., 0], ncell_c[..., 1], ncell_c[..., 2]).astype(jnp.int32)
    members = g.table[codes]                                      # (Q,27,K)
    valid = (members >= 0) & inside[..., None]
    q = query_pos.shape[0]
    return jnp.maximum(members, 0).reshape(q, 27 * k), valid.reshape(q, 27 * k)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HashGridState:
    """Spatial-hash grid with a fixed bucket table (memory-capped alternative)."""
    origin: jnp.ndarray
    box_size: jnp.ndarray
    keys: jnp.ndarray
    order: jnp.ndarray
    starts: jnp.ndarray
    counts: jnp.ndarray


def _hash_cell(cell: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    # classic 3-prime spatial hash (Teschner et al.)
    p = jnp.asarray([73856093, 19349663, 83492791], jnp.uint32)
    h = (cell[..., 0].astype(jnp.uint32) * p[0]
         ^ cell[..., 1].astype(jnp.uint32) * p[1]
         ^ cell[..., 2].astype(jnp.uint32) * p[2])
    return h % jnp.uint32(n_buckets)


def build_hash_grid(spec: GridSpec, pool: AgentPool, origin, box_size,
                    n_buckets: int = 1 << 14) -> HashGridState:
    cell = morton.cell_of(pool.position, origin, box_size, spec.dims)
    keys = _hash_cell(cell, n_buckets)
    keys = jnp.where(pool.alive, keys, jnp.uint32(n_buckets))
    order = jnp.argsort(keys).astype(jnp.int32)
    sorted_keys = keys[order]
    bucket_ids = jnp.arange(n_buckets, dtype=jnp.uint32)
    starts = jnp.searchsorted(sorted_keys, bucket_ids, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(sorted_keys, bucket_ids, side="right").astype(jnp.int32)
    return HashGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                         keys=keys, order=order, starts=starts, counts=ends - starts)


def hash_grid_candidates(spec: GridSpec, g: HashGridState, query_pos,
                         n_buckets: int = 1 << 14, k_mult: int = 4
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Collisions inflate buckets, so gather capacity is k_mult×max_per_box."""
    k = spec.max_per_box * k_mult
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    h = _hash_cell(ncell_c, n_buckets)
    s = g.starts[h]
    n = jnp.where(inside, g.counts[h], 0)
    lane = jnp.arange(k, dtype=jnp.int32)
    pos = s[..., None] + lane
    valid = lane < jnp.minimum(n, k)[..., None]
    pos = jnp.where(valid, pos, 0)
    ids = g.order[pos]
    q = query_pos.shape[0]
    return ids.reshape(q, 27 * k), valid.reshape(q, 27 * k)
