"""Uniform-grid neighbor search — paper §3.1, adapted sort-based for TPU.

BioDynaMo's grid stores each box's agents in an array-based linked list and
indexes boxes *row-major*; pointer chasing and per-box timestamps are CPU
idioms. The TPU-native formulation (DESIGN.md §2–§3):

  build:  linear (row-major) box key per agent → parallel sort by key →
          per-box (start, count) via one vectorized ``searchsorted`` over the
          dense table of exactly ``prod(dims)`` boxes. O(#agents log #agents)
          fully parallel work and O(#boxes) *vector* memset equivalents — no
          serial O(#boxes) pass, which is what the paper's timestamp trick was
          avoiding (DESIGN.md §2).
  query:  because z is the fastest-varying key axis, the 3×3×3 stencil (paper
          §3.1) collapses into **9 contiguous runs of ≤3 boxes**: 9 range
          lookups and 9 gathers of run width instead of 27 independent K-wide
          gathers. Candidates are gathered from a *pre-sorted* copy of the
          channels, so each run is a contiguous streaming read of the sorted
          pool (DESIGN.md §3).

The agent *memory layout* sort (paper §4.2) remains Morton-ordered
(engine.sort_pool); grid indexing and agent ordering are decoupled.

Alternative environments (paper Fig 11 comparison, DESIGN.md §10.5):
  * BruteForceEnvironment — exact O(N²) masked sweep (small N oracle).
  * ScatterGridEnvironment — 'standard' grid materializing a dense (boxes × K)
    table by scatter; models the cost of touching O(#boxes) memory that the
    paper's timestamp trick addresses.
  * HashGridEnvironment — fixed-bucket spatial hash (collisions filtered by the
    radius mask); models a memory-capped alternative.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import morton
from .agents import AgentPool

# 27 neighbor offsets of the 3x3x3 cube (static python constant) — used by the
# scatter/hash environments, whose tables are not contiguous in z.
_OFFSETS = np.array([(dx, dy, dz)
                     for dx in (-1, 0, 1)
                     for dy in (-1, 0, 1)
                     for dz in (-1, 0, 1)], dtype=np.int32)   # (27, 3)

# 9 xy-offsets of the 3x3x3 cube; each pairs with a contiguous z-run of 3 boxes.
_RUN_OFFSETS = np.array([(dx, dy)
                         for dx in (-1, 0, 1)
                         for dy in (-1, 0, 1)], dtype=np.int32)   # (9, 2)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static grid configuration (hashable; part of the jit cache key)."""
    dims: Tuple[int, int, int]          # boxes per axis
    max_per_box: int = 16               # K: bound on agents in any single box
    query_chunk: int = 2048             # agents per neighbor-apply chunk
    max_per_run: Optional[int] = None   # R: gather capacity per 3-box z-run
                                        # (None → 3·K, the loosest exact bound)

    @property
    def table_size(self) -> int:
        """Exactly prod(dims) — no power-of-two padding (DESIGN.md §3)."""
        return morton.linear_size(self.dims)

    @property
    def run_capacity(self) -> int:
        """R: agents gathered per z-run. A run pools 3 boxes, so occupancy
        concentrates around 3·mean rather than 3·max — callers with measured
        densities may set ``max_per_run`` well below 3·K; the build-time
        ``max_run_count`` check keeps it exact (DESIGN.md §4.2)."""
        return self.max_per_run if self.max_per_run is not None \
            else 3 * self.max_per_box


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GridState:
    """Per-iteration neighbor index (rebuilt every step, paper Algorithm 1 L3-5)."""
    origin: jnp.ndarray        # (3,) float — grid origin (traced: domain may move)
    box_size: jnp.ndarray      # ()   float — box edge = interaction radius
    keys: jnp.ndarray          # (C,) uint32 — linear box key per slot (dead → MAX)
    order: jnp.ndarray         # (C,) int32 — slot ids sorted by key (dead at end)
    rank: jnp.ndarray          # (C,) int32 — inverse of order
    starts: jnp.ndarray        # (M,) int32 — first sorted position of each box
    counts: jnp.ndarray        # (M,) int32 — agents in each box
    max_count: jnp.ndarray     # ()   int32 — max agents in any box
    max_run_count: jnp.ndarray # ()   int32 — max agents in any 3-box z-run
                               #      (the query-exactness bound; overflow iff
                               #       > spec.run_capacity)


_DEAD_KEY = jnp.uint32(0xFFFFFFFF)


def _pcast_varying(v: jnp.ndarray, axes: Tuple[str, ...]) -> jnp.ndarray:
    """jax.lax.pcast(..., to="varying") with a no-op fallback for jax < 0.6
    (older shard_map has no varying-axis tracking to satisfy)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(v, axes, to="varying")
    return v


def build(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
          box_size: jnp.ndarray) -> GridState:
    """Build the grid index. O(#agents) parallel work + one parallel sort."""
    keys = morton.linear_keys(pool.position, origin, box_size, spec.dims)
    keys = jnp.where(pool.alive, keys, _DEAD_KEY)
    order = jnp.argsort(keys).astype(jnp.int32)              # stable radix-ish sort
    sorted_keys = keys[order]
    rank = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=jnp.int32))
    # one searchsorted over M+1 ids gives starts AND counts (ends[i]=starts[i+1];
    # the M'th entry lands at n_live because dead keys sort above every box id)
    box_ids = jnp.arange(spec.table_size + 1, dtype=jnp.uint32)
    bounds = jnp.searchsorted(sorted_keys, box_ids, side="left").astype(jnp.int32)
    starts = bounds[:-1]
    counts = bounds[1:] - bounds[:-1]
    # per z-run occupancy: windowed sum of 3 consecutive-z boxes
    c3 = counts.reshape(spec.dims)
    cp = jnp.pad(c3, ((0, 0), (0, 0), (1, 1)))
    runs = cp[:, :, :-2] + cp[:, :, 1:-1] + cp[:, :, 2:]
    return GridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                     keys=keys, order=order, rank=rank, starts=starts,
                     counts=counts, max_count=jnp.max(counts),
                     max_run_count=jnp.max(runs))


def neighbor_runs(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbors as *sorted-pool positions*, 9 contiguous runs each.

    query_pos: (Q, 3). Returns (pos, valid): (Q, 9·R) int32 positions into the
    key-sorted pool and bool mask. Each of the 9 (dx, dy) stencil columns is
    one contiguous range [starts[k_lo], starts[k_hi]+counts[k_hi]) covering the
    z-run of ≤3 boxes — 9 range lookups instead of 27 per-box lookups, and the
    resulting gathers stream contiguous spans. Candidates are *box-level*;
    callers apply the radius test.
    """
    r_cap = spec.run_capacity
    dims = spec.dims
    cell = morton.cell_of(query_pos, grid.origin, grid.box_size, dims)   # (Q,3)
    off = jnp.asarray(_RUN_OFFSETS)                                      # (9,2)
    nx = cell[:, None, 0] + off[None, :, 0]                              # (Q,9)
    ny = cell[:, None, 1] + off[None, :, 1]
    inside = ((nx >= 0) & (nx < dims[0]) & (ny >= 0) & (ny < dims[1]))
    nx = jnp.clip(nx, 0, dims[0] - 1)
    ny = jnp.clip(ny, 0, dims[1] - 1)
    z_lo = jnp.maximum(cell[:, 2] - 1, 0)[:, None]                       # (Q,1)
    z_hi = jnp.minimum(cell[:, 2] + 1, dims[2] - 1)[:, None]
    k_lo = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_lo, nx.shape), dims)
    k_hi = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_hi, nx.shape), dims)
    s = grid.starts[k_lo]                                                # (Q,9)
    e = grid.starts[k_hi] + grid.counts[k_hi]
    n = jnp.where(inside, e - s, 0)
    lane = jnp.arange(r_cap, dtype=jnp.int32)                            # (R,)
    pos = s[..., None] + lane                                            # (Q,9,R)
    valid = lane < jnp.minimum(n, r_cap)[..., None]
    pos = jnp.where(valid, pos, 0)
    q = query_pos.shape[0]
    return pos.reshape(q, 9 * r_cap), valid.reshape(q, 9 * r_cap)


def neighbor_candidates(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbor *slot ids* for each query position (compat wrapper).

    query_pos: (Q, 3). Returns (ids, valid): (Q, 9·R) int32 slot ids and bool
    mask. Prefer :func:`neighbor_runs` + sorted channels on hot paths — slot
    ids re-randomize the gather order this layout exists to avoid.
    """
    pos, valid = neighbor_runs(spec, grid, query_pos)
    return grid.order[pos], valid


def sort_channels(grid: GridState, channels: Dict[str, jnp.ndarray]
                  ) -> Dict[str, jnp.ndarray]:
    """Channels reordered by grid key — neighbor runs become contiguous reads."""
    return {k: v[grid.order] for k, v in channels.items()}


def chunk_apply(channels: Dict[str, jnp.ndarray],
                gather_channels: Dict[str, jnp.ndarray],
                query_idx: jnp.ndarray,
                n_query: jnp.ndarray,
                cand_fn: Callable[[jnp.ndarray, jnp.ndarray],
                                  Tuple[jnp.ndarray, jnp.ndarray]],
                pair_fn: Callable[[Dict[str, jnp.ndarray],
                                   Dict[str, jnp.ndarray],
                                   jnp.ndarray, jnp.ndarray], Dict[str, jnp.ndarray]],
                out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                chunk: int,
                pvary_axes: Tuple[str, ...] = (),
                ) -> Dict[str, jnp.ndarray]:
    """The one chunked query loop shared by every environment (DESIGN.md §3.4).

    The chunk loop has a *dynamic* trip count ⌈n_query / chunk⌉ — with
    static-region detection on, compute really does shrink with the active set
    (paper §5 / O6; DESIGN.md §2).

    channels: full per-slot SoA dict (what q entries are sliced from).
    gather_channels: dict neighbor candidates are gathered from — the
      key-sorted copy for the uniform grid (contiguous runs), the raw slot
      view for scatter/hash/brute environments.
    query_idx: (C,) int32 — compacted active slots (tail padded, see
      compaction.active_index_list); n_query: traced count.
    cand_fn(q_pos, q_slot) -> (idx, valid): candidate indices *into
      gather_channels* and validity (self-exclusion included).
    pair_fn(q, nbr, valid, q_slot) -> dict of per-query reductions; q entries
      are (B, ...) chunk slices, nbr entries are (B, W, ...) gathers, valid is
      (B, W) bool, q_slot is (B,) the query slot ids.
    out_specs: name → (shape_suffix, dtype) of per-agent outputs; results are
      scattered back to slot positions, zeros elsewhere.
    """
    c = channels["position"].shape[0]
    b = min(chunk, c)
    n_chunks_max = (c + b - 1) // b
    # pad so dynamic_slice never clamps (clamping would desync q_slot vs lane_ok)
    qi = jnp.pad(query_idx, (0, n_chunks_max * b - c))
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {k: _pcast_varying(v, pvary_axes) for k, v in outs.items()}

    def body(i, outs):
        sl = i * b
        q_slot = jax.lax.dynamic_slice(qi, (sl,), (b,))                     # (B,)
        lane_ok = (sl + jnp.arange(b)) < n_query                            # (B,)
        q = {k: v[q_slot] for k, v in channels.items()}
        idx, valid = cand_fn(q["position"], q_slot)
        valid &= lane_ok[:, None]
        nbr = {k: v[idx] for k, v in gather_channels.items()}
        res = pair_fn(q, nbr, valid, q_slot)
        new_outs = {}
        for name, val in res.items():
            val = jnp.where(
                lane_ok.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = outs[name].at[q_slot].add(val.astype(outs[name].dtype),
                                                       mode="drop")
        for name in outs:
            if name not in res:
                new_outs[name] = outs[name]
        return new_outs

    n_chunks = jnp.minimum((n_query + b - 1) // b, n_chunks_max)
    return jax.lax.fori_loop(0, n_chunks, body, outs)


def neighbor_apply(spec: GridSpec,
                   grid: GridState,
                   channels: Dict[str, jnp.ndarray],
                   query_idx: jnp.ndarray,
                   n_query: jnp.ndarray,
                   pair_fn: Callable,
                   out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                   pvary_axes: Tuple[str, ...] = (),
                   ) -> Dict[str, jnp.ndarray]:
    """Apply ``pair_fn`` over each query agent's run candidates, chunked.

    Sorts the channels once (the runs then gather contiguous spans) and
    resolves candidates inline per chunk. For several consumers per grid build,
    use :func:`build_candidates` + :func:`candidates_apply` instead — the
    engine shares one candidate list across forces, behaviors and statics.
    """
    sorted_ch = sort_channels(grid, channels)

    def cand_fn(q_pos, q_slot):
        pos, valid = neighbor_runs(spec, grid, q_pos)
        valid &= pos != grid.rank[q_slot][:, None]          # exclude self
        return pos, valid

    return chunk_apply(channels, sorted_ch, query_idx, n_query, cand_fn,
                       pair_fn, out_specs, spec.query_chunk, pvary_axes)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NeighborCandidates:
    """Per-step cached candidate pipeline (DESIGN.md §3.4).

    Built once per grid build and shared by every neighbor consumer of the
    step (force sweep, behaviors, static-flag update) — cells, keys and range
    lookups are resolved exactly once per iteration.
    """
    pos: jnp.ndarray                          # (C, 9·R) int32 sorted-pool positions
    valid: jnp.ndarray                        # (C, 9·R) bool (self excluded)
    sorted_channels: Dict[str, jnp.ndarray]   # channels in grid-key order


def build_candidates(spec: GridSpec, grid: GridState,
                     channels: Dict[str, jnp.ndarray]) -> NeighborCandidates:
    """Resolve every slot's candidate runs once (vectorized, no chunking)."""
    pos, valid = neighbor_runs(spec, grid, channels["position"])
    valid &= pos != grid.rank[:, None]                      # exclude self
    return NeighborCandidates(pos=pos, valid=valid,
                              sorted_channels=sort_channels(grid, channels))


def candidates_apply(spec: GridSpec,
                     cand: NeighborCandidates,
                     channels: Dict[str, jnp.ndarray],
                     query_idx: jnp.ndarray,
                     n_query: jnp.ndarray,
                     pair_fn: Callable,
                     out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                     pvary_axes: Tuple[str, ...] = (),
                     ) -> Dict[str, jnp.ndarray]:
    """``neighbor_apply`` over a pre-built shared candidate list."""
    def cand_fn(q_pos, q_slot):
        return cand.pos[q_slot], cand.valid[q_slot]

    return chunk_apply(channels, cand.sorted_channels, query_idx, n_query,
                       cand_fn, pair_fn, out_specs, spec.query_chunk,
                       pvary_axes)


# ---------------------------------------------------------------------------
# Alternative environments (Fig 11 comparison)
# ---------------------------------------------------------------------------

def brute_force_apply(channels: Dict[str, jnp.ndarray],
                      alive: jnp.ndarray,
                      pair_fn,
                      out_specs,
                      chunk: int = 512) -> Dict[str, jnp.ndarray]:
    """Exact O(N²) neighbor apply (oracle + Fig-11 baseline).

    pair_fn has the same signature as in neighbor_apply; candidates are *all*
    agents (``valid`` carries alive & not-self; the radius test is pair_fn's
    own distance mask, identical to the grid path).
    """
    c = channels["position"].shape[0]
    chunk = min(chunk, c)
    ids = jnp.arange(c, dtype=jnp.int32)

    def cand_fn(q_pos, q_slot):
        b = q_slot.shape[0]
        idx = jnp.broadcast_to(ids[None], (b, c))
        valid = alive[None, :] & (idx != q_slot[:, None])
        return idx, valid

    q_idx = jnp.arange(c, dtype=jnp.int32)
    return chunk_apply(channels, channels, q_idx, jnp.int32(c), cand_fn,
                       pair_fn, out_specs, chunk)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScatterGridState:
    """'Standard implementation' grid: dense (boxes × K) member table via scatter.

    Models BioDynaMo's *unoptimized* path: the table is re-zeroed and re-scattered
    every iteration, touching O(#boxes · K) memory — the cost the paper's
    timestamp trick (and our sort-based build) avoids.
    """
    origin: jnp.ndarray
    box_size: jnp.ndarray
    table: jnp.ndarray         # (M, K) int32 slot ids, -1 = empty
    counts: jnp.ndarray        # (M,)


def build_scatter_grid(spec: GridSpec, pool: AgentPool, origin, box_size
                       ) -> ScatterGridState:
    m, k = spec.table_size, spec.max_per_box
    keys = morton.linear_keys(pool.position, origin, box_size, spec.dims)
    keys = jnp.where(pool.alive, keys, m)  # park dead at row m (dropped)
    # slot-within-box via sort (the CPU version uses sequential insertion;
    # the data-parallel equivalent needs a sort or atomics — we sort).
    order = jnp.argsort(keys)
    sorted_keys = keys[order]
    first = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    slot_in_box = jnp.arange(keys.shape[0]) - first                  # rank within box
    table = jnp.full((m + 1, k), -1, jnp.int32)
    sk = jnp.minimum(slot_in_box, k - 1)
    table = table.at[sorted_keys.astype(jnp.int32), sk].set(order.astype(jnp.int32),
                                                            mode="drop")
    counts = jnp.zeros((m + 1,), jnp.int32).at[keys.astype(jnp.int32)].add(
        pool.alive.astype(jnp.int32), mode="drop")
    return ScatterGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                            table=table[:m], counts=counts[:m])


def scatter_grid_candidates(spec: GridSpec, g: ScatterGridState, query_pos
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = spec.max_per_box
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    codes = morton.linear_encode3(ncell_c[..., 0], ncell_c[..., 1],
                                  ncell_c[..., 2], spec.dims).astype(jnp.int32)
    members = g.table[codes]                                      # (Q,27,K)
    valid = (members >= 0) & inside[..., None]
    q = query_pos.shape[0]
    return jnp.maximum(members, 0).reshape(q, 27 * k), valid.reshape(q, 27 * k)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HashGridState:
    """Spatial-hash grid with a fixed bucket table (memory-capped alternative)."""
    origin: jnp.ndarray
    box_size: jnp.ndarray
    keys: jnp.ndarray
    order: jnp.ndarray
    starts: jnp.ndarray
    counts: jnp.ndarray


def _hash_cell(cell: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    # classic 3-prime spatial hash (Teschner et al.)
    p = jnp.asarray([73856093, 19349663, 83492791], jnp.uint32)
    h = (cell[..., 0].astype(jnp.uint32) * p[0]
         ^ cell[..., 1].astype(jnp.uint32) * p[1]
         ^ cell[..., 2].astype(jnp.uint32) * p[2])
    return h % jnp.uint32(n_buckets)


def build_hash_grid(spec: GridSpec, pool: AgentPool, origin, box_size,
                    n_buckets: int = 1 << 14) -> HashGridState:
    cell = morton.cell_of(pool.position, origin, box_size, spec.dims)
    keys = _hash_cell(cell, n_buckets)
    keys = jnp.where(pool.alive, keys, jnp.uint32(n_buckets))
    order = jnp.argsort(keys).astype(jnp.int32)
    sorted_keys = keys[order]
    bucket_ids = jnp.arange(n_buckets, dtype=jnp.uint32)
    starts = jnp.searchsorted(sorted_keys, bucket_ids, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(sorted_keys, bucket_ids, side="right").astype(jnp.int32)
    return HashGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                         keys=keys, order=order, starts=starts, counts=ends - starts)


def hash_grid_candidates(spec: GridSpec, g: HashGridState, query_pos,
                         n_buckets: int = 1 << 14, k_mult: int = 4
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Collisions inflate buckets, so gather capacity is k_mult×max_per_box."""
    k = spec.max_per_box * k_mult
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    h = _hash_cell(ncell_c, n_buckets)
    s = g.starts[h]
    n = jnp.where(inside, g.counts[h], 0)
    lane = jnp.arange(k, dtype=jnp.int32)
    pos = s[..., None] + lane
    valid = lane < jnp.minimum(n, k)[..., None]
    pos = jnp.where(valid, pos, 0)
    ids = g.order[pos]
    q = query_pos.shape[0]
    return ids.reshape(q, 27 * k), valid.reshape(q, 27 * k)
