"""Uniform-grid neighbor search — paper §3.1, adapted sort-based for TPU.

BioDynaMo's grid stores each box's agents in an array-based linked list and
indexes boxes *row-major*; pointer chasing and per-box timestamps are CPU
idioms. The TPU-native formulation (DESIGN.md §2–§3):

  build:  linear (row-major) box key per agent → parallel sort by key →
          per-box (start, count) via one vectorized ``searchsorted`` over the
          dense table of exactly ``prod(dims)`` boxes. O(#agents log #agents)
          fully parallel work and O(#boxes) *vector* memset equivalents — no
          serial O(#boxes) pass, which is what the paper's timestamp trick was
          avoiding (DESIGN.md §2).
  query:  because z is the fastest-varying key axis, the 3×3×3 stencil (paper
          §3.1) collapses into **9 contiguous runs of ≤3 boxes**: 9 range
          lookups per query instead of 27 per-box lookups, and each run is a
          contiguous streaming read of the grid-ordered pool (DESIGN.md §3).

**Resident layout (DESIGN.md §3.2):** :func:`build_resident` applies the key
sort's permutation to the pool itself, so grid-key order *is* the memory
layout: no per-step sorted copy of the channels, query chunks are contiguous
slices, the paper's periodic Morton sort (§4.2) is subsumed (agents in the
same box are adjacent in memory every step), and — because dead slots carry
the maximum key — the same permutation is the §3.2 death compaction.
:func:`resident_apply` then *streams* the 9 z-runs through the pairwise
reduction one at a time (peak candidate footprint B×R instead of B×9R) and
skips fully-inactive query blocks outright via a dynamic trip count (paper §5
static regions at block granularity).

Alternative environments (paper Fig 11 comparison, DESIGN.md §11.5):
  * BruteForceEnvironment — exact O(N²) masked sweep (small N oracle).
  * ScatterGridEnvironment — 'standard' grid materializing a dense (boxes × K)
    table by scatter; models the cost of touching O(#boxes) memory that the
    paper's timestamp trick addresses.
  * HashGridEnvironment — fixed-bucket spatial hash (collisions filtered by the
    radius mask); models a memory-capped alternative. Its 27 probes stream
    through :func:`phased_chunk_apply` — same accumulation loop as the
    resident path, width K_hash per phase instead of 27·K_hash at once.
"""

from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compaction, morton
from .agents import AgentPool

# 27 neighbor offsets of the 3x3x3 cube (static python constant) — used by the
# scatter/hash environments, whose tables are not contiguous in z.
_OFFSETS = np.array([(dx, dy, dz)
                     for dx in (-1, 0, 1)
                     for dy in (-1, 0, 1)
                     for dz in (-1, 0, 1)], dtype=np.int32)   # (27, 3)

# 9 xy-offsets of the 3x3x3 cube; each pairs with a contiguous z-run of 3 boxes.
_RUN_OFFSETS = np.array([(dx, dy)
                         for dx in (-1, 0, 1)
                         for dy in (-1, 0, 1)], dtype=np.int32)   # (9, 2)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static grid configuration (hashable; part of the jit cache key)."""
    dims: Tuple[int, int, int]          # boxes per axis
    max_per_box: int = 16               # K: bound on agents in any single box
    query_chunk: int = 2048             # agents per neighbor-apply chunk
    max_per_run: Optional[int] = None   # R: gather capacity per 3-box z-run
                                        # (None → 3·K, the loosest exact bound)

    @property
    def table_size(self) -> int:
        """Exactly prod(dims) — no power-of-two padding (DESIGN.md §3)."""
        return morton.linear_size(self.dims)

    @property
    def run_capacity(self) -> int:
        """R: agents gathered per z-run. A run pools 3 boxes, so occupancy
        concentrates around 3·mean rather than 3·max — callers with measured
        densities may set ``max_per_run`` well below 3·K; the build-time
        ``max_run_count`` check keeps it exact (DESIGN.md §4.2)."""
        return self.max_per_run if self.max_per_run is not None \
            else 3 * self.max_per_box


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GridState:
    """Per-iteration neighbor index (rebuilt every step, paper Algorithm 1 L3-5)."""
    origin: jnp.ndarray        # (3,) float — grid origin (traced: domain may move)
    box_size: jnp.ndarray      # ()   float — box edge = interaction radius
    keys: jnp.ndarray          # (C,) uint32 — linear box key per slot (dead → MAX)
    order: jnp.ndarray         # (C,) int32 — slot ids sorted by key (dead at end)
    rank: jnp.ndarray          # (C,) int32 — inverse of order
    starts: jnp.ndarray        # (M,) int32 — first sorted position of each box
    counts: jnp.ndarray        # (M,) table_count_dtype(capacity): int16 when
                               #      the pool fits int16, else int32 —
                               #      values bounded by capacity (§4.3)
    max_count: jnp.ndarray     # ()   int32 — max agents in any box
    max_run_count: jnp.ndarray # ()   int32 — max agents in any 3-box z-run
                               #      (the query-exactness bound; overflow iff
                               #       > spec.run_capacity)


_DEAD_KEY = morton.DEAD_KEY


# ---------------------------------------------------------------------------
# O(N) counting-sort permutation (DESIGN.md §2) — the grid build's key sort
# ---------------------------------------------------------------------------
#
# Box keys live in [0, table_size] (the sentinel table_size stands in for
# DEAD_KEY), so a comparison sort is overkill: a counting sort — histogram the
# keys into the exact-size table, exclusive-scan the histogram into per-key
# offsets, scatter each slot to offset[key] + rank-within-key — produces the
# same stable permutation in O(N + table_size) work. Ties break by slot id,
# which makes the result *bit-exact* with jnp.argsort (stable): a stable sort
# permutation is uniquely determined by its keys, so every downstream
# guarantee that was stated over argsort (ladder-rewind bit-exactness,
# distributed parity) carries over unchanged.
#
# Two realizations, selected by ``impl``:
#   * "xla" — an in-graph LSD radix cascade: each pass histograms one
#     _DIGIT_BITS-wide digit per 1024-slot block (rank-within-digit via the
#     block-sorted segment boundaries), exclusive-scans block histograms into
#     global offsets, and applies the pass with ONE length-N scatter. Valid
#     under jit, lax.cond, and shard_map; portable to accelerators.
#   * "host" — jax.pure_callback into numpy's stable integer argsort (an LSD
#     radix cascade on these dtypes, ~3.7× faster than jnp.argsort at 16M
#     keys). OPT-IN ONLY: on jaxlib 0.4.37's CPU runtime, converting a
#     *computed* callback operand to numpy deadlocks once the copy leaves
#     the inline path (≥ ~32k elements — np.asarray/dlpack/memoryview all
#     block the same way), so the engine must never select it implicitly.
# "auto" picks "xla" everywhere; "argsort" keeps the comparison sort (oracle
# for the parity tests — measured on-par with "xla" on a CPU host, where
# XLA's variadic sort and the radix cascade are both ~3× slower than
# numpy's radix; the per-step build win comes from RebuildPolicy skipping,
# not the sort constant).

SORT_IMPLS = ("auto", "host", "xla", "argsort")

_LANE_BITS = 10
_SORT_BLOCK = 1 << _LANE_BITS        # slots per radix block (one sort row)
_DIGIT_BITS = 11                     # digit width per counting-sort pass


def _np_stable_argsort(keys: np.ndarray) -> np.ndarray:
    # pure_callback hands us a jax.Array view, not an ndarray; materialize it
    # BEFORE sorting or np.argsort's method dispatch re-enters jnp.argsort on
    # the callback thread and deadlocks the runtime once the sort is large
    # enough to leave the inline execution path
    return np.argsort(np.asarray(keys), kind="stable").astype(np.int32)


# jax ≥ 0.5 replaces pure_callback's ``vectorized`` kwarg with ``vmap_method``
_CALLBACK_KW = (
    {"vmap_method": "sequential"}
    if "vmap_method" in inspect.signature(jax.pure_callback).parameters
    else {"vectorized": False})


def _counting_sort_host(keys: jnp.ndarray) -> jnp.ndarray:
    return jax.pure_callback(
        _np_stable_argsort,
        jax.ShapeDtypeStruct(keys.shape, jnp.int32), keys, **_CALLBACK_KW)


def _radix_pass(vals: jnp.ndarray, order: jnp.ndarray, shift: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One stable counting-sort pass on digit ``(vals >> shift) & (D-1)``.

    vals/order are block-padded to a multiple of _SORT_BLOCK. Packing
    (digit << _LANE_BITS) | lane and value-sorting each block row yields the
    per-block stable digit order without an argsort/take_along_axis pair;
    rank-within-digit falls out of the sorted block's segment boundaries
    (one searchsorted per block — the "segment cumsum" of the counting
    sort), and the cross-block exclusive scan of the per-block histograms
    turns local ranks into global destinations. The pass is applied with a
    single length-N scatter of the inverse permutation.
    """
    n = vals.shape[0]
    nb = n // _SORT_BLOCK
    d = 1 << _DIGIT_BITS
    lane = jnp.arange(_SORT_BLOCK, dtype=jnp.uint32)
    digits = ((vals >> shift) & jnp.uint32(d - 1)).reshape(nb, _SORT_BLOCK)
    packed = jnp.sort((digits << _LANE_BITS) | lane[None, :], axis=1)
    d_sorted = (packed >> _LANE_BITS).astype(jnp.int32)           # (nb, B)
    lane_src = (packed & jnp.uint32(_SORT_BLOCK - 1)).astype(jnp.int32)

    ids = jnp.arange(d + 1, dtype=jnp.int32)
    bounds = jax.vmap(lambda row: jnp.searchsorted(row, ids))(d_sorted)
    counts_b = bounds[:, 1:] - bounds[:, :-1]                     # (nb, D)
    off_d = jnp.concatenate([jnp.zeros((1,), counts_b.dtype),
                             jnp.cumsum(counts_b.sum(axis=0))[:-1]])
    cross = jnp.cumsum(counts_b, axis=0) - counts_b               # excl. scan

    rows = jnp.arange(nb, dtype=jnp.int32)[:, None]
    j = jnp.arange(_SORT_BLOCK, dtype=jnp.int32)[None, :]
    local = j - bounds[rows, d_sorted]                            # rank in digit
    dst = (off_d[d_sorted] + cross[rows, d_sorted] + local).reshape(-1)
    src = (rows * _SORT_BLOCK + lane_src).reshape(-1)
    inv = jnp.zeros((n,), jnp.int32).at[dst].set(
        src, unique_indices=True, mode="promise_in_bounds")
    return vals[inv], order[inv]


def _counting_sort_xla(keys: jnp.ndarray, table_size: int) -> jnp.ndarray:
    c = keys.shape[0]
    nb = -(-c // _SORT_BLOCK)
    kp = jnp.pad(keys, (0, nb * _SORT_BLOCK - c), constant_values=_DEAD_KEY)
    # dead (and pad) keys → the sentinel table_size: the key domain becomes
    # [0, table_size], so bit_length(table_size) digits cover every pass. The
    # remap is monotone, and pad slots tie-break after every real slot, so
    # order[:c] is exactly the stable permutation of the original keys.
    ki = jnp.where(kp == _DEAD_KEY, jnp.uint32(table_size), kp)
    order = jnp.arange(nb * _SORT_BLOCK, dtype=jnp.int32)
    for shift in range(0, max(1, int(table_size).bit_length()), _DIGIT_BITS):
        ki, order = _radix_pass(ki, order, shift)
    return order[:c]


def counting_sort_order(keys: jnp.ndarray, table_size: int, *,
                        impl: str = "auto") -> jnp.ndarray:
    """Stable sort permutation of box keys — bit-exact with ``jnp.argsort``.

    keys: (C,) uint32 in [0, table_size] ∪ {morton.DEAD_KEY}. Returns (C,)
    int32 slot ids in ascending (key, slot) order — the unique stable
    permutation, whichever ``impl`` computes it (see SORT_IMPLS above).
    """
    if impl == "auto":
        impl = "xla"          # "host" is opt-in only (deadlock note above)
    if impl == "argsort":
        return jnp.argsort(keys).astype(jnp.int32)
    if impl == "host":
        return _counting_sort_host(keys)
    if impl == "xla":
        return _counting_sort_xla(keys, table_size)
    raise ValueError(f"sort_impl must be one of {SORT_IMPLS}, got {impl!r}")


# ---------------------------------------------------------------------------
# Rebuild policy (DESIGN.md §4) — when the per-step build may be skipped
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RebuildPolicy:
    """When the environment build runs (static; part of the jit closure).

    mode="every_step" (default): rebuild every iteration — the exact paper
    Algorithm-1 schedule, byte-identical to the engine before this knob
    existed.

    mode="every_k": reuse the previous build for up to ``k - 1`` further
    steps, as long as the accumulated per-agent displacement stays within
    ``displacement_bound``. Correctness (DESIGN.md §4.4): grid cells widen to
    ``interaction_radius + displacement_bound``, so for any current-position
    pair within the interaction radius r, the neighbor's *stale* cell (its
    cell at build time) is within one cell of the query's current cell —
    per axis |x_now(q) − x_build(n)| ≤ |x_now(q) − x_now(n)| +
    |x_now(n) − x_build(n)| ≤ r + bound = cell — hence inside the 3×3×3
    stencil. Stale candidates are a superset; pair forces read *current*
    channel values, so extra candidates beyond r contribute exactly zero.
    Any structural change (death compaction, birth commit, migration,
    arriving ghosts) marks the cached build dirty and forces a rebuild on
    the next step, so stale tables never index a reordered pool.
    """
    mode: str = "every_step"          # "every_step" | "every_k"
    k: int = 1                        # max steps served by one build
    displacement_bound: float = 0.0   # accumulated-displacement budget

    def __post_init__(self):
        if self.mode not in ("every_step", "every_k"):
            raise ValueError(
                f"rebuild.mode must be 'every_step' or 'every_k', "
                f"got {self.mode!r}")
        if not isinstance(self.k, int) or self.k < 1:
            raise ValueError(f"rebuild.k must be an int ≥ 1, got {self.k!r}")
        if self.displacement_bound < 0:
            raise ValueError(f"rebuild.displacement_bound must be ≥ 0, "
                             f"got {self.displacement_bound!r}")
        if self.mode == "every_step" and (self.k != 1
                                          or self.displacement_bound != 0.0):
            raise ValueError(
                "rebuild.k and rebuild.displacement_bound only apply under "
                "rebuild.mode='every_k' (every_step rebuilds unconditionally)")

    @property
    def cell_slack(self) -> float:
        """Extra grid-cell width the stale-build coverage argument needs."""
        return float(self.displacement_bound) if self.mode == "every_k" else 0.0


@dataclasses.dataclass(frozen=True)
class PairListConfig:
    """Static Verlet pair-list configuration (hashable; part of the jit key).

    skin:      extra filter radius beyond the interaction radius. The list is
               built at ``r + skin`` and stays a superset of every in-range
               pair while each agent's accumulated euclidean displacement
               since the build is ≤ ``skin/2`` (triangle inequality: two
               agents approaching head-on close the gap by at most
               2·(skin/2) = skin). skin=0 ⇒ the list is exact only for the
               build step, so it pairs with every-step rebuilds.
    max_pairs: P — fixed per-agent width of the index table. Demand above P
               flags ``pair_overflow`` in StepStats (never silent; the
               capacity ladder grows this rung with bit-identical rewind).
    """
    skin: float = 0.0
    max_pairs: int = 32

    def __post_init__(self):
        if self.skin < 0:
            raise ValueError(f"pairlist.skin must be ≥ 0, got {self.skin!r}")
        if not isinstance(self.max_pairs, int) or self.max_pairs < 1:
            raise ValueError(f"pairlist.max_pairs must be an int ≥ 1, "
                             f"got {self.max_pairs!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PairList:
    """Compacted per-agent candidate table (Verlet list, DESIGN.md §3.4).

    Built once per grid rebuild by :func:`build_pairlist` from the same
    streamed 3×3×3 candidate runs the fused sweep consumes, keeping only
    candidates within ``radius`` (= r + skin). Row order inside the table is
    run-major, lane-minor — exactly the order the streamed sweep accumulates
    — and ``run_off`` keeps the 9 per-run segment boundaries, so
    :func:`resident_apply_fused` can replay the identical two-level
    (per-run, then across-run) accumulation over the pruned set (dropped
    candidates contribute exact zeros; see the parity caveat there — float
    sums can still wiggle by ~1 ulp because XLA's lane reduction is
    lane-position sensitive).

    idx:     (C, P) int32 — sorted-pool candidate positions, row-packed
    run_off: (C, 10) int32 — cumulative per-run segment offsets into idx
             (off[:, 0] = 0, off[:, 9] = per-row stored count), capped at P
    count:   (C,) int32 — UNCAPPED per-row demand (provenance for the ladder)
    demand:  () int32 — max over rows of ``count``; overflow ⇔ demand > P
    """
    idx: jnp.ndarray
    run_off: jnp.ndarray
    count: jnp.ndarray
    demand: jnp.ndarray


def initial_pairlist(capacity: int, max_pairs: int) -> PairList:
    """Zero tables — what a fresh build writes for rows it never visits."""
    return PairList(idx=jnp.zeros((capacity, max_pairs), jnp.int32),
                    run_off=jnp.zeros((capacity, 10), jnp.int32),
                    count=jnp.zeros((capacity,), jnp.int32),
                    demand=jnp.zeros((), jnp.int32))


def grow_pairlist(pairs: PairList, new_capacity: int, new_max_pairs: int
                  ) -> PairList:
    """Grow a cached PairList to a larger pool capacity and/or table width.

    Ladder-rewind counterpart of :func:`grow_grid_state`: zero row/column
    padding is exactly what a pre-sized build would have written (new rows
    were never visited; columns past a row's count are never written — a
    cached list that *overflowed* is never carried, because the ladder
    rewinds the overflowing step before its post-state is kept, so the
    capped ``run_off`` never actually engaged). Supports a leading shard
    axis (distributed ladder: arrays (S, C, ...)).
    """
    old_c = pairs.count.shape[-1]
    old_p = pairs.idx.shape[-1]
    if new_capacity < old_c or new_max_pairs < old_p:
        raise ValueError(f"grow_pairlist: ({new_capacity}, {new_max_pairs}) "
                         f"< ({old_c}, {old_p})")
    if new_capacity == old_c and new_max_pairs == old_p:
        return pairs
    lead = len(pairs.count.shape) - 1
    row_pad = [(0, 0)] * lead + [(0, new_capacity - old_c)]
    return PairList(
        idx=jnp.pad(pairs.idx, row_pad + [(0, new_max_pairs - old_p)]),
        run_off=jnp.pad(pairs.run_off, row_pad + [(0, 0)]),
        count=jnp.pad(pairs.count, row_pad),
        demand=pairs.demand)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RebuildState:
    """Carried environment cache for RebuildPolicy(mode='every_k').

    grid:        the last build's GridState (tables index the pool layout as
                 of that build; the skip invariants above keep it valid)
    steps_since: () int32 — steps served by ``grid`` so far
    disp_accum:  () float32 — accumulated max per-agent per-axis |Δposition|
                 since the build (the displacement-bound budget spent)
    dirty:       () bool — a structural change invalidated ``grid``
    pairs:       cached PairList built alongside ``grid`` (None when the
                 pair-list stage is disabled — no pytree leaves, so old
                 checkpoints and sharding specs are unchanged)
    pair_disp:   () float32 — accumulated max per-agent EUCLIDEAN ‖Δposition‖
                 since the build. Separate from ``disp_accum`` (a per-axis
                 max, which does NOT bound the euclidean motion the skin
                 argument needs); list reuse requires 2·pair_disp ≤ skin.
    """
    grid: GridState
    steps_since: jnp.ndarray
    disp_accum: jnp.ndarray
    dirty: jnp.ndarray
    pairs: Optional[PairList] = None
    pair_disp: Optional[jnp.ndarray] = None


def initial_rebuild_state(spec: GridSpec, capacity: int, origin, box_size,
                          pairlist: Optional[PairListConfig] = None
                          ) -> RebuildState:
    """Pre-first-step cache: empty tables, dirty so step 0 always builds."""
    ident = jnp.arange(capacity, dtype=jnp.int32)
    cdt = table_count_dtype(capacity)    # max_* follow counts' dtype (§4.3)
    grid = GridState(
        origin=jnp.asarray(origin, jnp.float32),
        box_size=jnp.asarray(box_size, jnp.float32),
        keys=jnp.full((capacity,), _DEAD_KEY, jnp.uint32),
        order=ident, rank=ident,
        starts=jnp.zeros((spec.table_size,), jnp.int32),
        counts=jnp.zeros((spec.table_size,), cdt),
        max_count=jnp.zeros((), cdt),
        max_run_count=jnp.zeros((), cdt))
    pairs = pair_disp = None
    if pairlist is not None:
        pairs = initial_pairlist(capacity, pairlist.max_pairs)
        pair_disp = jnp.zeros((), jnp.float32)
    return RebuildState(grid=grid,
                        steps_since=jnp.zeros((), jnp.int32),
                        disp_accum=jnp.zeros((), jnp.float32),
                        dirty=jnp.ones((), bool),
                        pairs=pairs, pair_disp=pair_disp)


def grow_grid_state(grid: GridState, new_capacity: int) -> GridState:
    """Grow a cached *resident* GridState to a larger pool capacity.

    Used by the capacity-ladder rewind (host side): the pre-step state being
    re-run at the bigger rung carries this cache, and a pre-sized run at the
    new capacity would have produced exactly these arrays — dead-key padding
    keeps ``keys`` sorted, the identity order/rank extend with iota, and the
    dense tables are capacity-independent (counts only re-cast when the
    capacity crosses the int16 table dtype threshold). That is what keeps
    grown trajectories bit-identical to pre-sized ones under every_k.
    Supports a leading shard axis (distributed ladder: arrays (S, C...)).
    """
    old = grid.keys.shape[-1]
    if new_capacity == old:
        return grid
    if new_capacity < old:
        raise ValueError(f"grow_grid_state: {new_capacity} < {old}")
    pad = new_capacity - old
    lead = grid.keys.shape[:-1]
    ident_pad = jnp.broadcast_to(
        jnp.arange(old, new_capacity, dtype=jnp.int32), lead + (pad,))
    pad_widths = [(0, 0)] * len(lead) + [(0, pad)]
    cdt = table_count_dtype(new_capacity)
    return dataclasses.replace(
        grid,
        keys=jnp.pad(grid.keys, pad_widths, constant_values=_DEAD_KEY),
        order=jnp.concatenate([grid.order, ident_pad], axis=-1),
        rank=jnp.concatenate([grid.rank, ident_pad], axis=-1),
        counts=grid.counts.astype(cdt),
        max_count=grid.max_count.astype(cdt),
        max_run_count=grid.max_run_count.astype(cdt))


def _pcast_varying(v: jnp.ndarray, axes: Tuple[str, ...]) -> jnp.ndarray:
    """jax.lax.pcast(..., to="varying") with a no-op fallback for jax < 0.6
    (older shard_map has no varying-axis tracking to satisfy)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(v, axes, to="varying")
    return v


def table_count_dtype(capacity: int) -> jnp.dtype:
    """Dtype of per-box/per-bucket occupancy tables, capacity-parameterized.

    A box can hold at most ``capacity`` agents, so counts fit int16 whenever
    the pool does — halving the (M,)-table footprint at small ladder rungs
    (DESIGN.md §4.3). Sums of ≤3 counts (z-runs) are equally bounded by
    ``capacity`` and stay in range. Starts always need int32 (values up to
    capacity *positions*, but also used as table offsets up to M)."""
    return jnp.dtype(jnp.int16 if capacity < 2 ** 15 else jnp.int32)


def box_tables(sorted_keys: jnp.ndarray, table_size: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense per-box (starts, counts) from the key-sorted keys.

    One searchsorted over M+1 ids gives starts AND counts (ends[i]=starts[i+1];
    the M'th entry lands at n_live because dead keys sort above every box id).
    Shared with the kernel compat wrapper (kernels/ops.collision_force) so the
    table derivation exists exactly once. Counts use the capacity-
    parameterized :func:`table_count_dtype`.
    """
    box_ids = jnp.arange(table_size + 1, dtype=jnp.uint32)
    bounds = jnp.searchsorted(sorted_keys, box_ids, side="left").astype(jnp.int32)
    counts = (bounds[1:] - bounds[:-1]).astype(
        table_count_dtype(sorted_keys.shape[0]))
    return bounds[:-1], counts


def _index_tables(spec: GridSpec, sorted_keys: jnp.ndarray):
    """(starts, counts, max_count, max_run_count) from the key-sorted keys."""
    starts, counts = box_tables(sorted_keys, spec.table_size)
    # per z-run occupancy: windowed sum of 3 consecutive-z boxes
    c3 = counts.reshape(spec.dims)
    cp = jnp.pad(c3, ((0, 0), (0, 0), (1, 1)))
    runs = cp[:, :, :-2] + cp[:, :, 1:-1] + cp[:, :, 2:]
    return starts, counts, jnp.max(counts), jnp.max(runs)


def _build_sorted_impl(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
                       box_size: jnp.ndarray, sort_impl: str = "auto"
                       ) -> GridState:
    """Build the grid index over the pool *as laid out* (non-resident).

    O(#agents) counting sort + O(#boxes) vector table derivation. Queries
    against this state gather from a key-sorted channel copy
    (``sort_channels``); the engine's hot path uses the resident build
    instead, which makes that copy the pool itself. Kept for callers that
    must preserve slot order (the Fig-11 baselines).
    """
    keys = morton.grid_sort_keys(pool.position, pool.alive, origin, box_size,
                                 spec.dims)
    order = counting_sort_order(keys, spec.table_size, impl=sort_impl)
    sorted_keys = keys[order]
    rank = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=jnp.int32))
    starts, counts, max_count, max_run = _index_tables(spec, sorted_keys)
    return GridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                     keys=keys, order=order, rank=rank, starts=starts,
                     counts=counts, max_count=max_count, max_run_count=max_run)


def _build_resident_impl(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
                         box_size: jnp.ndarray, sort_impl: str = "auto"
                         ) -> Tuple[AgentPool, GridState, jnp.ndarray]:
    """Permute the pool into grid-key order and index it **in place**.

    The one permutation (DESIGN.md §3.2) composes three reorderings the
    engine used to perform separately:
      * the grid build's key sort (agents of a box are adjacent),
      * the paper's §4.2 memory-layout sort (boxes are adjacent row-major —
        the periodic Morton sort becomes a no-op special case), and
      * §3.2 death compaction (dead slots carry ``morton.DEAD_KEY`` and sink
        stably to the tail, so live agents occupy ``[0, n_live)``).

    Returns (pool, grid, order) with ``pool`` reordered, ``grid.order``/
    ``grid.rank`` the identity (sorted position == slot id), ``grid.keys``
    already sorted, and ``order`` the applied old→new gather permutation
    (callers tracking external per-slot state re-map with it).
    """
    keys = morton.grid_sort_keys(pool.position, pool.alive, origin, box_size,
                                 spec.dims)
    order = counting_sort_order(keys, spec.table_size, impl=sort_impl)
    pool = compaction.apply_permutation(pool, order)
    sorted_keys = keys[order]
    starts, counts, max_count, max_run = _index_tables(spec, sorted_keys)
    ident = jnp.arange(order.shape[0], dtype=jnp.int32)
    grid = GridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                     keys=sorted_keys, order=ident, rank=ident, starts=starts,
                     counts=counts, max_count=max_count, max_run_count=max_run)
    return pool, grid, order


def run_bounds(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query (start, length) of the 9 contiguous stencil z-runs.

    query_pos: (Q, 3). Returns (s, n), each (Q, 9) int32: for every (dx, dy)
    stencil column, the sorted-pool range [s, s+n) covering the z-run of ≤3
    boxes — ``[starts[k_lo], starts[k_hi]+counts[k_hi])`` with clipped
    endpoints, zero-length where the column falls outside the grid.
    Candidates are *box-level*; callers apply the radius test.
    """
    dims = spec.dims
    cell = morton.cell_of(query_pos, grid.origin, grid.box_size, dims)   # (Q,3)
    off = jnp.asarray(_RUN_OFFSETS)                                      # (9,2)
    nx = cell[:, None, 0] + off[None, :, 0]                              # (Q,9)
    ny = cell[:, None, 1] + off[None, :, 1]
    inside = ((nx >= 0) & (nx < dims[0]) & (ny >= 0) & (ny < dims[1]))
    nx = jnp.clip(nx, 0, dims[0] - 1)
    ny = jnp.clip(ny, 0, dims[1] - 1)
    z_lo = jnp.maximum(cell[:, 2] - 1, 0)[:, None]                       # (Q,1)
    z_hi = jnp.minimum(cell[:, 2] + 1, dims[2] - 1)[:, None]
    k_lo = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_lo, nx.shape), dims)
    k_hi = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_hi, nx.shape), dims)
    s = grid.starts[k_lo]                                                # (Q,9)
    e = grid.starts[k_hi] + grid.counts[k_hi]
    n = jnp.where(inside, e - s, 0)
    return s, n


def build_pairlist(spec: GridSpec, grid: GridState, position: jnp.ndarray,
                   alive: jnp.ndarray, *, radius, max_pairs: int,
                   chunk: Optional[int] = None,
                   pvary_axes: Tuple[str, ...] = ()) -> PairList:
    """Distance-filter the streamed candidate runs into a packed PairList.

    One pass with the exact block/run decomposition of the streamed sweep
    (same ``active_block_list`` blocks over ``alive``, same clamped slices,
    same 9 z-runs truncated at ``run_capacity``), keeping only candidates
    with ‖Δpos‖² ≤ radius² (inclusive, so behaviors that interact AT their
    radius — e.g. Infection's ``dist² ≤ r²`` — are covered at skin=0).
    Each row's kept candidates are cumsum-compacted in run-major lane-minor
    order; per-row demand past ``max_pairs`` parks in a discarded column and
    is reported uncapped through ``count``/``demand`` (§4.2 never-silent).

    ``position``/``alive`` must be the RESIDENT grid-ordered channels of the
    build (sorted position == slot id), as everywhere in this module.

    Compaction is gather-based: a row-major cumsum over the (B, 9·R) valid
    mask followed by a per-row binary search (searchsorted) for each of the
    P output lanes. A scatter formulation (``.at[dst].set``) is the obvious
    alternative but serializes element-by-element on XLA:CPU — measured
    ~20× slower than the whole pruned sweep it feeds.
    """
    c = position.shape[0]
    b = min(chunk if chunk is not None else spec.query_chunk, c)
    p = max_pairs
    r_cap = spec.run_capacity
    blk_idx, n_blk = compaction.active_block_list(alive, b)
    lane = jnp.arange(r_cap, dtype=jnp.int32)
    r2 = jnp.square(jnp.asarray(radius, position.dtype))
    out_rank = jnp.arange(1, p + 1, dtype=jnp.int32)                 # (P,)

    carry0 = (jnp.zeros((c, p), jnp.int32), jnp.zeros((c, 10), jnp.int32),
              jnp.zeros((c,), jnp.int32), jnp.zeros((), jnp.int32))
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        carry0 = tuple(_pcast_varying(v, pvary_axes) for v in carry0)

    def body(i, carry):
        idx_t, off_t, cnt_t, demand = carry
        # clamp the window so a trailing partial block stays in range; overlap
        # rows recompute identical values (pure per-row function of channels)
        sl = jnp.minimum(blk_idx[i] * b, c - b)
        rows = sl + jnp.arange(b, dtype=jnp.int32)                       # (B,)
        qpos = jax.lax.dynamic_slice_in_dim(position, sl, b, axis=0)
        arow = jax.lax.dynamic_slice_in_dim(alive, sl, b, axis=0)
        s, n = run_bounds(spec, grid, qpos)                              # (B,9)
        n = jnp.minimum(n, r_cap)

        # all 9 runs at once, run-major lane-minor: (B, 9, R) → (B, 9R)
        pos = (s[:, :, None] + lane[None, None, :]).reshape(b, 9 * r_cap)
        valid = (lane[None, None, :] < n[:, :, None]).reshape(b, 9 * r_cap)
        valid &= pos != rows[:, None]              # resident: position == slot
        valid &= arow[:, None]
        safe = jnp.where(valid, pos, 0)
        d = position[safe] - qpos[:, None, :]
        valid &= jnp.sum(d * d, axis=-1) <= r2
        inc = jnp.cumsum(valid.astype(jnp.int32), axis=1)            # (B,9R)
        cnt = inc[:, -1]                                 # uncapped demand
        # inverse of the compacting scatter: output lane m holds the source
        # lane where the running kept-count first reaches m+1
        src = jax.vmap(lambda a, v: jnp.searchsorted(a, v))(inc, out_rank[None, :].repeat(b, 0))
        stored = out_rank[None, :] <= jnp.minimum(cnt, p)[:, None]
        buf = jnp.where(stored,
                        jnp.take_along_axis(safe, jnp.minimum(src, 9 * r_cap - 1), axis=1),
                        0)
        # per-run segment boundaries: kept-count at each run's last lane
        run_end = inc.reshape(b, 9, r_cap)[:, :, -1]                 # (B,9)
        off = jnp.concatenate([jnp.zeros((b, 1), jnp.int32),
                               jnp.minimum(run_end, p)], axis=1)
        idx_t = jax.lax.dynamic_update_slice(idx_t, buf, (sl, 0))
        off_t = jax.lax.dynamic_update_slice(off_t, off, (sl, 0))
        cnt_t = jax.lax.dynamic_update_slice_in_dim(cnt_t, cnt, sl, axis=0)
        return idx_t, off_t, cnt_t, jnp.maximum(demand, jnp.max(cnt))

    idx_t, off_t, cnt_t, demand = jax.lax.fori_loop(0, n_blk, body, carry0)
    return PairList(idx=idx_t, run_off=off_t, count=cnt_t, demand=demand)


def neighbor_runs(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbors as *sorted-pool positions*, all 9 runs materialized.

    query_pos: (Q, 3). Returns (pos, valid): (Q, 9·R) int32 positions into the
    key-sorted pool and bool mask. The wide form of :func:`run_bounds` — hot
    paths stream the runs one at a time instead (:func:`resident_apply`).
    """
    r_cap = spec.run_capacity
    s, n = run_bounds(spec, grid, query_pos)
    lane = jnp.arange(r_cap, dtype=jnp.int32)                            # (R,)
    pos = s[..., None] + lane                                            # (Q,9,R)
    valid = lane < jnp.minimum(n, r_cap)[..., None]
    pos = jnp.where(valid, pos, 0)
    q = query_pos.shape[0]
    return pos.reshape(q, 9 * r_cap), valid.reshape(q, 9 * r_cap)


def neighbor_candidates(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbor *slot ids* for each query position (compat wrapper).

    query_pos: (Q, 3). Returns (ids, valid): (Q, 9·R) int32 slot ids and bool
    mask. Prefer :func:`neighbor_runs` + sorted channels on hot paths — slot
    ids re-randomize the gather order this layout exists to avoid.
    """
    pos, valid = neighbor_runs(spec, grid, query_pos)
    return grid.order[pos], valid


def sort_channels(grid: GridState, channels: Dict[str, jnp.ndarray]
                  ) -> Dict[str, jnp.ndarray]:
    """Channels reordered by grid key — neighbor runs become contiguous reads.

    Non-resident compat only (distributed engine, Fig-11 baselines): under
    :func:`build_resident` the pool itself is already in this order and no
    copy exists to make.
    """
    return {k: v[grid.order] for k, v in channels.items()}


def chunk_apply(channels: Dict[str, jnp.ndarray],
                gather_channels: Dict[str, jnp.ndarray],
                query_idx: jnp.ndarray,
                n_query: jnp.ndarray,
                cand_fn: Callable[[jnp.ndarray, jnp.ndarray],
                                  Tuple[jnp.ndarray, jnp.ndarray]],
                pair_fn: Callable[[Dict[str, jnp.ndarray],
                                   Dict[str, jnp.ndarray],
                                   jnp.ndarray, jnp.ndarray], Dict[str, jnp.ndarray]],
                out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                chunk: int,
                pvary_axes: Tuple[str, ...] = (),
                ) -> Dict[str, jnp.ndarray]:
    """The one chunked query loop shared by every environment (DESIGN.md §3.5).

    The chunk loop has a *dynamic* trip count ⌈n_query / chunk⌉ — with
    static-region detection on, compute really does shrink with the active set
    (paper §5 / O6; DESIGN.md §2).

    channels: full per-slot SoA dict (what q entries are sliced from).
    gather_channels: dict neighbor candidates are gathered from — the
      key-sorted copy for the uniform grid (contiguous runs), the raw slot
      view for scatter/hash/brute environments.
    query_idx: (C,) int32 — compacted active slots (tail padded, see
      compaction.active_index_list); n_query: traced count.
    cand_fn(q_pos, q_slot) -> (idx, valid): candidate indices *into
      gather_channels* and validity (self-exclusion included).
    pair_fn(q, nbr, valid, q_slot) -> dict of per-query reductions; q entries
      are (B, ...) chunk slices, nbr entries are (B, W, ...) gathers, valid is
      (B, W) bool, q_slot is (B,) the query slot ids. May return a subset of
      out_specs (missing outputs keep their zeros).
    out_specs: name → (shape_suffix, dtype) of per-agent outputs; results are
      scattered back to slot positions, zeros elsewhere.

    This is the single-phase special case of :func:`phased_chunk_apply` —
    one candidate slab of full width W instead of n_phases streamed slabs.
    """
    return phased_chunk_apply(channels, gather_channels, query_idx, n_query,
                              lambda q_pos, q_slot, j: cand_fn(q_pos, q_slot),
                              1, pair_fn, out_specs, chunk, pvary_axes)


def neighbor_apply(spec: GridSpec,
                   grid: GridState,
                   channels: Dict[str, jnp.ndarray],
                   query_idx: jnp.ndarray,
                   n_query: jnp.ndarray,
                   pair_fn: Callable,
                   out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                   pvary_axes: Tuple[str, ...] = (),
                   ) -> Dict[str, jnp.ndarray]:
    """Apply ``pair_fn`` over each query agent's run candidates, chunked.

    Non-resident compat path: sorts a channel copy once (the runs then gather
    contiguous spans) and resolves candidates inline per chunk. The engine's
    hot path is :func:`build_resident` + :func:`resident_apply`, which needs
    neither the copy nor the slot-id indirection.
    """
    sorted_ch = sort_channels(grid, channels)

    def cand_fn(q_pos, q_slot):
        pos, valid = neighbor_runs(spec, grid, q_pos)
        valid &= pos != grid.rank[q_slot][:, None]          # exclude self
        return pos, valid

    return chunk_apply(channels, sorted_ch, query_idx, n_query, cand_fn,
                       pair_fn, out_specs, spec.query_chunk, pvary_axes)


def resident_apply(spec: GridSpec,
                   grid: GridState,
                   channels: Dict[str, jnp.ndarray],
                   query_mask: jnp.ndarray,
                   pair_fn: Callable,
                   out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                   chunk: Optional[int] = None,
                   pvary_axes: Tuple[str, ...] = (),
                   ) -> Dict[str, jnp.ndarray]:
    """Run-streaming neighbor apply over the RESIDENT grid-ordered pool.

    ``channels`` must be in grid-key order (from :func:`build_resident` —
    sorted position == slot id). The loop differs from :func:`chunk_apply`
    in three load-bearing ways (DESIGN.md §3.2):

      * **Contiguous queries.** A query block is a ``dynamic_slice`` of the
        pool, not a gather through an index list; outputs are written back
        with ``dynamic_update_slice``, not scatter-add.
      * **Run streaming.** The 3×3×3 stencil is consumed as 9 sequential
        z-run gathers of width R accumulated into the per-block outputs —
        peak candidate footprint B×R instead of the B×9R materialized
        matrix, and each gather reads one contiguous span.
      * **Block-granular static skipping (paper §5 / O6).** Only blocks
        containing ≥1 ``query_mask`` row are visited: the trip count is the
        *dynamic* number of active blocks (compaction.active_block_list).
        The resident order clusters spatially-quiescent agents into the same
        blocks, which is what makes the skip rate track the static fraction.

    ``pair_fn`` outputs must be additive across splits of the candidate axis
    (sums/counts — encode an OR-style reduction as a count and threshold it).
    Outputs are written for ``query_mask`` rows, zeros elsewhere.
    """
    c = channels["position"].shape[0]
    b = min(chunk if chunk is not None else spec.query_chunk, c)
    r_cap = spec.run_capacity
    blk_idx, n_blk = compaction.active_block_list(query_mask, b)
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {k: _pcast_varying(v, pvary_axes) for k, v in outs.items()}
    lane = jnp.arange(r_cap, dtype=jnp.int32)

    def body(i, outs):
        # clamp the window so a trailing partial block stays in range; overlap
        # rows recompute identical values (pure per-row function of channels)
        sl = jnp.minimum(blk_idx[i] * b, c - b)
        rows = sl + jnp.arange(b, dtype=jnp.int32)                       # (B,)
        q = {k: jax.lax.dynamic_slice_in_dim(v, sl, b, axis=0)
             for k, v in channels.items()}
        qmask = jax.lax.dynamic_slice_in_dim(query_mask, sl, b, axis=0)
        s, n = run_bounds(spec, grid, q["position"])                     # (B,9)
        n = jnp.minimum(n, r_cap)

        def run(j, acc):
            pos = s[:, j, None] + lane                                   # (B,R)
            valid = lane[None, :] < n[:, j, None]
            valid &= pos != rows[:, None]          # resident: position == slot
            pos = jnp.where(valid, pos, 0)
            nbr = {k: v[pos] for k, v in channels.items()}
            res = pair_fn(q, nbr, valid, rows)
            return {name: acc[name] + res[name].astype(acc[name].dtype)
                    if name in res else acc[name] for name in acc}

        acc0 = {name: jnp.zeros((b, *sfx), dt)
                for name, (sfx, dt) in out_specs.items()}
        if pvary_axes:   # inner carry must match the varying results it sums
            acc0 = {k: _pcast_varying(v, pvary_axes) for k, v in acc0.items()}
        acc = jax.lax.fori_loop(0, 9, run, acc0)
        new_outs = {}
        for name, val in acc.items():
            val = jnp.where(qmask.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = jax.lax.dynamic_update_slice_in_dim(
                outs[name], val, sl, axis=0)
        return new_outs

    return jax.lax.fori_loop(0, n_blk, body, outs)


@dataclasses.dataclass(frozen=True)
class PairKernel:
    """One pair kernel registered into a fused resident sweep (DESIGN.md §3.2).

    name:      unique registry key; the fused sweep returns its outputs under
               ``results[name]``.
    pair_fn:   ``(q, nbr, valid, q_slot) -> dict`` with the same contract as
               :func:`resident_apply` — outputs must be additive across
               candidate-axis splits.
    out_specs: output name → (shape_suffix, dtype), per kernel.
    reads:     the channel *footprint* — every pool channel the pair_fn reads
               on either the query or the neighbor side (``extra.*`` names
               included). The sweep gathers exactly the union of all
               registered footprints, so an undeclared read fails loudly at
               trace time (KeyError) instead of silently streaming the whole
               SoA.
    query_mask: per-kernel query rows (None → the sweep's default mask).
               Outputs are zero outside the kernel's own mask even when a
               block was visited for another kernel's sake.
    """
    name: str
    pair_fn: Callable
    out_specs: Dict[str, Tuple[Tuple[int, ...], Any]]
    reads: Tuple[str, ...]
    query_mask: Optional[jnp.ndarray] = None


def fused_reads(kernels: Sequence["PairKernel"]) -> Tuple[str, ...]:
    """Union of the kernels' channel footprints, first-appearance order."""
    seen, order = set(), []
    for k in kernels:
        for ch in k.reads:
            if ch not in seen:
                seen.add(ch)
                order.append(ch)
    return tuple(order)


def resident_apply_fused(spec: GridSpec,
                         grid: GridState,
                         channels: Dict[str, jnp.ndarray],
                         kernels: Sequence[PairKernel],
                         default_mask: jnp.ndarray,
                         chunk: Optional[int] = None,
                         pvary_axes: Tuple[str, ...] = (),
                         pairs: Optional[PairList] = None,
                         ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Multi-kernel :func:`resident_apply`: ONE candidate stream per block.

    The single-kernel loop re-gathers the 9 z-runs once per phase (forces,
    each neighbor behavior, ...). Here every registered :class:`PairKernel`
    is evaluated against the *same* per-run gather, and that gather is pruned
    to the union of the declared footprints — an SIR run never streams
    ``diameter``, a forces-only run never streams infection timers. Peak
    per-block candidate memory drops from ``phases × B×R×|channels|`` streams
    to ``1 × B×R×|union reads|``, and the pass count over the pool from one
    per phase to one total.

    Parity vs sequential single-kernel sweeps (tests/test_fused.py):

      * The block list is driven by the OR of the kernels' query masks. A
        block visited by both paths sees the identical slice offset, run
        bounds, gather and run accumulation order, so each kernel's outputs
        on its own mask rows are **bit-exact** vs its sequential sweep.
      * A block visited only for another kernel's sake writes zeros for this
        kernel (its mask slice is all-False there) — identical to the
        sequential path never visiting it.

    **from_pairlist mode** (``pairs`` given, DESIGN.md §3.4): instead of
    streaming the 9 z-runs at width R, gather the row's pruned candidates
    ONCE at width P = pairs.idx.shape[-1] and evaluate each kernel once per
    run *segment* of the packed table. Parity vs the streamed sweep:

      * ``build_pairlist`` kept candidates in run-major lane-minor order
        with per-run boundaries (``run_off``), so each run's masked segment
        presents the surviving candidates in the streamed order with the
        dropped ones replaced by exact zeros (out-of-reach candidates
        contribute +0.0 / int 0 in every kernel — the same identity the
        streamed reduction already relies on), and the across-run
        accumulation order is identical. With skin=0 and an every-step
        rebuild the listed set is built at this step's positions, so
        per-kernel INTEGER outputs are bit-exact vs the streamed sweep and
        float outputs agree to the last bit in almost every row — but not
        unconditionally: XLA:CPU lowers the lane-axis ``jnp.sum`` inside a
        pair_fn to a lane-POSITION-sensitive partial-accumulator scheme, so
        packing bit-equal addends into different lanes (or a different
        width P ≠ R) can regroup a near-cancelling row's sum by 1-2 ulp.
        Same-mode comparisons (ladder rewind vs pre-sized, shard counts,
        the Pallas block map) share one layout and stay fully bit-exact.
      * Under every_k reuse (skin>0, 2·pair_disp ≤ skin) the listed set is
        an exact superset of the in-range pairs at *current* positions; the
        residue vs a fresh streamed sweep is float-association only (the
        same nonzero contributions may group into different run segments
        once agents cross cell lines).
    """
    if not kernels:
        return {}
    names = [k.name for k in kernels]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate PairKernel names: {names} — give each "
                         f"registered kernel (behavior) a unique name")
    reads = fused_reads(kernels)
    missing = [ch for ch in reads if ch not in channels]
    if missing:
        raise KeyError(f"PairKernel footprint names channels not in the pool: "
                       f"{missing} (have {sorted(channels)})")
    c = channels["position"].shape[0]
    b = min(chunk if chunk is not None else spec.query_chunk, c)
    r_cap = spec.run_capacity
    masks = [k.query_mask if k.query_mask is not None else default_mask
             for k in kernels]
    union_mask = masks[0]
    for m in masks[1:]:
        union_mask = union_mask | m
    blk_idx, n_blk = compaction.active_block_list(union_mask, b)
    gather_ch = {ch: channels[ch] for ch in reads}      # the pruned stream
    q_src = dict(gather_ch)
    if pairs is None:
        q_src.setdefault("position", channels["position"])  # run_bounds
    lane = jnp.arange(r_cap, dtype=jnp.int32)

    outs = {k.name: {name: jnp.zeros((c, *sfx), dt)
                     for name, (sfx, dt) in k.out_specs.items()}
            for k in kernels}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {kn: {n: _pcast_varying(v, pvary_axes) for n, v in o.items()}
                for kn, o in outs.items()}

    def acc_zeros():
        acc0 = {k.name: {name: jnp.zeros((b, *sfx), dt)
                         for name, (sfx, dt) in k.out_specs.items()}
                for k in kernels}
        if pvary_axes:   # inner carry must match the varying results it sums
            acc0 = {kn: {n_: _pcast_varying(v, pvary_axes)
                         for n_, v in o.items()} for kn, o in acc0.items()}
        return acc0

    def kernel_round(q, nbr, valid, rows, accs):
        new = {}
        for k in kernels:
            res = k.pair_fn(q, nbr, valid, rows)
            acc = accs[k.name]
            new[k.name] = {
                name: acc[name] + res[name].astype(acc[name].dtype)
                if name in res else acc[name] for name in acc}
        return new

    def writeback(outs, accs, kmasks, sl):
        new_outs = {}
        for k, km in zip(kernels, kmasks):
            ko = {}
            for name, val in accs[k.name].items():
                val = jnp.where(
                    km.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
                ko[name] = jax.lax.dynamic_update_slice_in_dim(
                    outs[k.name][name], val, sl, axis=0)
            new_outs[k.name] = ko
        return new_outs

    if pairs is not None:
        p = pairs.idx.shape[-1]
        lane_p = jnp.arange(p, dtype=jnp.int32)

        def body(i, outs):
            sl = jnp.minimum(blk_idx[i] * b, c - b)
            rows = sl + jnp.arange(b, dtype=jnp.int32)                   # (B,)
            q = {ch: jax.lax.dynamic_slice_in_dim(v, sl, b, axis=0)
                 for ch, v in q_src.items()}
            kmasks = [jax.lax.dynamic_slice_in_dim(m, sl, b, axis=0)
                      for m in masks]
            idx_b = jax.lax.dynamic_slice(pairs.idx, (sl, 0), (b, p))
            off_b = jax.lax.dynamic_slice(pairs.run_off, (sl, 0), (b, 10))
            stored = lane_p[None, :] < off_b[:, -1:]
            posc = jnp.where(stored, idx_b, 0)
            nbr = {ch: v[posc] for ch, v in gather_ch.items()}  # ONE gather

            def run(j, accs):
                lo = jax.lax.dynamic_slice_in_dim(off_b, j, 1, axis=1)
                hi = jax.lax.dynamic_slice_in_dim(off_b, j + 1, 1, axis=1)
                valid = (lane_p[None, :] >= lo) & (lane_p[None, :] < hi)
                return kernel_round(q, nbr, valid, rows, accs)

            accs = jax.lax.fori_loop(0, 9, run, acc_zeros())
            return writeback(outs, accs, kmasks, sl)

        return jax.lax.fori_loop(0, n_blk, body, outs)

    def body(i, outs):
        # clamp the window so a trailing partial block stays in range; overlap
        # rows recompute identical values (pure per-row function of channels)
        sl = jnp.minimum(blk_idx[i] * b, c - b)
        rows = sl + jnp.arange(b, dtype=jnp.int32)                       # (B,)
        q = {ch: jax.lax.dynamic_slice_in_dim(v, sl, b, axis=0)
             for ch, v in q_src.items()}
        kmasks = [jax.lax.dynamic_slice_in_dim(m, sl, b, axis=0)
                  for m in masks]
        s, n = run_bounds(spec, grid, q["position"])                     # (B,9)
        n = jnp.minimum(n, r_cap)

        def run(j, accs):
            pos = s[:, j, None] + lane                                   # (B,R)
            valid = lane[None, :] < n[:, j, None]
            valid &= pos != rows[:, None]          # resident: position == slot
            pos = jnp.where(valid, pos, 0)
            nbr = {ch: v[pos] for ch, v in gather_ch.items()}  # ONE gather
            return kernel_round(q, nbr, valid, rows, accs)

        accs = jax.lax.fori_loop(0, 9, run, acc_zeros())
        return writeback(outs, accs, kmasks, sl)

    return jax.lax.fori_loop(0, n_blk, body, outs)


def phased_chunk_apply(channels: Dict[str, jnp.ndarray],
                       gather_channels: Dict[str, jnp.ndarray],
                       query_idx: jnp.ndarray,
                       n_query: jnp.ndarray,
                       phase_fn: Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray],
                                          Tuple[jnp.ndarray, jnp.ndarray]],
                       n_phases: int,
                       pair_fn: Callable,
                       out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                       chunk: int,
                       pvary_axes: Tuple[str, ...] = (),
                       ) -> Dict[str, jnp.ndarray]:
    """:func:`chunk_apply` with the candidate axis split into streamed phases.

    ``phase_fn(q_pos, q_slot, j)`` resolves the j'th candidate slab (idx,
    valid) of fixed width W; the inner loop accumulates ``pair_fn`` results
    across the ``n_phases`` slabs, so peak candidate footprint is B×W instead
    of B×(n_phases·W). The same additive-output contract as
    :func:`resident_apply` applies (``pair_fn`` may return a subset of
    out_specs). Used by the hash-grid environment (27 single-box probes —
    the wide form was its Fig-11 pathology) and, with ``n_phases=1``, as the
    body of :func:`chunk_apply`.
    """
    c = channels["position"].shape[0]
    b = min(chunk, c)
    n_chunks_max = (c + b - 1) // b
    # pad so dynamic_slice never clamps (clamping would desync q_slot vs lane_ok)
    qi = jnp.pad(query_idx, (0, n_chunks_max * b - c))
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {k: _pcast_varying(v, pvary_axes) for k, v in outs.items()}

    def body(i, outs):
        sl = i * b
        q_slot = jax.lax.dynamic_slice(qi, (sl,), (b,))                  # (B,)
        lane_ok = (sl + jnp.arange(b)) < n_query                         # (B,)
        q = {k: v[q_slot] for k, v in channels.items()}

        def phase(j, acc):
            idx, valid = phase_fn(q["position"], q_slot, j)
            valid &= lane_ok[:, None]
            nbr = {k: v[idx] for k, v in gather_channels.items()}
            res = pair_fn(q, nbr, valid, q_slot)
            return {name: acc[name] + res[name].astype(acc[name].dtype)
                    if name in res else acc[name] for name in acc}

        acc0 = {name: jnp.zeros((b, *sfx), dt)
                for name, (sfx, dt) in out_specs.items()}
        if pvary_axes:   # inner carry must match the varying results it sums
            acc0 = {k: _pcast_varying(v, pvary_axes) for k, v in acc0.items()}
        if n_phases == 1:
            acc = phase(jnp.int32(0), acc0)
        else:
            acc = jax.lax.fori_loop(0, n_phases, phase, acc0)
        new_outs = {}
        for name, val in acc.items():
            val = jnp.where(
                lane_ok.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = outs[name].at[q_slot].add(
                val.astype(outs[name].dtype), mode="drop")
        return new_outs

    n_chunks = jnp.minimum((n_query + b - 1) // b, n_chunks_max)
    return jax.lax.fori_loop(0, n_chunks, body, outs)


# ---------------------------------------------------------------------------
# Alternative environments (Fig 11 comparison)
# ---------------------------------------------------------------------------

def brute_force_apply(channels: Dict[str, jnp.ndarray],
                      alive: jnp.ndarray,
                      pair_fn,
                      out_specs,
                      chunk: int = 512) -> Dict[str, jnp.ndarray]:
    """Exact O(N²) neighbor apply (oracle + Fig-11 baseline).

    pair_fn has the same signature as in neighbor_apply; candidates are *all*
    agents (``valid`` carries alive & not-self; the radius test is pair_fn's
    own distance mask, identical to the grid path).
    """
    c = channels["position"].shape[0]
    chunk = min(chunk, c)
    ids = jnp.arange(c, dtype=jnp.int32)

    def cand_fn(q_pos, q_slot):
        b = q_slot.shape[0]
        idx = jnp.broadcast_to(ids[None], (b, c))
        valid = alive[None, :] & (idx != q_slot[:, None])
        return idx, valid

    q_idx = jnp.arange(c, dtype=jnp.int32)
    return chunk_apply(channels, channels, q_idx, jnp.int32(c), cand_fn,
                       pair_fn, out_specs, chunk)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScatterGridState:
    """'Standard implementation' grid: dense (boxes × K) member table via scatter.

    Models BioDynaMo's *unoptimized* path: the table is re-zeroed and re-scattered
    every iteration, touching O(#boxes · K) memory — the cost the paper's
    timestamp trick (and our sort-based build) avoids.
    """
    origin: jnp.ndarray
    box_size: jnp.ndarray
    table: jnp.ndarray         # (M, K) int32 slot ids, -1 = empty
    counts: jnp.ndarray        # (M,)


def _build_scatter_impl(spec: GridSpec, pool: AgentPool, origin, box_size,
                        sort_impl: str = "auto") -> ScatterGridState:
    m, k = spec.table_size, spec.max_per_box
    keys = morton.linear_keys(pool.position, origin, box_size, spec.dims)
    keys = jnp.where(pool.alive, keys, m)  # park dead at row m (dropped)
    # slot-within-box via sort (the CPU version uses sequential insertion;
    # the data-parallel equivalent needs a sort or atomics — we sort).
    order = counting_sort_order(keys, m, impl=sort_impl)
    sorted_keys = keys[order]
    first = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    slot_in_box = jnp.arange(keys.shape[0]) - first                  # rank within box
    table = jnp.full((m + 1, k), -1, jnp.int32)
    sk = jnp.minimum(slot_in_box, k - 1)
    table = table.at[sorted_keys.astype(jnp.int32), sk].set(order.astype(jnp.int32),
                                                            mode="drop")
    counts = jnp.zeros((m + 1,), jnp.int32).at[keys.astype(jnp.int32)].add(
        pool.alive.astype(jnp.int32), mode="drop")
    return ScatterGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                            table=table[:m], counts=counts[:m])


def scatter_grid_candidates(spec: GridSpec, g: ScatterGridState, query_pos
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = spec.max_per_box
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    codes = morton.linear_encode3(ncell_c[..., 0], ncell_c[..., 1],
                                  ncell_c[..., 2], spec.dims).astype(jnp.int32)
    members = g.table[codes]                                      # (Q,27,K)
    valid = (members >= 0) & inside[..., None]
    q = query_pos.shape[0]
    return jnp.maximum(members, 0).reshape(q, 27 * k), valid.reshape(q, 27 * k)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HashGridState:
    """Spatial-hash grid with a fixed bucket table (memory-capped alternative).

    ``cell_keys`` holds each slot's *unhashed* linear cell id (dead slots →
    DEAD_KEY): a bucket mixes agents from every cell that hashes to it, so
    queries must re-check the candidate's true cell against the probed
    stencil cell — without it, two stencil cells colliding into one bucket
    would yield the bucket's in-radius agents twice (double-counted force
    and force_nnz).
    """
    origin: jnp.ndarray
    box_size: jnp.ndarray
    keys: jnp.ndarray
    cell_keys: jnp.ndarray
    order: jnp.ndarray
    starts: jnp.ndarray
    counts: jnp.ndarray
    max_bucket_count: jnp.ndarray


# default probe gather width multiplier: hash collisions inflate buckets, so
# queries gather HASH_K_MULT×max_per_box per bucket; a bucket fuller than that
# truncates → flagged via stats["box_overflow"] (engine, DESIGN.md §4.2)
HASH_K_MULT = 4


def _hash_cell(cell: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    # classic 3-prime spatial hash (Teschner et al.)
    p = jnp.asarray([73856093, 19349663, 83492791], jnp.uint32)
    h = (cell[..., 0].astype(jnp.uint32) * p[0]
         ^ cell[..., 1].astype(jnp.uint32) * p[1]
         ^ cell[..., 2].astype(jnp.uint32) * p[2])
    return h % jnp.uint32(n_buckets)


def _build_hash_impl(spec: GridSpec, pool: AgentPool, origin, box_size,
                     n_buckets: int = 1 << 14, sort_impl: str = "auto"
                     ) -> HashGridState:
    cell = morton.cell_of(pool.position, origin, box_size, spec.dims)
    keys = _hash_cell(cell, n_buckets)
    keys = jnp.where(pool.alive, keys, jnp.uint32(n_buckets))
    cell_keys = jnp.where(pool.alive,
                          morton.linear_encode3(cell[..., 0], cell[..., 1],
                                                cell[..., 2], spec.dims),
                          morton.DEAD_KEY)
    order = counting_sort_order(keys, n_buckets, impl=sort_impl)
    sorted_keys = keys[order]
    bucket_ids = jnp.arange(n_buckets, dtype=jnp.uint32)
    starts = jnp.searchsorted(sorted_keys, bucket_ids, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(sorted_keys, bucket_ids, side="right").astype(jnp.int32)
    counts = (ends - starts).astype(table_count_dtype(pool.capacity))
    return HashGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                         keys=keys, cell_keys=cell_keys, order=order,
                         starts=starts, counts=counts,
                         max_bucket_count=jnp.max(counts))


def hash_grid_probe(spec: GridSpec, g: HashGridState, query_pos,
                    j: jnp.ndarray, k_mult: int = HASH_K_MULT
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidates of the j'th stencil box only — one streamed hash probe.

    phase_fn for :func:`phased_chunk_apply` (27 phases): capacity per probe is
    one bucket (k_mult·max_per_box), not 27 buckets at once. This is the fix
    for the Fig-11 hash-grid pathology: the wide (Q, 27·K_hash) candidate
    matrix was ~12× the uniform grid's and dominated its search time.

    Candidates are filtered to the probed cell's true members (``cell_keys``
    re-check): without it, two stencil cells hashing to one bucket would
    double-count the bucket's in-radius agents across phases.
    """
    n_buckets = g.starts.shape[0]       # from the build — no mismatch possible
    k = spec.max_per_box * k_mult
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)    # (Q,3)
    ncell = cell + jnp.asarray(_OFFSETS)[j][None, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    h = _hash_cell(ncell_c, n_buckets)
    k_true = morton.linear_encode3(ncell_c[..., 0], ncell_c[..., 1],
                                   ncell_c[..., 2], spec.dims)           # (Q,)
    s = g.starts[h]
    n = jnp.where(inside, g.counts[h], 0)
    lane = jnp.arange(k, dtype=jnp.int32)
    pos = s[:, None] + lane
    valid = lane < jnp.minimum(n, k)[:, None]
    pos = jnp.where(valid, pos, 0)
    ids = g.order[pos]                                                   # (Q,k)
    valid &= g.cell_keys[ids] == k_true[:, None]
    return ids, valid


def hash_grid_candidates(spec: GridSpec, g: HashGridState, query_pos,
                         k_mult: int = HASH_K_MULT
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Wide (Q, 27·k) candidate matrix: all 27 probes of
    :func:`hash_grid_probe` materialized at once. Fig-11 baseline only
    ('hash_grid_wide' — its width is the pathology the streamed probes fix);
    kept as a thin stack over the probe so the two paths cannot diverge.
    """
    probes = [hash_grid_probe(spec, g, query_pos, j, k_mult)
              for j in range(27)]
    return (jnp.concatenate([ids for ids, _ in probes], axis=1),
            jnp.concatenate([valid for _, valid in probes], axis=1))


# ---------------------------------------------------------------------------
# Unified builder factory — ONE entry point over the grid-build zoo
# ---------------------------------------------------------------------------

BUILD_METHODS = ("resident", "sorted", "scatter", "hash")


class BuildResult(NamedTuple):
    """Uniform result of every grid build (whatever the method).

    pool:     the pool the tables index — permuted into grid-key order by
              the resident method, returned unchanged by the others
    grid:     GridState ('resident'/'sorted'), ScatterGridState, or
              HashGridState
    order:    (C,) int32 old→new gather permutation *applied to the pool*
              (identity for the non-permuting methods) — callers tracking
              external per-slot state re-map with it
    overflow: () int32 — agents beyond the method's fixed gather/table
              capacity this build: run_capacity excess for the uniform grid,
              per-box truncation for the scatter table (which the legacy
              entry point dropped silently), probe-width excess for the hash
              grid. 0 ⇔ queries against this build are exact.
    demand:   () int32 — the observed peak occupancy behind ``overflow``
              (max 3-box z-run / max box / max bucket): the which-capacity
              provenance the capacity ladder sizes the next rung from.
    """
    pool: AgentPool
    grid: Any
    order: jnp.ndarray
    overflow: jnp.ndarray
    demand: jnp.ndarray


def make_builder(spec: GridSpec, *, method: str = "resident",
                 sort_impl: str = "auto", n_buckets: int = 1 << 14
                 ) -> Callable[[AgentPool, jnp.ndarray, jnp.ndarray],
                               BuildResult]:
    """The one grid-builder entry point (replaces the build_* zoo).

    Returns ``build_fn(pool, origin, box_size) -> BuildResult`` for the
    chosen method, with a common overflow/demand surface (§4.2 never-silent
    contract) regardless of which underlying structure is built:

      * "resident" — counting-sort permutation applied to the pool itself;
        grid order IS memory order (the engine hot path).
      * "sorted"   — same tables over the pool as laid out (slot order
        preserved; queries gather through ``sort_channels``).
      * "scatter"  — dense (boxes × K) member table via scatter (the
        paper's 'standard implementation' baseline).
      * "hash"     — fixed-bucket spatial hash over ``n_buckets`` buckets.

    sort_impl selects the key-sort realization (SORT_IMPLS): the O(N)
    counting sort on its "xla" (in-graph, the "auto" default) and "host"
    (opt-in callback — see the deadlock note above) paths, "argsort" as
    the comparison-sort oracle.
    """
    if method not in BUILD_METHODS:
        raise ValueError(
            f"method must be one of {BUILD_METHODS}, got {method!r}")
    if sort_impl not in SORT_IMPLS:
        raise ValueError(
            f"sort_impl must be one of {SORT_IMPLS}, got {sort_impl!r}")

    if method in ("resident", "sorted"):
        def build_fn(pool: AgentPool, origin, box_size) -> BuildResult:
            if method == "resident":
                pool, grid, order = _build_resident_impl(
                    spec, pool, origin, box_size, sort_impl)
            else:
                grid = _build_sorted_impl(spec, pool, origin, box_size,
                                          sort_impl)
                order = jnp.arange(pool.capacity, dtype=jnp.int32)
            demand = grid.max_run_count.astype(jnp.int32)
            return BuildResult(pool, grid, order,
                               jnp.maximum(demand - spec.run_capacity, 0),
                               demand)
    elif method == "scatter":
        def build_fn(pool: AgentPool, origin, box_size) -> BuildResult:
            grid = _build_scatter_impl(spec, pool, origin, box_size,
                                       sort_impl)
            demand = jnp.max(grid.counts).astype(jnp.int32)
            return BuildResult(pool, grid,
                               jnp.arange(pool.capacity, dtype=jnp.int32),
                               jnp.maximum(demand - spec.max_per_box, 0),
                               demand)
    else:
        def build_fn(pool: AgentPool, origin, box_size) -> BuildResult:
            grid = _build_hash_impl(spec, pool, origin, box_size, n_buckets,
                                    sort_impl)
            demand = grid.max_bucket_count.astype(jnp.int32)
            return BuildResult(pool, grid,
                               jnp.arange(pool.capacity, dtype=jnp.int32),
                               jnp.maximum(
                                   demand - HASH_K_MULT * spec.max_per_box,
                                   0),
                               demand)
    return build_fn


# -- one-release deprecation shims over the legacy direct entry points -------

class GridBuilderDeprecationWarning(DeprecationWarning):
    """A legacy direct grid-build entry point was called (use make_builder).

    Its own category so CI can promote exactly these to errors
    (``-W error::repro.core.grid.GridBuilderDeprecationWarning``) without
    entangling unrelated DeprecationWarnings from dependencies.
    """


def _builder_deprecated(name: str, repl: str) -> None:
    warnings.warn(
        f"grid.{name} is deprecated and will be removed next release; use "
        f"grid.make_builder(spec, method={repl!r}) instead",
        GridBuilderDeprecationWarning, stacklevel=3)


def build(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
          box_size: jnp.ndarray) -> GridState:
    """Deprecated: ``make_builder(spec, method='sorted')(...).grid``."""
    _builder_deprecated("build", "sorted")
    return _build_sorted_impl(spec, pool, origin, box_size)


def build_resident(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
                   box_size: jnp.ndarray
                   ) -> Tuple[AgentPool, GridState, jnp.ndarray]:
    """Deprecated: ``make_builder(spec, method='resident')`` → BuildResult."""
    _builder_deprecated("build_resident", "resident")
    return _build_resident_impl(spec, pool, origin, box_size)


def build_scatter_grid(spec: GridSpec, pool: AgentPool, origin, box_size
                       ) -> ScatterGridState:
    """Deprecated: ``make_builder(spec, method='scatter')(...).grid``."""
    _builder_deprecated("build_scatter_grid", "scatter")
    return _build_scatter_impl(spec, pool, origin, box_size)


def build_hash_grid(spec: GridSpec, pool: AgentPool, origin, box_size,
                    n_buckets: int = 1 << 14) -> HashGridState:
    """Deprecated: ``make_builder(spec, method='hash')(...).grid``."""
    _builder_deprecated("build_hash_grid", "hash")
    return _build_hash_impl(spec, pool, origin, box_size, n_buckets)
