"""Uniform-grid neighbor search — paper §3.1, adapted sort-based for TPU.

BioDynaMo's grid stores each box's agents in an array-based linked list and
indexes boxes *row-major*; pointer chasing and per-box timestamps are CPU
idioms. The TPU-native formulation (DESIGN.md §2–§3):

  build:  linear (row-major) box key per agent → parallel sort by key →
          per-box (start, count) via one vectorized ``searchsorted`` over the
          dense table of exactly ``prod(dims)`` boxes. O(#agents log #agents)
          fully parallel work and O(#boxes) *vector* memset equivalents — no
          serial O(#boxes) pass, which is what the paper's timestamp trick was
          avoiding (DESIGN.md §2).
  query:  because z is the fastest-varying key axis, the 3×3×3 stencil (paper
          §3.1) collapses into **9 contiguous runs of ≤3 boxes**: 9 range
          lookups per query instead of 27 per-box lookups, and each run is a
          contiguous streaming read of the grid-ordered pool (DESIGN.md §3).

**Resident layout (DESIGN.md §3.2):** :func:`build_resident` applies the key
sort's permutation to the pool itself, so grid-key order *is* the memory
layout: no per-step sorted copy of the channels, query chunks are contiguous
slices, the paper's periodic Morton sort (§4.2) is subsumed (agents in the
same box are adjacent in memory every step), and — because dead slots carry
the maximum key — the same permutation is the §3.2 death compaction.
:func:`resident_apply` then *streams* the 9 z-runs through the pairwise
reduction one at a time (peak candidate footprint B×R instead of B×9R) and
skips fully-inactive query blocks outright via a dynamic trip count (paper §5
static regions at block granularity).

Alternative environments (paper Fig 11 comparison, DESIGN.md §10.5):
  * BruteForceEnvironment — exact O(N²) masked sweep (small N oracle).
  * ScatterGridEnvironment — 'standard' grid materializing a dense (boxes × K)
    table by scatter; models the cost of touching O(#boxes) memory that the
    paper's timestamp trick addresses.
  * HashGridEnvironment — fixed-bucket spatial hash (collisions filtered by the
    radius mask); models a memory-capped alternative. Its 27 probes stream
    through :func:`phased_chunk_apply` — same accumulation loop as the
    resident path, width K_hash per phase instead of 27·K_hash at once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compaction, morton
from .agents import AgentPool

# 27 neighbor offsets of the 3x3x3 cube (static python constant) — used by the
# scatter/hash environments, whose tables are not contiguous in z.
_OFFSETS = np.array([(dx, dy, dz)
                     for dx in (-1, 0, 1)
                     for dy in (-1, 0, 1)
                     for dz in (-1, 0, 1)], dtype=np.int32)   # (27, 3)

# 9 xy-offsets of the 3x3x3 cube; each pairs with a contiguous z-run of 3 boxes.
_RUN_OFFSETS = np.array([(dx, dy)
                         for dx in (-1, 0, 1)
                         for dy in (-1, 0, 1)], dtype=np.int32)   # (9, 2)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static grid configuration (hashable; part of the jit cache key)."""
    dims: Tuple[int, int, int]          # boxes per axis
    max_per_box: int = 16               # K: bound on agents in any single box
    query_chunk: int = 2048             # agents per neighbor-apply chunk
    max_per_run: Optional[int] = None   # R: gather capacity per 3-box z-run
                                        # (None → 3·K, the loosest exact bound)

    @property
    def table_size(self) -> int:
        """Exactly prod(dims) — no power-of-two padding (DESIGN.md §3)."""
        return morton.linear_size(self.dims)

    @property
    def run_capacity(self) -> int:
        """R: agents gathered per z-run. A run pools 3 boxes, so occupancy
        concentrates around 3·mean rather than 3·max — callers with measured
        densities may set ``max_per_run`` well below 3·K; the build-time
        ``max_run_count`` check keeps it exact (DESIGN.md §4.2)."""
        return self.max_per_run if self.max_per_run is not None \
            else 3 * self.max_per_box


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GridState:
    """Per-iteration neighbor index (rebuilt every step, paper Algorithm 1 L3-5)."""
    origin: jnp.ndarray        # (3,) float — grid origin (traced: domain may move)
    box_size: jnp.ndarray      # ()   float — box edge = interaction radius
    keys: jnp.ndarray          # (C,) uint32 — linear box key per slot (dead → MAX)
    order: jnp.ndarray         # (C,) int32 — slot ids sorted by key (dead at end)
    rank: jnp.ndarray          # (C,) int32 — inverse of order
    starts: jnp.ndarray        # (M,) int32 — first sorted position of each box
    counts: jnp.ndarray        # (M,) table_count_dtype(capacity): int16 when
                               #      the pool fits int16, else int32 —
                               #      values bounded by capacity (§4.3)
    max_count: jnp.ndarray     # ()   int32 — max agents in any box
    max_run_count: jnp.ndarray # ()   int32 — max agents in any 3-box z-run
                               #      (the query-exactness bound; overflow iff
                               #       > spec.run_capacity)


_DEAD_KEY = morton.DEAD_KEY


def _pcast_varying(v: jnp.ndarray, axes: Tuple[str, ...]) -> jnp.ndarray:
    """jax.lax.pcast(..., to="varying") with a no-op fallback for jax < 0.6
    (older shard_map has no varying-axis tracking to satisfy)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(v, axes, to="varying")
    return v


def table_count_dtype(capacity: int) -> jnp.dtype:
    """Dtype of per-box/per-bucket occupancy tables, capacity-parameterized.

    A box can hold at most ``capacity`` agents, so counts fit int16 whenever
    the pool does — halving the (M,)-table footprint at small ladder rungs
    (DESIGN.md §4.3). Sums of ≤3 counts (z-runs) are equally bounded by
    ``capacity`` and stay in range. Starts always need int32 (values up to
    capacity *positions*, but also used as table offsets up to M)."""
    return jnp.dtype(jnp.int16 if capacity < 2 ** 15 else jnp.int32)


def box_tables(sorted_keys: jnp.ndarray, table_size: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense per-box (starts, counts) from the key-sorted keys.

    One searchsorted over M+1 ids gives starts AND counts (ends[i]=starts[i+1];
    the M'th entry lands at n_live because dead keys sort above every box id).
    Shared with the kernel compat wrapper (kernels/ops.collision_force) so the
    table derivation exists exactly once. Counts use the capacity-
    parameterized :func:`table_count_dtype`.
    """
    box_ids = jnp.arange(table_size + 1, dtype=jnp.uint32)
    bounds = jnp.searchsorted(sorted_keys, box_ids, side="left").astype(jnp.int32)
    counts = (bounds[1:] - bounds[:-1]).astype(
        table_count_dtype(sorted_keys.shape[0]))
    return bounds[:-1], counts


def _index_tables(spec: GridSpec, sorted_keys: jnp.ndarray):
    """(starts, counts, max_count, max_run_count) from the key-sorted keys."""
    starts, counts = box_tables(sorted_keys, spec.table_size)
    # per z-run occupancy: windowed sum of 3 consecutive-z boxes
    c3 = counts.reshape(spec.dims)
    cp = jnp.pad(c3, ((0, 0), (0, 0), (1, 1)))
    runs = cp[:, :, :-2] + cp[:, :, 1:-1] + cp[:, :, 2:]
    return starts, counts, jnp.max(counts), jnp.max(runs)


def build(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
          box_size: jnp.ndarray) -> GridState:
    """Build the grid index over the pool *as laid out* (non-resident).

    O(#agents) parallel work + one parallel sort. Queries against this state
    gather from a key-sorted channel copy (``sort_channels``); the engine's
    hot path uses :func:`build_resident` instead, which makes that copy the
    pool itself. Kept for callers that must preserve slot order — the
    distributed engine (ghost concatenation) and the Fig-11 baselines.
    """
    keys = morton.grid_sort_keys(pool.position, pool.alive, origin, box_size,
                                 spec.dims)
    order = jnp.argsort(keys).astype(jnp.int32)              # stable radix-ish sort
    sorted_keys = keys[order]
    rank = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=jnp.int32))
    starts, counts, max_count, max_run = _index_tables(spec, sorted_keys)
    return GridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                     keys=keys, order=order, rank=rank, starts=starts,
                     counts=counts, max_count=max_count, max_run_count=max_run)


def build_resident(spec: GridSpec, pool: AgentPool, origin: jnp.ndarray,
                   box_size: jnp.ndarray
                   ) -> Tuple[AgentPool, GridState, jnp.ndarray]:
    """Permute the pool into grid-key order and index it **in place**.

    The one permutation (DESIGN.md §3.2) composes three reorderings the
    engine used to perform separately:
      * the grid build's key sort (agents of a box are adjacent),
      * the paper's §4.2 memory-layout sort (boxes are adjacent row-major —
        the periodic Morton sort becomes a no-op special case), and
      * §3.2 death compaction (dead slots carry ``morton.DEAD_KEY`` and sink
        stably to the tail, so live agents occupy ``[0, n_live)``).

    Returns (pool, grid, order) with ``pool`` reordered, ``grid.order``/
    ``grid.rank`` the identity (sorted position == slot id), ``grid.keys``
    already sorted, and ``order`` the applied old→new gather permutation
    (callers tracking external per-slot state re-map with it).
    """
    keys = morton.grid_sort_keys(pool.position, pool.alive, origin, box_size,
                                 spec.dims)
    order = jnp.argsort(keys).astype(jnp.int32)
    pool = compaction.apply_permutation(pool, order)
    sorted_keys = keys[order]
    starts, counts, max_count, max_run = _index_tables(spec, sorted_keys)
    ident = jnp.arange(order.shape[0], dtype=jnp.int32)
    grid = GridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                     keys=sorted_keys, order=ident, rank=ident, starts=starts,
                     counts=counts, max_count=max_count, max_run_count=max_run)
    return pool, grid, order


def run_bounds(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query (start, length) of the 9 contiguous stencil z-runs.

    query_pos: (Q, 3). Returns (s, n), each (Q, 9) int32: for every (dx, dy)
    stencil column, the sorted-pool range [s, s+n) covering the z-run of ≤3
    boxes — ``[starts[k_lo], starts[k_hi]+counts[k_hi])`` with clipped
    endpoints, zero-length where the column falls outside the grid.
    Candidates are *box-level*; callers apply the radius test.
    """
    dims = spec.dims
    cell = morton.cell_of(query_pos, grid.origin, grid.box_size, dims)   # (Q,3)
    off = jnp.asarray(_RUN_OFFSETS)                                      # (9,2)
    nx = cell[:, None, 0] + off[None, :, 0]                              # (Q,9)
    ny = cell[:, None, 1] + off[None, :, 1]
    inside = ((nx >= 0) & (nx < dims[0]) & (ny >= 0) & (ny < dims[1]))
    nx = jnp.clip(nx, 0, dims[0] - 1)
    ny = jnp.clip(ny, 0, dims[1] - 1)
    z_lo = jnp.maximum(cell[:, 2] - 1, 0)[:, None]                       # (Q,1)
    z_hi = jnp.minimum(cell[:, 2] + 1, dims[2] - 1)[:, None]
    k_lo = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_lo, nx.shape), dims)
    k_hi = morton.linear_encode3(nx, ny, jnp.broadcast_to(z_hi, nx.shape), dims)
    s = grid.starts[k_lo]                                                # (Q,9)
    e = grid.starts[k_hi] + grid.counts[k_hi]
    n = jnp.where(inside, e - s, 0)
    return s, n


def neighbor_runs(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbors as *sorted-pool positions*, all 9 runs materialized.

    query_pos: (Q, 3). Returns (pos, valid): (Q, 9·R) int32 positions into the
    key-sorted pool and bool mask. The wide form of :func:`run_bounds` — hot
    paths stream the runs one at a time instead (:func:`resident_apply`).
    """
    r_cap = spec.run_capacity
    s, n = run_bounds(spec, grid, query_pos)
    lane = jnp.arange(r_cap, dtype=jnp.int32)                            # (R,)
    pos = s[..., None] + lane                                            # (Q,9,R)
    valid = lane < jnp.minimum(n, r_cap)[..., None]
    pos = jnp.where(valid, pos, 0)
    q = query_pos.shape[0]
    return pos.reshape(q, 9 * r_cap), valid.reshape(q, 9 * r_cap)


def neighbor_candidates(spec: GridSpec, grid: GridState, query_pos: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate neighbor *slot ids* for each query position (compat wrapper).

    query_pos: (Q, 3). Returns (ids, valid): (Q, 9·R) int32 slot ids and bool
    mask. Prefer :func:`neighbor_runs` + sorted channels on hot paths — slot
    ids re-randomize the gather order this layout exists to avoid.
    """
    pos, valid = neighbor_runs(spec, grid, query_pos)
    return grid.order[pos], valid


def sort_channels(grid: GridState, channels: Dict[str, jnp.ndarray]
                  ) -> Dict[str, jnp.ndarray]:
    """Channels reordered by grid key — neighbor runs become contiguous reads.

    Non-resident compat only (distributed engine, Fig-11 baselines): under
    :func:`build_resident` the pool itself is already in this order and no
    copy exists to make.
    """
    return {k: v[grid.order] for k, v in channels.items()}


def chunk_apply(channels: Dict[str, jnp.ndarray],
                gather_channels: Dict[str, jnp.ndarray],
                query_idx: jnp.ndarray,
                n_query: jnp.ndarray,
                cand_fn: Callable[[jnp.ndarray, jnp.ndarray],
                                  Tuple[jnp.ndarray, jnp.ndarray]],
                pair_fn: Callable[[Dict[str, jnp.ndarray],
                                   Dict[str, jnp.ndarray],
                                   jnp.ndarray, jnp.ndarray], Dict[str, jnp.ndarray]],
                out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                chunk: int,
                pvary_axes: Tuple[str, ...] = (),
                ) -> Dict[str, jnp.ndarray]:
    """The one chunked query loop shared by every environment (DESIGN.md §3.4).

    The chunk loop has a *dynamic* trip count ⌈n_query / chunk⌉ — with
    static-region detection on, compute really does shrink with the active set
    (paper §5 / O6; DESIGN.md §2).

    channels: full per-slot SoA dict (what q entries are sliced from).
    gather_channels: dict neighbor candidates are gathered from — the
      key-sorted copy for the uniform grid (contiguous runs), the raw slot
      view for scatter/hash/brute environments.
    query_idx: (C,) int32 — compacted active slots (tail padded, see
      compaction.active_index_list); n_query: traced count.
    cand_fn(q_pos, q_slot) -> (idx, valid): candidate indices *into
      gather_channels* and validity (self-exclusion included).
    pair_fn(q, nbr, valid, q_slot) -> dict of per-query reductions; q entries
      are (B, ...) chunk slices, nbr entries are (B, W, ...) gathers, valid is
      (B, W) bool, q_slot is (B,) the query slot ids. May return a subset of
      out_specs (missing outputs keep their zeros).
    out_specs: name → (shape_suffix, dtype) of per-agent outputs; results are
      scattered back to slot positions, zeros elsewhere.

    This is the single-phase special case of :func:`phased_chunk_apply` —
    one candidate slab of full width W instead of n_phases streamed slabs.
    """
    return phased_chunk_apply(channels, gather_channels, query_idx, n_query,
                              lambda q_pos, q_slot, j: cand_fn(q_pos, q_slot),
                              1, pair_fn, out_specs, chunk, pvary_axes)


def neighbor_apply(spec: GridSpec,
                   grid: GridState,
                   channels: Dict[str, jnp.ndarray],
                   query_idx: jnp.ndarray,
                   n_query: jnp.ndarray,
                   pair_fn: Callable,
                   out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                   pvary_axes: Tuple[str, ...] = (),
                   ) -> Dict[str, jnp.ndarray]:
    """Apply ``pair_fn`` over each query agent's run candidates, chunked.

    Non-resident compat path: sorts a channel copy once (the runs then gather
    contiguous spans) and resolves candidates inline per chunk. The engine's
    hot path is :func:`build_resident` + :func:`resident_apply`, which needs
    neither the copy nor the slot-id indirection.
    """
    sorted_ch = sort_channels(grid, channels)

    def cand_fn(q_pos, q_slot):
        pos, valid = neighbor_runs(spec, grid, q_pos)
        valid &= pos != grid.rank[q_slot][:, None]          # exclude self
        return pos, valid

    return chunk_apply(channels, sorted_ch, query_idx, n_query, cand_fn,
                       pair_fn, out_specs, spec.query_chunk, pvary_axes)


def resident_apply(spec: GridSpec,
                   grid: GridState,
                   channels: Dict[str, jnp.ndarray],
                   query_mask: jnp.ndarray,
                   pair_fn: Callable,
                   out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                   chunk: Optional[int] = None,
                   pvary_axes: Tuple[str, ...] = (),
                   ) -> Dict[str, jnp.ndarray]:
    """Run-streaming neighbor apply over the RESIDENT grid-ordered pool.

    ``channels`` must be in grid-key order (from :func:`build_resident` —
    sorted position == slot id). The loop differs from :func:`chunk_apply`
    in three load-bearing ways (DESIGN.md §3.2):

      * **Contiguous queries.** A query block is a ``dynamic_slice`` of the
        pool, not a gather through an index list; outputs are written back
        with ``dynamic_update_slice``, not scatter-add.
      * **Run streaming.** The 3×3×3 stencil is consumed as 9 sequential
        z-run gathers of width R accumulated into the per-block outputs —
        peak candidate footprint B×R instead of the B×9R materialized
        matrix, and each gather reads one contiguous span.
      * **Block-granular static skipping (paper §5 / O6).** Only blocks
        containing ≥1 ``query_mask`` row are visited: the trip count is the
        *dynamic* number of active blocks (compaction.active_block_list).
        The resident order clusters spatially-quiescent agents into the same
        blocks, which is what makes the skip rate track the static fraction.

    ``pair_fn`` outputs must be additive across splits of the candidate axis
    (sums/counts — encode an OR-style reduction as a count and threshold it).
    Outputs are written for ``query_mask`` rows, zeros elsewhere.
    """
    c = channels["position"].shape[0]
    b = min(chunk if chunk is not None else spec.query_chunk, c)
    r_cap = spec.run_capacity
    blk_idx, n_blk = compaction.active_block_list(query_mask, b)
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {k: _pcast_varying(v, pvary_axes) for k, v in outs.items()}
    lane = jnp.arange(r_cap, dtype=jnp.int32)

    def body(i, outs):
        # clamp the window so a trailing partial block stays in range; overlap
        # rows recompute identical values (pure per-row function of channels)
        sl = jnp.minimum(blk_idx[i] * b, c - b)
        rows = sl + jnp.arange(b, dtype=jnp.int32)                       # (B,)
        q = {k: jax.lax.dynamic_slice_in_dim(v, sl, b, axis=0)
             for k, v in channels.items()}
        qmask = jax.lax.dynamic_slice_in_dim(query_mask, sl, b, axis=0)
        s, n = run_bounds(spec, grid, q["position"])                     # (B,9)
        n = jnp.minimum(n, r_cap)

        def run(j, acc):
            pos = s[:, j, None] + lane                                   # (B,R)
            valid = lane[None, :] < n[:, j, None]
            valid &= pos != rows[:, None]          # resident: position == slot
            pos = jnp.where(valid, pos, 0)
            nbr = {k: v[pos] for k, v in channels.items()}
            res = pair_fn(q, nbr, valid, rows)
            return {name: acc[name] + res[name].astype(acc[name].dtype)
                    if name in res else acc[name] for name in acc}

        acc0 = {name: jnp.zeros((b, *sfx), dt)
                for name, (sfx, dt) in out_specs.items()}
        if pvary_axes:   # inner carry must match the varying results it sums
            acc0 = {k: _pcast_varying(v, pvary_axes) for k, v in acc0.items()}
        acc = jax.lax.fori_loop(0, 9, run, acc0)
        new_outs = {}
        for name, val in acc.items():
            val = jnp.where(qmask.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = jax.lax.dynamic_update_slice_in_dim(
                outs[name], val, sl, axis=0)
        return new_outs

    return jax.lax.fori_loop(0, n_blk, body, outs)


def phased_chunk_apply(channels: Dict[str, jnp.ndarray],
                       gather_channels: Dict[str, jnp.ndarray],
                       query_idx: jnp.ndarray,
                       n_query: jnp.ndarray,
                       phase_fn: Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray],
                                          Tuple[jnp.ndarray, jnp.ndarray]],
                       n_phases: int,
                       pair_fn: Callable,
                       out_specs: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]],
                       chunk: int,
                       pvary_axes: Tuple[str, ...] = (),
                       ) -> Dict[str, jnp.ndarray]:
    """:func:`chunk_apply` with the candidate axis split into streamed phases.

    ``phase_fn(q_pos, q_slot, j)`` resolves the j'th candidate slab (idx,
    valid) of fixed width W; the inner loop accumulates ``pair_fn`` results
    across the ``n_phases`` slabs, so peak candidate footprint is B×W instead
    of B×(n_phases·W). The same additive-output contract as
    :func:`resident_apply` applies (``pair_fn`` may return a subset of
    out_specs). Used by the hash-grid environment (27 single-box probes —
    the wide form was its Fig-11 pathology) and, with ``n_phases=1``, as the
    body of :func:`chunk_apply`.
    """
    c = channels["position"].shape[0]
    b = min(chunk, c)
    n_chunks_max = (c + b - 1) // b
    # pad so dynamic_slice never clamps (clamping would desync q_slot vs lane_ok)
    qi = jnp.pad(query_idx, (0, n_chunks_max * b - c))
    outs = {name: jnp.zeros((c, *sfx), dt) for name, (sfx, dt) in out_specs.items()}
    if pvary_axes:   # under shard_map: mark the carry varying on those axes
        outs = {k: _pcast_varying(v, pvary_axes) for k, v in outs.items()}

    def body(i, outs):
        sl = i * b
        q_slot = jax.lax.dynamic_slice(qi, (sl,), (b,))                  # (B,)
        lane_ok = (sl + jnp.arange(b)) < n_query                         # (B,)
        q = {k: v[q_slot] for k, v in channels.items()}

        def phase(j, acc):
            idx, valid = phase_fn(q["position"], q_slot, j)
            valid &= lane_ok[:, None]
            nbr = {k: v[idx] for k, v in gather_channels.items()}
            res = pair_fn(q, nbr, valid, q_slot)
            return {name: acc[name] + res[name].astype(acc[name].dtype)
                    if name in res else acc[name] for name in acc}

        acc0 = {name: jnp.zeros((b, *sfx), dt)
                for name, (sfx, dt) in out_specs.items()}
        if pvary_axes:   # inner carry must match the varying results it sums
            acc0 = {k: _pcast_varying(v, pvary_axes) for k, v in acc0.items()}
        if n_phases == 1:
            acc = phase(jnp.int32(0), acc0)
        else:
            acc = jax.lax.fori_loop(0, n_phases, phase, acc0)
        new_outs = {}
        for name, val in acc.items():
            val = jnp.where(
                lane_ok.reshape((b,) + (1,) * (val.ndim - 1)), val, 0)
            new_outs[name] = outs[name].at[q_slot].add(
                val.astype(outs[name].dtype), mode="drop")
        return new_outs

    n_chunks = jnp.minimum((n_query + b - 1) // b, n_chunks_max)
    return jax.lax.fori_loop(0, n_chunks, body, outs)


# ---------------------------------------------------------------------------
# Alternative environments (Fig 11 comparison)
# ---------------------------------------------------------------------------

def brute_force_apply(channels: Dict[str, jnp.ndarray],
                      alive: jnp.ndarray,
                      pair_fn,
                      out_specs,
                      chunk: int = 512) -> Dict[str, jnp.ndarray]:
    """Exact O(N²) neighbor apply (oracle + Fig-11 baseline).

    pair_fn has the same signature as in neighbor_apply; candidates are *all*
    agents (``valid`` carries alive & not-self; the radius test is pair_fn's
    own distance mask, identical to the grid path).
    """
    c = channels["position"].shape[0]
    chunk = min(chunk, c)
    ids = jnp.arange(c, dtype=jnp.int32)

    def cand_fn(q_pos, q_slot):
        b = q_slot.shape[0]
        idx = jnp.broadcast_to(ids[None], (b, c))
        valid = alive[None, :] & (idx != q_slot[:, None])
        return idx, valid

    q_idx = jnp.arange(c, dtype=jnp.int32)
    return chunk_apply(channels, channels, q_idx, jnp.int32(c), cand_fn,
                       pair_fn, out_specs, chunk)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScatterGridState:
    """'Standard implementation' grid: dense (boxes × K) member table via scatter.

    Models BioDynaMo's *unoptimized* path: the table is re-zeroed and re-scattered
    every iteration, touching O(#boxes · K) memory — the cost the paper's
    timestamp trick (and our sort-based build) avoids.
    """
    origin: jnp.ndarray
    box_size: jnp.ndarray
    table: jnp.ndarray         # (M, K) int32 slot ids, -1 = empty
    counts: jnp.ndarray        # (M,)


def build_scatter_grid(spec: GridSpec, pool: AgentPool, origin, box_size
                       ) -> ScatterGridState:
    m, k = spec.table_size, spec.max_per_box
    keys = morton.linear_keys(pool.position, origin, box_size, spec.dims)
    keys = jnp.where(pool.alive, keys, m)  # park dead at row m (dropped)
    # slot-within-box via sort (the CPU version uses sequential insertion;
    # the data-parallel equivalent needs a sort or atomics — we sort).
    order = jnp.argsort(keys)
    sorted_keys = keys[order]
    first = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    slot_in_box = jnp.arange(keys.shape[0]) - first                  # rank within box
    table = jnp.full((m + 1, k), -1, jnp.int32)
    sk = jnp.minimum(slot_in_box, k - 1)
    table = table.at[sorted_keys.astype(jnp.int32), sk].set(order.astype(jnp.int32),
                                                            mode="drop")
    counts = jnp.zeros((m + 1,), jnp.int32).at[keys.astype(jnp.int32)].add(
        pool.alive.astype(jnp.int32), mode="drop")
    return ScatterGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                            table=table[:m], counts=counts[:m])


def scatter_grid_candidates(spec: GridSpec, g: ScatterGridState, query_pos
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = spec.max_per_box
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)
    ncell = cell[:, None, :] + jnp.asarray(_OFFSETS)[None, :, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    codes = morton.linear_encode3(ncell_c[..., 0], ncell_c[..., 1],
                                  ncell_c[..., 2], spec.dims).astype(jnp.int32)
    members = g.table[codes]                                      # (Q,27,K)
    valid = (members >= 0) & inside[..., None]
    q = query_pos.shape[0]
    return jnp.maximum(members, 0).reshape(q, 27 * k), valid.reshape(q, 27 * k)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HashGridState:
    """Spatial-hash grid with a fixed bucket table (memory-capped alternative).

    ``cell_keys`` holds each slot's *unhashed* linear cell id (dead slots →
    DEAD_KEY): a bucket mixes agents from every cell that hashes to it, so
    queries must re-check the candidate's true cell against the probed
    stencil cell — without it, two stencil cells colliding into one bucket
    would yield the bucket's in-radius agents twice (double-counted force
    and force_nnz).
    """
    origin: jnp.ndarray
    box_size: jnp.ndarray
    keys: jnp.ndarray
    cell_keys: jnp.ndarray
    order: jnp.ndarray
    starts: jnp.ndarray
    counts: jnp.ndarray
    max_bucket_count: jnp.ndarray


# default probe gather width multiplier: hash collisions inflate buckets, so
# queries gather HASH_K_MULT×max_per_box per bucket; a bucket fuller than that
# truncates → flagged via stats["box_overflow"] (engine, DESIGN.md §4.2)
HASH_K_MULT = 4


def _hash_cell(cell: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    # classic 3-prime spatial hash (Teschner et al.)
    p = jnp.asarray([73856093, 19349663, 83492791], jnp.uint32)
    h = (cell[..., 0].astype(jnp.uint32) * p[0]
         ^ cell[..., 1].astype(jnp.uint32) * p[1]
         ^ cell[..., 2].astype(jnp.uint32) * p[2])
    return h % jnp.uint32(n_buckets)


def build_hash_grid(spec: GridSpec, pool: AgentPool, origin, box_size,
                    n_buckets: int = 1 << 14) -> HashGridState:
    cell = morton.cell_of(pool.position, origin, box_size, spec.dims)
    keys = _hash_cell(cell, n_buckets)
    keys = jnp.where(pool.alive, keys, jnp.uint32(n_buckets))
    cell_keys = jnp.where(pool.alive,
                          morton.linear_encode3(cell[..., 0], cell[..., 1],
                                                cell[..., 2], spec.dims),
                          morton.DEAD_KEY)
    order = jnp.argsort(keys).astype(jnp.int32)
    sorted_keys = keys[order]
    bucket_ids = jnp.arange(n_buckets, dtype=jnp.uint32)
    starts = jnp.searchsorted(sorted_keys, bucket_ids, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(sorted_keys, bucket_ids, side="right").astype(jnp.int32)
    counts = (ends - starts).astype(table_count_dtype(pool.capacity))
    return HashGridState(origin=jnp.asarray(origin), box_size=jnp.asarray(box_size),
                         keys=keys, cell_keys=cell_keys, order=order,
                         starts=starts, counts=counts,
                         max_bucket_count=jnp.max(counts))


def hash_grid_probe(spec: GridSpec, g: HashGridState, query_pos,
                    j: jnp.ndarray, k_mult: int = HASH_K_MULT
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Candidates of the j'th stencil box only — one streamed hash probe.

    phase_fn for :func:`phased_chunk_apply` (27 phases): capacity per probe is
    one bucket (k_mult·max_per_box), not 27 buckets at once. This is the fix
    for the Fig-11 hash-grid pathology: the wide (Q, 27·K_hash) candidate
    matrix was ~12× the uniform grid's and dominated its search time.

    Candidates are filtered to the probed cell's true members (``cell_keys``
    re-check): without it, two stencil cells hashing to one bucket would
    double-count the bucket's in-radius agents across phases.
    """
    n_buckets = g.starts.shape[0]       # from the build — no mismatch possible
    k = spec.max_per_box * k_mult
    cell = morton.cell_of(query_pos, g.origin, g.box_size, spec.dims)    # (Q,3)
    ncell = cell + jnp.asarray(_OFFSETS)[j][None, :]
    dims = jnp.asarray(spec.dims, jnp.int32)
    inside = jnp.all((ncell >= 0) & (ncell < dims), axis=-1)
    ncell_c = jnp.clip(ncell, 0, dims - 1)
    h = _hash_cell(ncell_c, n_buckets)
    k_true = morton.linear_encode3(ncell_c[..., 0], ncell_c[..., 1],
                                   ncell_c[..., 2], spec.dims)           # (Q,)
    s = g.starts[h]
    n = jnp.where(inside, g.counts[h], 0)
    lane = jnp.arange(k, dtype=jnp.int32)
    pos = s[:, None] + lane
    valid = lane < jnp.minimum(n, k)[:, None]
    pos = jnp.where(valid, pos, 0)
    ids = g.order[pos]                                                   # (Q,k)
    valid &= g.cell_keys[ids] == k_true[:, None]
    return ids, valid


def hash_grid_candidates(spec: GridSpec, g: HashGridState, query_pos,
                         k_mult: int = HASH_K_MULT
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Wide (Q, 27·k) candidate matrix: all 27 probes of
    :func:`hash_grid_probe` materialized at once. Fig-11 baseline only
    ('hash_grid_wide' — its width is the pathology the streamed probes fix);
    kept as a thin stack over the probe so the two paths cannot diverge.
    """
    probes = [hash_grid_probe(spec, g, query_pos, j, k_mult)
              for j in range(27)]
    return (jnp.concatenate([ids for ids, _ in probes], axis=1),
            jnp.concatenate([valid for _, valid in probes], axis=1))
