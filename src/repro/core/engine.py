"""Simulation engine — paper §2 Algorithm 1, one fused XLA program per iteration.

Iteration structure (paper L2–L19):
  pre-standalone ops:   resident grid rebuild (§3.1 + §4.2: ONE permutation
                        grid-orders the pool, sorts agents in memory, and
                        compacts the dead — the periodic Morton sort is a
                        no-op special case of it), diffusion step, static-flag
                        update (§5, box-granular, from last iteration's
                        bookkeeping)
  agent ops:            mechanical forces over the *active blocks* only
                        (§5 skipping at block granularity, run-streaming),
                        displacement integration, behaviors
  post-standalone ops:  death compaction + birth commit (§3.2), statistics

The paper's two thread barriers (L6/L15) vanish: under jit the whole iteration
is a single XLA program — the strongest possible form of 'maximize the parallel
part' (Amdahl, paper Challenge 1).

Environment selection mirrors the paper's environment interface: the optimized
uniform grid (default), the scatter-table 'standard' grid, or brute force
(Fig 11 comparison).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import compaction, diffusion as diff_mod, forces as force_mod, grid as grid_mod
from . import morton, statics as statics_mod
from .agents import AgentPool, make_pool
from .behaviors import Behavior, BehaviorEffects


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration (part of the jit closure)."""
    capacity: int
    domain_lo: Tuple[float, float, float]
    domain_hi: Tuple[float, float, float]
    interaction_radius: float
    dt: float = 1.0
    use_forces: bool = True
    detect_static: bool = False            # paper detect_static_agents
    sort_frequency: int = 0                # paper Fig 12 (0 = never sort).
                                           # Resident environments
                                           # (uniform_grid/brute_force) sort
                                           # every step as part of the grid
                                           # build; this only drives the
                                           # Morton sort of scatter/hash envs.
    environment: str = "uniform_grid"      # uniform_grid | scatter_grid | hash_grid | brute_force
    force_impl: str = "xla"                # xla | pallas (K1 windowed kernel;
                                           # interpret mode on CPU, native on TPU)
    max_per_box: int = 16
    max_per_run: Optional[int] = None      # gather width per 3-box z-run (None → 3·K)
    query_chunk: int = 2048
    adhesion: Optional[Tuple[Tuple[float, ...], ...]] = None  # type adhesion matrix
    force: force_mod.ForceParams = dataclasses.field(default_factory=force_mod.ForceParams)
    diffusion: Optional[diff_mod.DiffusionSpec] = None
    diffusion_substeps: int = 1

    @property
    def grid_spec(self) -> grid_mod.GridSpec:
        dims = tuple(max(1, int(math.ceil((hi - lo) / self.interaction_radius)))
                     for lo, hi in zip(self.domain_lo, self.domain_hi))
        return grid_mod.GridSpec(dims=dims, max_per_box=self.max_per_box,
                                 max_per_run=self.max_per_run,
                                 query_chunk=self.query_chunk)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    pool: AgentPool
    conc: jnp.ndarray                    # diffusion grid ((1,1,1) dummy if unused)
    rng: jax.Array
    iteration: jnp.ndarray               # () int32
    stats: Dict[str, jnp.ndarray]        # per-iteration scalars


@dataclasses.dataclass
class StepContext:
    """What behaviors may read/use during one iteration."""
    config: EngineConfig
    dt: float
    domain_lo: jnp.ndarray
    domain_hi: jnp.ndarray
    iteration: jnp.ndarray
    neighbor_apply: Callable                 # (pair_fn, out_specs) -> dict
    substance_gradient: Callable             # positions -> (N, 3)
    substance_value: Callable                # positions -> (N,)


class Simulation:
    """Builds and runs the jitted iteration for a config + behavior list."""

    def __init__(self, config: EngineConfig, behaviors: Sequence[Behavior] = ()):
        self.config = config
        self.behaviors = list(behaviors)
        self.spec = config.grid_spec
        if config.force_impl == "pallas" and config.environment != "uniform_grid":
            raise ValueError("force_impl='pallas' requires the uniform_grid "
                             "environment (the kernel consumes its resident "
                             "grid tables)")
        self._step_fn = jax.jit(self._build_step())

    # -- state construction -------------------------------------------------
    def init_state(self, position, diameter=None, agent_type=None,
                   extra_init: Dict[str, jnp.ndarray] | None = None,
                   seed: int = 0) -> EngineState:
        specs: Dict[str, tuple] = {}
        for b in self.behaviors:
            specs.update(b.extra_specs())
        pool = make_pool(self.config.capacity, position=jnp.asarray(position),
                         diameter=None if diameter is None else jnp.asarray(diameter),
                         agent_type=None if agent_type is None else jnp.asarray(agent_type),
                         extra_specs=specs)
        if extra_init:
            n = jnp.asarray(position).shape[0]
            for k, v in extra_init.items():
                pool.extra[k] = pool.extra[k].at[:n].set(jnp.asarray(v))
        dspec = self.config.diffusion
        conc = jnp.zeros(dspec.dims, jnp.float32) if dspec else jnp.zeros((1, 1, 1))
        stats = {k: jnp.zeros((), jnp.int32) for k in
                 ("n_live", "n_active", "births", "deaths", "box_overflow",
                  "birth_overflow")}
        return EngineState(pool=pool, conc=conc, rng=jax.random.PRNGKey(seed),
                           iteration=jnp.zeros((), jnp.int32), stats=stats)

    # -- environment dispatch ------------------------------------------------
    def _make_neighbor_apply(self, pool: AgentPool, grid_env, channels):
        """One neighbor_apply closure per step.

        Every closure takes ``(pair_fn, out_specs, query_mask=None)`` — the
        mask defaults to the live set. The uniform grid runs the resident
        run-streaming loop (grid.resident_apply): contiguous query slices,
        9 streamed z-runs at width R, and whole-block skipping driven by the
        mask (§5/O6 — this is where static blocks drop out of the trip
        count). The hash grid streams its 27 probes through
        grid.phased_chunk_apply; scatter ('standard implementation') and
        brute force keep the wide chunk_apply loop.
        """
        cfg, spec = self.config, self.spec

        if cfg.environment == "uniform_grid":
            def apply(pair_fn, out_specs, query_mask=None):
                if query_mask is None:
                    query_mask = pool.alive
                return grid_mod.resident_apply(spec, grid_env, channels,
                                               query_mask, pair_fn, out_specs,
                                               cfg.query_chunk)
            return apply

        if cfg.environment == "hash_grid":
            def phase_fn(q_pos, q_slot, j):
                ids, valid = grid_mod.hash_grid_probe(spec, grid_env, q_pos, j)
                valid &= ids != q_slot[:, None]              # exclude self
                return ids, valid

            def apply(pair_fn, out_specs, query_mask=None):
                if query_mask is None:
                    query_mask = pool.alive
                query_idx, n_query = compaction.active_index_list(query_mask)
                return grid_mod.phased_chunk_apply(
                    channels, channels, query_idx, n_query, phase_fn, 27,
                    pair_fn, out_specs, cfg.query_chunk)
            return apply

        if cfg.environment == "scatter_grid":
            def box_cand(qp):
                return grid_mod.scatter_grid_candidates(spec, grid_env, qp)
        elif cfg.environment == "brute_force":
            ids_all = jnp.arange(pool.capacity, dtype=jnp.int32)

            def box_cand(qp):
                q = qp.shape[0]
                ids = jnp.broadcast_to(ids_all[None], (q, pool.capacity))
                valid = jnp.broadcast_to(pool.alive[None], (q, pool.capacity))
                return ids, valid
        else:
            raise ValueError(f"unknown environment {cfg.environment}")

        def cand_fn(q_pos, q_slot):
            ids, valid = box_cand(q_pos)
            valid &= ids != q_slot[:, None]                  # exclude self
            return ids, valid

        def apply(pair_fn, out_specs, query_mask=None):
            if query_mask is None:
                query_mask = pool.alive
            query_idx, n_query = compaction.active_index_list(query_mask)
            return grid_mod.chunk_apply(channels, channels, query_idx, n_query,
                                        cand_fn, pair_fn, out_specs,
                                        cfg.query_chunk)
        return apply

    def _build_env(self, pool, origin, box_size):
        """Build the iteration's environment.

        Resident environments (uniform_grid, and brute_force — which keeps
        the grid for statics bookkeeping) return a *permuted pool* alongside
        the grid state: the pool itself is the key-sorted layout
        (grid.build_resident). Scatter/hash return the pool unchanged.
        """
        cfg, spec = self.config, self.spec
        if cfg.environment in ("uniform_grid", "brute_force"):
            pool, genv, _ = grid_mod.build_resident(spec, pool, origin, box_size)
            return pool, genv
        if cfg.environment == "scatter_grid":
            return pool, grid_mod.build_scatter_grid(spec, pool, origin, box_size)
        if cfg.environment == "hash_grid":
            return pool, grid_mod.build_hash_grid(spec, pool, origin, box_size)
        raise ValueError(cfg.environment)

    # -- the iteration -------------------------------------------------------
    def _build_step(self):
        cfg = self.config
        spec = self.spec
        behaviors = self.behaviors
        origin = jnp.asarray(cfg.domain_lo, jnp.float32)
        dlo = jnp.asarray(cfg.domain_lo, jnp.float32)
        dhi = jnp.asarray(cfg.domain_hi, jnp.float32)
        box_size = jnp.asarray(cfg.interaction_radius, jnp.float32)
        adhesion = (jnp.asarray(cfg.adhesion, jnp.float32)
                    if cfg.adhesion is not None else None)
        force_pair = force_mod.make_force_pair_fn(cfg.force, adhesion)

        def sort_pool(pool: AgentPool) -> AgentPool:
            keys = morton.morton_keys(pool.position, origin, box_size, spec.dims)
            keys = jnp.where(pool.alive, keys, grid_mod._DEAD_KEY)
            order = jnp.argsort(keys).astype(jnp.int32)
            return compaction.apply_permutation(pool, order)

        def step(state: EngineState) -> EngineState:
            pool = state.pool
            it = state.iteration
            rng, k_force, *bkeys = jax.random.split(state.rng, 2 + len(behaviors))
            stats = dict(state.stats)

            # ---------------- pre standalone ops ----------------
            # Resident envs reorder every build (the permutation IS the §4.2
            # sort); the periodic Morton sort only serves scatter/hash.
            if cfg.sort_frequency > 0 and cfg.environment in ("scatter_grid",
                                                              "hash_grid"):
                pool = jax.lax.cond(it % cfg.sort_frequency == 0,
                                    sort_pool, lambda p: p, pool)
            pool, grid_env = self._build_env(pool, origin, box_size)
            if cfg.environment == "uniform_grid":
                # query exactness bound: every 3-box z-run must fit the run
                # gather capacity (DESIGN.md §4.2 overflow contract)
                stats["box_overflow"] = (grid_env.max_run_count
                                         > spec.run_capacity).astype(jnp.int32)
            elif cfg.environment == "hash_grid":
                # same contract: a bucket fuller than the probe gather width
                # would silently truncate candidates (grid.hash_grid_probe)
                stats["box_overflow"] = (
                    grid_env.max_bucket_count
                    > grid_mod.HASH_K_MULT * spec.max_per_box).astype(jnp.int32)

            conc = state.conc
            if cfg.diffusion is not None:
                sub_dt = cfg.dt / cfg.diffusion_substeps
                for _ in range(cfg.diffusion_substeps):
                    conc = diff_mod.step(cfg.diffusion, conc, sub_dt)

            channels = {k: v for k, v in pool.channels().items()
                        if not k.startswith("extra.")}
            nbr_apply = self._make_neighbor_apply(pool, grid_env, channels)

            # static flags from last iteration's bookkeeping (paper §5):
            # box-granular aggregation over the grid tables — no extra
            # neighbor sweep (statics.py)
            if cfg.detect_static and cfg.environment in ("uniform_grid",
                                                         "brute_force"):
                static = statics_mod.update_static_flags(pool, spec, grid_env,
                                                         it)
                pool = dataclasses.replace(pool, static=static)

            pos0 = pool.position
            dia0 = pool.diameter

            # ---------------- agent ops: forces ----------------
            active = None
            if cfg.use_forces:
                if cfg.detect_static:
                    active = pool.alive & ~pool.static
                else:
                    active = pool.alive
                if cfg.force_impl == "pallas":
                    # K1 over the resident layout: the kernel consumes the
                    # step's grid tables directly (no sort/unsort) and skips
                    # fully-static row blocks (kernels/ops.py)
                    from ..kernels import ops as kops
                    f, nnz, ovf = kops.collision_force_resident(
                        pool.position, pool.diameter, pool.agent_type,
                        pool.alive, active, grid_env.starts, grid_env.counts,
                        origin, box_size,
                        dims=spec.dims, k_rep=cfg.force.k_rep,
                        adhesion=cfg.adhesion,
                        adhesion_band=cfg.force.adhesion_band)
                    # column-map overflow means possibly-missed pairs: surface
                    # it through the same never-silent contract (DESIGN.md §4.2)
                    stats["box_overflow"] = jnp.maximum(
                        stats["box_overflow"], ovf.astype(jnp.int32))
                    res = {"force": f, "force_nnz": nnz}
                else:
                    res = nbr_apply(force_pair,
                                    {"force": ((3,), jnp.float32),
                                     "force_nnz": ((), jnp.int32)},
                                    query_mask=active)
                dx = force_mod.displacement(res["force"], cfg.force, cfg.dt)
                new_pos = jnp.clip(pool.position + dx, dlo, dhi)
                new_pos = jnp.where(active[:, None], new_pos, pool.position)
                force_nnz = jnp.where(active, res["force_nnz"], pool.force_nnz)
                pool = dataclasses.replace(pool, position=new_pos,
                                           force_nnz=force_nnz)

            # ---------------- agent ops: behaviors ----------------
            ctx = StepContext(
                config=cfg, dt=cfg.dt, domain_lo=dlo, domain_hi=dhi,
                iteration=it, neighbor_apply=nbr_apply,
                substance_gradient=(
                    (lambda p: diff_mod.gradient(cfg.diffusion, conc, p, origin))
                    if cfg.diffusion else (lambda p: jnp.zeros_like(p))),
                substance_value=(
                    (lambda p: diff_mod.sample(cfg.diffusion, conc, p, origin))
                    if cfg.diffusion else (lambda p: jnp.zeros(p.shape[:-1]))),
            )
            birth_queues: List[Tuple[Dict[str, jnp.ndarray], jnp.ndarray]] = []
            death_mask = jnp.zeros((pool.capacity,), bool)
            for b, bk in zip(behaviors, bkeys):
                eff = b(ctx, pool, bk)
                if eff.set_channels:
                    ch = pool.channels()
                    for name, val in eff.set_channels.items():
                        ch[name] = val
                    pool = pool.with_channels(ch)
                if eff.birth_channels is not None:
                    birth_queues.append((eff.birth_channels, eff.birth_valid))
                if eff.death_mask is not None:
                    death_mask |= eff.death_mask
                if eff.secretion is not None and cfg.diffusion is not None:
                    conc = diff_mod.add_sources(cfg.diffusion, conc,
                                                pool.position, eff.secretion,
                                                origin)

            # bookkeeping for the next static detection
            move_d = pool.position - pos0
            moved = jnp.sum(move_d * move_d, -1) > cfg.force.move_eps ** 2
            grew = pool.diameter > dia0 + 1e-12
            pool = dataclasses.replace(pool, moved=moved & pool.alive,
                                       grew=grew & pool.alive)

            # ---------------- post standalone ops: commit ----------------
            deaths = jnp.sum((death_mask & pool.alive).astype(jnp.int32))
            stats["deaths"] = deaths
            pool = dataclasses.replace(pool, alive=pool.alive & ~death_mask)
            # n_active = force-computed agents still alive at iteration end
            # (counting at force time could exceed n_live after deaths)
            stats["n_active"] = (jnp.sum((active & pool.alive).astype(jnp.int32))
                                 if active is not None else pool.n_live)
            pool = jax.lax.cond(deaths > 0, compaction.compact,
                                lambda p: p, pool)

            births = jnp.zeros((), jnp.int32)
            overflow = jnp.zeros((), jnp.int32)
            for q, valid in birth_queues:
                overflow += compaction.birth_overflow(pool, valid)
                births += jnp.sum(valid.astype(jnp.int32))
                pool = compaction.commit_births(pool, q, valid, it)
            stats["births"] = births
            stats["birth_overflow"] = overflow
            stats["n_live"] = pool.n_live

            return EngineState(pool=pool, conc=conc, rng=rng,
                               iteration=it + 1, stats=stats)

        return step

    # -- public API ----------------------------------------------------------
    def step(self, state: EngineState) -> EngineState:
        return self._step_fn(state)

    def run(self, state: EngineState, n_iterations: int,
            callback: Callable[[int, EngineState], None] | None = None,
            check_overflow: bool = False) -> EngineState:
        """Run ``n_iterations``. With ``check_overflow`` the host checks the
        box/birth overflow flags each iteration and raises — the engine never
        silently drops interactions (DESIGN.md §4.2 fallback contract); callers
        respond by raising ``max_per_box`` / ``capacity`` (a recompile, mirroring
        BioDynaMo's dynamic grid growth)."""
        for i in range(n_iterations):
            state = self._step_fn(state)
            if check_overflow:
                if int(state.stats["box_overflow"]):
                    if self.config.environment == "hash_grid":
                        raise RuntimeError(
                            f"iteration {i}: hash bucket overflow (a bucket "
                            f"holds > {grid_mod.HASH_K_MULT}×max_per_box = "
                            f"{grid_mod.HASH_K_MULT * self.spec.max_per_box} "
                            f"agents); raise EngineConfig.max_per_box")
                    raise RuntimeError(
                        f"iteration {i}: grid run overflow (a 3-box z-run "
                        f"holds > {self.spec.run_capacity} agents); raise "
                        f"EngineConfig.max_per_run / max_per_box")
                if int(state.stats["birth_overflow"]):
                    raise RuntimeError(
                        f"iteration {i}: birth overflow; raise EngineConfig.capacity")
            if callback is not None:
                callback(i, state)
        return state
