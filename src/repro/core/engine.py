"""Simulation engine — paper §2 Algorithm 1, one fused XLA program per iteration.

Iteration structure (paper L2–L19):
  pre-standalone ops:   resident grid rebuild (§3.1 + §4.2: ONE permutation
                        grid-orders the pool, sorts agents in memory, and
                        compacts the dead — the periodic Morton sort is a
                        no-op special case of it), diffusion step, static-flag
                        update (§5, box-granular, from last iteration's
                        bookkeeping)
  agent ops:            mechanical forces over the *active blocks* only
                        (§5 skipping at block granularity, run-streaming),
                        displacement integration, behaviors
  post-standalone ops:  death compaction + birth commit (§3.2), statistics

The paper's two thread barriers (L6/L15) vanish: under jit the whole iteration
is a single XLA program — the strongest possible form of 'maximize the parallel
part' (Amdahl, paper Challenge 1).

**The iteration core is engine-agnostic** (:func:`make_iteration_core`,
DESIGN.md §7): the same body serves the single-device `Simulation` and each
slab of the distributed shard_map engine. The distributed wrapper
parameterizes it with an *owned* channel (local agents vs ghost force-sources
from neighboring slabs), the mesh axes its collectives vary over
(``pvary_axes``), and a sharded `DiffusionOps` — nothing about forces,
behaviors, births/deaths, statics, or diffusion is duplicated per engine.

Environment selection mirrors the paper's environment interface: the optimized
uniform grid (default), the scatter-table 'standard' grid, or brute force
(Fig 11 comparison).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import compaction, diffusion as diff_mod, forces as force_mod, grid as grid_mod
from . import health as health_mod, morton, statics as statics_mod
from .agents import AgentPool, DtypePolicy, make_pool
from .behaviors import Behavior, BehaviorEffects
from .stats import StepStats


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration (part of the jit closure)."""
    capacity: int
    domain_lo: Tuple[float, float, float]
    domain_hi: Tuple[float, float, float]
    interaction_radius: float
    dt: float = 1.0
    use_forces: bool = True
    fused_sweep: bool = True               # evaluate forces + every declared
                                           # behavior kernel against ONE
                                           # pruned candidate stream per
                                           # block (grid.resident_apply_fused;
                                           # uniform_grid only — other
                                           # environments run the sequential
                                           # per-phase sweeps). False keeps
                                           # the sequential path (parity
                                           # tests, breakdown benchmark).
    detect_static: bool = False            # paper detect_static_agents
    sort_frequency: int = 0                # paper Fig 12 (0 = never sort).
                                           # Resident environments
                                           # (uniform_grid/brute_force) sort
                                           # every step as part of the grid
                                           # build; this only drives the
                                           # Morton sort of scatter/hash envs.
    environment: str = "uniform_grid"      # uniform_grid | scatter_grid | hash_grid | brute_force
    force_impl: str = "xla"                # xla | pallas (K1 windowed kernel;
                                           # interpret mode off-TPU, native on TPU)
    max_per_box: int = 16
    max_per_run: Optional[int] = None      # gather width per 3-box z-run (None → 3·K)
    query_chunk: int = 2048
    adhesion: Optional[Tuple[Tuple[float, ...], ...]] = None  # type adhesion matrix
    force: force_mod.ForceParams = dataclasses.field(default_factory=force_mod.ForceParams)
    diffusion: Optional[diff_mod.DiffusionSpec] = None
    diffusion_substeps: int = 1
    rebuild: grid_mod.RebuildPolicy = dataclasses.field(
        default_factory=grid_mod.RebuildPolicy)
                                           # when the grid build runs
                                           # (every_step | every_k with a
                                           # displacement bound; grid.py)
    pairlist: Optional[grid_mod.PairListConfig] = None
                                           # Verlet pair-list stage: at each
                                           # rebuild, compact the in-range(+
                                           # skin) candidates into a fixed
                                           # (C, max_pairs) table and serve
                                           # the fused sweep from it
                                           # (grid.build_pairlist; None keeps
                                           # the streamed 9-run sweep)
    sort_impl: str = "auto"                # key-sort realization of the grid
                                           # build (grid.SORT_IMPLS): O(N)
                                           # counting sort on host/xla,
                                           # argsort as the parity oracle
    dtypes: DtypePolicy = dataclasses.field(default_factory=DtypePolicy)
                                           # channel storage dtypes (§4.3:
                                           # narrower aux channels → more
                                           # agents per byte per rung)
    health: Optional[health_mod.HealthConfig] = dataclasses.field(
        default_factory=health_mod.HealthConfig)
                                           # in-graph health watchdog folded
                                           # into StepStats.health (§7.5);
                                           # None disables it entirely

    def __post_init__(self):
        if self.sort_impl not in grid_mod.SORT_IMPLS:
            raise ValueError(f"sort_impl must be one of {grid_mod.SORT_IMPLS},"
                             f" got {self.sort_impl!r}")
        if self.rebuild.mode == "every_k":
            if self.environment != "uniform_grid":
                raise ValueError(
                    f"rebuild.mode='every_k' requires "
                    f"environment='uniform_grid' (the cached resident tables "
                    f"are what a skipped step reuses), got "
                    f"environment={self.environment!r}")
            if self.detect_static:
                raise ValueError(
                    "rebuild.mode='every_k' is incompatible with "
                    "detect_static=True: box-granular disturbance "
                    "aggregation (statics.py) reads fresh per-step tables; "
                    "set rebuild=RebuildPolicy() or detect_static=False")
        if self.pairlist is not None:
            if self.environment != "uniform_grid" or not self.fused_sweep:
                raise ValueError(
                    "pairlist requires environment='uniform_grid' and "
                    "fused_sweep=True (the pair table prunes the fused "
                    "resident candidate stream; other environments / the "
                    "sequential sweeps never consume it)")
            if self.detect_static:
                raise ValueError(
                    "pairlist is incompatible with detect_static=True: the "
                    "pair table is built over all live rows while static "
                    "detection re-masks queries per step from fresh tables; "
                    "disable one of the two")
            if self.pairlist.skin > 0 and self.rebuild.mode != "every_k":
                raise ValueError(
                    "pairlist.skin > 0 only pays off under "
                    "rebuild.mode='every_k' (the skin exists to let cached "
                    "lists survive between rebuilds); use skin=0 with "
                    "every-step rebuilds")

    @property
    def cell_size(self) -> float:
        """Grid box edge: the interaction radius, widened by the larger of
        the rebuild policy's displacement bound (stale-table stencils must
        cover every in-radius pair — grid.RebuildPolicy coverage argument)
        and the pair-list skin (a fresh build's 3×3×3 stencil must reach
        every candidate within r + skin for grid.build_pairlist)."""
        skin = self.pairlist.skin if self.pairlist is not None else 0.0
        return self.interaction_radius + max(self.rebuild.cell_slack, skin)

    @property
    def grid_spec(self) -> grid_mod.GridSpec:
        dims = tuple(max(1, int(math.ceil((hi - lo) / self.cell_size)))
                     for lo, hi in zip(self.domain_lo, self.domain_hi))
        return grid_mod.GridSpec(dims=dims, max_per_box=self.max_per_box,
                                 max_per_run=self.max_per_run,
                                 query_chunk=self.query_chunk)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    pool: AgentPool
    conc: jnp.ndarray                    # diffusion grid ((1,1,1) dummy if unused)
    rng: jax.Array
    iteration: jnp.ndarray               # () int32
    stats: StepStats                     # per-iteration counters (stats.py)
    env: Optional[grid_mod.RebuildState] = None
                                         # cached grid build carried across
                                         # steps (RebuildPolicy every_k);
                                         # None under every_step


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScenarioParams:
    """Dynamic per-run scenario knobs, traced INTO the iteration core.

    ``EngineConfig`` is static — every float in it is baked into the jit
    program, so two runs with different dt or β are two compiles. This
    pytree carries the knobs that may differ per run *as traced values*:
    one program serves any parameter point, which is what lets the
    ensemble engine (ensemble.py) vmap hundreds of differently-
    parameterized simulations in lockstep and the simulation service
    (serve/sim_service.py) admit a new parameter point into a free lane
    without recompiling.

    dt:    () float32 — overrides ``cfg.dt`` (None → use the static value).
    force: ForceParams field overrides (e.g. ``{"k_rep": x}``) as traced
           scalars; empty → the static ``cfg.force``. Not supported with
           ``force_impl='pallas'`` (the kernel bakes its constants).
    rates: free-form behavior knobs, exposed to behaviors as
           ``ctx.params`` — a behavior opts in by taking a callable
           parameter (``Infection(beta=lambda ctx: ctx.params["beta"])``,
           behaviors.resolve).

    The dict *key sets* are static structure (part of the jit cache key);
    only the values are traced.
    """
    dt: Optional[jnp.ndarray] = None
    force: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    rates: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, dt: Optional[float] = None,
           force: Optional[Dict[str, float]] = None,
           **rates) -> "ScenarioParams":
        """Scalar-array ScenarioParams from plain Python numbers."""
        return cls(
            dt=None if dt is None else jnp.asarray(dt, jnp.float32),
            force={k: jnp.asarray(v, jnp.float32)
                   for k, v in (force or {}).items()},
            rates={k: jnp.asarray(v) for k, v in rates.items()})


@dataclasses.dataclass
class StepContext:
    """What behaviors may read/use during one iteration."""
    config: EngineConfig
    dt: float
    domain_lo: jnp.ndarray
    domain_hi: jnp.ndarray
    iteration: jnp.ndarray
    owned: jnp.ndarray                       # (C,) bool — live agents this
                                             # engine instance owns; behaviors
                                             # must act on this mask, never on
                                             # pool.alive (under the distributed
                                             # engine, alive also covers ghost
                                             # force-sources whose effects are
                                             # the neighbor shard's to commit)
    neighbor_apply: Callable                 # (pair_fn, out_specs) -> dict
    substance_gradient: Callable             # positions -> (N, 3)
    substance_value: Callable                # positions -> (N,)
    neighbor_results: Dict[str, Dict[str, jnp.ndarray]] = dataclasses.field(
        default_factory=dict)                # fused-sweep outputs, keyed by
                                             # PairKernel.name (empty on the
                                             # sequential path — behaviors
                                             # fall back to neighbor_apply)
    params: Dict[str, jnp.ndarray] = dataclasses.field(
        default_factory=dict)                # ScenarioParams.rates — traced
                                             # per-run behavior knobs ({} when
                                             # the caller passed none)


# -- environment dispatch (module-level: shared by both engines) -------------

_ENV_METHOD = {  # EngineConfig.environment → grid.make_builder method
    "uniform_grid": "resident",
    "brute_force": "resident",   # resident build kept for statics bookkeeping
    "scatter_grid": "scatter",
    "hash_grid": "hash",
}


def build_env(cfg: EngineConfig, spec: grid_mod.GridSpec, pool: AgentPool,
              origin: jnp.ndarray, box_size: jnp.ndarray
              ) -> grid_mod.BuildResult:
    """Build the iteration's environment via the unified builder factory.

    Resident environments (uniform_grid, and brute_force — which keeps the
    grid for statics bookkeeping) come back with a *permuted pool*: the pool
    itself is the key-sorted layout. Scatter/hash leave the pool unchanged.
    The engine consumes the BuildResult overflow surface only for the
    environments whose queries it makes exact through the ladder
    (uniform/hash); the scatter baseline's per-box truncation is deliberate
    'standard implementation' behavior, surfaced in the result but not
    flagged in StepStats.
    """
    if cfg.environment not in _ENV_METHOD:
        raise ValueError(cfg.environment)
    builder = grid_mod.make_builder(spec, method=_ENV_METHOD[cfg.environment],
                                    sort_impl=cfg.sort_impl)
    return builder(pool, origin, box_size)


def make_neighbor_apply(cfg: EngineConfig, spec: grid_mod.GridSpec, grid_env,
                        channels: Dict[str, jnp.ndarray],
                        default_mask: jnp.ndarray,
                        pvary_axes: Tuple[str, ...] = ()):
    """One neighbor_apply closure per step.

    Every closure takes ``(pair_fn, out_specs, query_mask=None)`` — the mask
    defaults to ``default_mask`` (the live *owned* set; ghost rows of a
    distributed slab are gather sources, never queries). The uniform grid
    runs the resident run-streaming loop (grid.resident_apply): contiguous
    query slices, 9 streamed z-runs at width R, and whole-block skipping
    driven by the mask (§5/O6 — this is where static blocks drop out of the
    trip count). The hash grid streams its 27 probes through
    grid.phased_chunk_apply; scatter ('standard implementation') and brute
    force keep the wide chunk_apply loop.
    """
    capacity = channels["position"].shape[0]

    if cfg.environment == "uniform_grid":
        def apply(pair_fn, out_specs, query_mask=None):
            if query_mask is None:
                query_mask = default_mask
            return grid_mod.resident_apply(spec, grid_env, channels,
                                           query_mask, pair_fn, out_specs,
                                           cfg.query_chunk,
                                           pvary_axes=pvary_axes)
        return apply

    if cfg.environment == "hash_grid":
        def phase_fn(q_pos, q_slot, j):
            ids, valid = grid_mod.hash_grid_probe(spec, grid_env, q_pos, j)
            valid &= ids != q_slot[:, None]              # exclude self
            return ids, valid

        def apply(pair_fn, out_specs, query_mask=None):
            if query_mask is None:
                query_mask = default_mask
            query_idx, n_query = compaction.active_index_list(query_mask)
            return grid_mod.phased_chunk_apply(
                channels, channels, query_idx, n_query, phase_fn, 27,
                pair_fn, out_specs, cfg.query_chunk, pvary_axes=pvary_axes)
        return apply

    if cfg.environment == "scatter_grid":
        def box_cand(qp):
            return grid_mod.scatter_grid_candidates(spec, grid_env, qp)
    elif cfg.environment == "brute_force":
        ids_all = jnp.arange(capacity, dtype=jnp.int32)

        def box_cand(qp):
            q = qp.shape[0]
            ids = jnp.broadcast_to(ids_all[None], (q, capacity))
            valid = jnp.broadcast_to(channels["alive"][None], (q, capacity))
            return ids, valid
    else:
        raise ValueError(f"unknown environment {cfg.environment}")

    def cand_fn(q_pos, q_slot):
        ids, valid = box_cand(q_pos)
        valid &= ids != q_slot[:, None]                  # exclude self
        return ids, valid

    def apply(pair_fn, out_specs, query_mask=None):
        if query_mask is None:
            query_mask = default_mask
        query_idx, n_query = compaction.active_index_list(query_mask)
        return grid_mod.chunk_apply(channels, channels, query_idx, n_query,
                                    cand_fn, pair_fn, out_specs,
                                    cfg.query_chunk, pvary_axes=pvary_axes)
    return apply


# -- fused-sweep introspection (CI examples-smoke; DESIGN.md §3.2) -----------

def registered_kernels(cfg: EngineConfig, behaviors: Sequence[Behavior]
                       ) -> List[grid_mod.PairKernel]:
    """The static PairKernel descriptors make_iteration_core registers
    (masks unresolved — they are per-step values)."""
    kernels: List[grid_mod.PairKernel] = []
    if cfg.use_forces:
        adhesion = (jnp.asarray(cfg.adhesion, jnp.float32)
                    if cfg.adhesion is not None else None)
        kernels.append(grid_mod.PairKernel(
            "force", force_mod.make_force_pair_fn(cfg.force, adhesion),
            force_mod.FORCE_OUT_SPECS, reads=force_mod.FORCE_READS))
    for b in behaviors:
        kernels.extend(b.neighbor_kernels())
    return kernels


def realized_footprint(cfg: EngineConfig, behaviors: Sequence[Behavior]
                       ) -> Tuple[str, ...]:
    """Union of channels the step's fused sweep will actually stream."""
    return grid_mod.fused_reads(registered_kernels(cfg, behaviors))


def check_kernel_footprints(cfg: EngineConfig, behaviors: Sequence[Behavior],
                            block: int = 4, width: int = 8
                            ) -> Tuple[str, ...]:
    """Trace every registered kernel against ONLY its declared footprint.

    In a real fused sweep an undeclared read can be masked by another
    kernel's declaration landing the channel in the gathered union; tracing
    each pair_fn in isolation (jax.eval_shape — no FLOPs) makes it a loud
    KeyError regardless. Also validates declared reads and outputs against
    the pool layout. Returns the realized footprint. CI's examples-smoke job
    runs this for every example (examples/check_footprints.py)."""
    pool = stage_pool(max(block, 1), behaviors,
                      jnp.zeros((1, 3), jnp.float32), policy=cfg.dtypes)
    channels = pool.channels()
    for k in registered_kernels(cfg, behaviors):
        missing = [ch for ch in k.reads if ch not in channels]
        if missing:
            raise KeyError(
                f"kernel {k.name!r} declares channels the pool does not "
                f"have: {missing} (pool has {sorted(channels)})")
        # the sweep always slices position for run_bounds, declared or not
        q_names = dict.fromkeys(("position",) + tuple(k.reads))
        q = {ch: jax.ShapeDtypeStruct((block,) + channels[ch].shape[1:],
                                      channels[ch].dtype) for ch in q_names}
        nbr = {ch: jax.ShapeDtypeStruct(
            (block, width) + channels[ch].shape[1:], channels[ch].dtype)
            for ch in k.reads}
        valid = jax.ShapeDtypeStruct((block, width), jnp.bool_)
        rows = jax.ShapeDtypeStruct((block,), jnp.int32)
        try:
            out = jax.eval_shape(k.pair_fn, q, nbr, valid, rows)
        except KeyError as e:
            raise KeyError(
                f"kernel {k.name!r} reads channel {e} it did not declare — "
                f"add it to PairKernel.reads (declared: {k.reads})") from None
        undeclared_out = sorted(set(out) - set(k.out_specs))
        if undeclared_out:
            raise KeyError(
                f"kernel {k.name!r} returns outputs {undeclared_out} "
                f"missing from its out_specs {sorted(k.out_specs)}")
    return realized_footprint(cfg, behaviors)


# -- the iteration core ------------------------------------------------------

def make_iteration_core(cfg: EngineConfig, behaviors: Sequence[Behavior],
                        *, owned_channel: Optional[str] = None,
                        pvary_axes: Tuple[str, ...] = (),
                        diff_ops: Optional[diff_mod.DiffusionOps] = None):
    """Build the pure Algorithm-1 iteration body both engines share.

    Returns ``core(pool, conc, rng, iteration, env, params=None) -> (pool,
    conc, rng, StepStats, env)``: resident build (or cached-build reuse under
    RebuildPolicy every_k — ``env`` carries the grid.RebuildState, None
    under every_step) → run-streaming/Pallas forces → behaviors → effects
    merge → death compaction + birth commit → statics bookkeeping →
    diffusion step — exactly the paper's iteration, over whatever pool view
    the caller hands in.

    owned_channel: name of a bool extra channel distinguishing agents this
      pool view *owns* from ghost force-sources appended by a distributed
      wrapper (None → everything alive is owned, the single-device case).
      Ghosts contribute to neighbor reductions and statics disturbance but
      are never queried, never acted on by behaviors, never counted in stats,
      and never committed — their authoritative step happens on the shard
      that owns them. Newborns inherit owned=True (they are committed by the
      shard that staged them).
    pvary_axes: mesh axes the pool is sharded over (threaded to the query
      loops so their carries are marked varying under shard_map).
    diff_ops: substance-grid strategy (diffusion.DiffusionOps). Defaults to
      the full-grid single-device implementation; the distributed engine
      substitutes slab-sharded ops with face-halo exchange.

    The optional trailing ``params`` argument (a :class:`ScenarioParams`
    pytree of traced scalars) overrides dt / force constants / behavior
    rates at *runtime* — one compiled program serves every parameter point.
    ``params=None`` (both engines' default) keeps the static ``cfg`` values
    and is bit-identical to the pre-params core; the ensemble engine
    (ensemble.py) vmaps the core over a leading lane axis of params.
    """
    if cfg.force_impl == "pallas" and cfg.environment != "uniform_grid":
        raise ValueError("force_impl='pallas' requires the uniform_grid "
                         "environment (the kernel consumes its resident "
                         "grid tables)")
    behaviors = list(behaviors)
    # fused sweep registry (DESIGN.md §3.2): every behavior-declared pair
    # kernel joins the force kernel in ONE resident sweep per step; names key
    # the ctx.neighbor_results handoff, so they must be unique ("force" is
    # the engine's own kernel)
    behavior_kernels = []
    for b in behaviors:
        behavior_kernels.extend(b.neighbor_kernels())
    knames = [k.name for k in behavior_kernels]
    if len(set(knames)) != len(knames) or "force" in knames:
        raise ValueError(
            f"behavior neighbor_kernels() names must be unique and must not "
            f"shadow the engine's 'force' kernel, got {knames} — give each "
            f"behavior instance a distinct .name")
    fused = cfg.fused_sweep and cfg.environment == "uniform_grid"
    spec = cfg.grid_spec
    origin = jnp.asarray(cfg.domain_lo, jnp.float32)
    dlo = jnp.asarray(cfg.domain_lo, jnp.float32)
    dhi = jnp.asarray(cfg.domain_hi, jnp.float32)
    box_size = jnp.asarray(cfg.cell_size, jnp.float32)   # radius + rebuild slack
    adhesion = (jnp.asarray(cfg.adhesion, jnp.float32)
                if cfg.adhesion is not None else None)
    force_pair = force_mod.make_force_pair_fn(cfg.force, adhesion)
    if diff_ops is None and cfg.diffusion is not None:
        diff_ops = diff_mod.DiffusionOps(cfg.diffusion, origin)

    def owned_of(pool: AgentPool) -> jnp.ndarray:
        if owned_channel is None:
            return pool.alive
        return pool.extra[owned_channel].astype(bool) & pool.alive

    def sort_pool(pool: AgentPool) -> AgentPool:
        keys = morton.morton_keys(pool.position, origin, box_size, spec.dims)
        keys = jnp.where(pool.alive, keys, grid_mod._DEAD_KEY)
        order = jnp.argsort(keys).astype(jnp.int32)
        return compaction.apply_permutation(pool, order)

    use_cache = cfg.rebuild.mode == "every_k"
    pl = cfg.pairlist
    pair_radius = (cfg.interaction_radius + pl.skin) if pl is not None else 0.0

    def build_pairs(pool: AgentPool, grid_env) -> Optional[grid_mod.PairList]:
        if pl is None:
            return None
        return grid_mod.build_pairlist(
            spec, grid_env, pool.position, pool.alive,
            radius=pair_radius, max_pairs=pl.max_pairs,
            chunk=cfg.query_chunk, pvary_axes=pvary_axes)

    def core(pool: AgentPool, conc: jnp.ndarray, rng: jax.Array,
             it: jnp.ndarray, env: Optional[grid_mod.RebuildState] = None,
             params: Optional[ScenarioParams] = None):
        rng, k_force, *bkeys = jax.random.split(rng, 2 + len(behaviors))
        stats = StepStats.zeros()

        # dynamic scenario knobs (ScenarioParams): traced dt / force
        # constants replace the static closure values; with params=None the
        # expressions below are the compile-time constants they always were
        dt = cfg.dt if params is None or params.dt is None else params.dt
        if params is not None and params.force:
            if cfg.force_impl == "pallas":
                raise ValueError(
                    "ScenarioParams.force overrides require force_impl='xla' "
                    "(the Pallas kernel bakes its force constants)")
            fp = dataclasses.replace(cfg.force, **params.force)
            fpair = force_mod.make_force_pair_fn(fp, adhesion)
        else:
            fp, fpair = cfg.force, force_pair
        rates = params.rates if params is not None else {}

        # ---------------- pre standalone ops ----------------
        # Resident envs reorder every build (the permutation IS the §4.2
        # sort); the periodic Morton sort only serves scatter/hash.
        if cfg.sort_frequency > 0 and cfg.environment in ("scatter_grid",
                                                          "hash_grid"):
            pool = jax.lax.cond(it % cfg.sort_frequency == 0,
                                sort_pool, lambda p: p, pool)
        rebuilt = jnp.ones((), jnp.int32)
        pairs = None
        if not use_cache:
            res = build_env(cfg, spec, pool, origin, box_size)
            pool, grid_env = res.pool, res.grid
            pairs = build_pairs(pool, grid_env)
        else:
            # every_k (uniform_grid only, enforced by EngineConfig): rebuild
            # when the cache is dirty (structural change last step), the k
            # budget is spent, or accumulated displacement exceeds the bound
            # the widened cells were sized for — otherwise skip the
            # permutation + table build outright and query the stale tables
            # (grid.RebuildPolicy coverage argument). A cached pair list has
            # its own, euclidean budget: it covers every in-range pair only
            # while 2·pair_disp ≤ skin (grid.PairListConfig).
            do_build = (env.dirty | (env.steps_since >= cfg.rebuild.k)
                        | (env.disp_accum > cfg.rebuild.displacement_bound))
            if pl is not None:
                do_build = do_build | (2.0 * env.pair_disp > pl.skin)

            def _fresh(pool, env):
                res = build_env(cfg, spec, pool, origin, box_size)
                return res.pool, grid_mod.RebuildState(
                    grid=res.grid,
                    steps_since=jnp.zeros((), jnp.int32),
                    disp_accum=jnp.zeros((), jnp.float32),
                    dirty=jnp.zeros((), bool),
                    pairs=build_pairs(res.pool, res.grid),
                    pair_disp=(jnp.zeros((), jnp.float32)
                               if pl is not None else None))

            pool, env = jax.lax.cond(do_build, _fresh,
                                     lambda pool, env: (pool, env), pool, env)
            grid_env = env.grid
            pairs = env.pairs
            rebuilt = do_build.astype(jnp.int32)
        box_overflow = stats.box_overflow
        box_demand = stats.box_demand
        if cfg.environment == "uniform_grid":
            # query exactness bound: every 3-box z-run must fit the run
            # gather capacity (DESIGN.md §4.2 overflow contract); the demand
            # is the which-capacity provenance the ladder sizes rungs from
            box_demand = grid_env.max_run_count.astype(jnp.int32)
            box_overflow = (grid_env.max_run_count
                            > spec.run_capacity).astype(jnp.int32)
        elif cfg.environment == "hash_grid":
            # same contract: a bucket fuller than the probe gather width
            # would silently truncate candidates (grid.hash_grid_probe)
            box_demand = grid_env.max_bucket_count.astype(jnp.int32)
            box_overflow = (
                grid_env.max_bucket_count
                > grid_mod.HASH_K_MULT * spec.max_per_box).astype(jnp.int32)
        pair_overflow = stats.pair_overflow
        pair_demand = stats.pair_demand
        if pairs is not None:
            # same never-silent contract as the run/bucket capacities: a row
            # demanding more than max_pairs entries truncated its list; the
            # demand is the which-capacity provenance the ladder sizes the
            # max_pairs rung from (§4.2/§4.3)
            pair_demand = pairs.demand
            pair_overflow = (pairs.demand > pl.max_pairs).astype(jnp.int32)

        if cfg.diffusion is not None:
            sub_dt = dt / cfg.diffusion_substeps
            for _ in range(cfg.diffusion_substeps):
                conc = diff_ops.step(conc, sub_dt)

        channels = {k: v for k, v in pool.channels().items()
                    if not k.startswith("extra.")}
        owned_alive = owned_of(pool)
        nbr_apply = make_neighbor_apply(cfg, spec, grid_env, channels,
                                        default_mask=owned_alive,
                                        pvary_axes=pvary_axes)

        # static flags from last iteration's bookkeeping (paper §5):
        # box-granular aggregation over the grid tables — no extra
        # neighbor sweep (statics.py). Ghost rows carry their owner's
        # bookkeeping, so boundary disturbance crosses shards.
        if cfg.detect_static and cfg.environment in ("uniform_grid",
                                                     "brute_force"):
            static = statics_mod.update_static_flags(pool, spec, grid_env, it)
            pool = dataclasses.replace(pool, static=static)

        pos0 = pool.position
        dia0 = pool.diameter

        # ---------------- agent ops: fused neighbor sweep ----------------
        # Forces and every behavior-declared pair kernel evaluate against ONE
        # candidate stream per block, pruned to the union of their declared
        # channel footprints (grid.resident_apply_fused). Fusing is a pure
        # scheduling change: the sequential path's behavior sweeps read the
        # same pre-force channel snapshot (the nbr_apply closure captures
        # ``channels`` before integration), so per-kernel results are
        # bit-exact vs the per-phase sweeps (tests/test_fused.py).
        active = None
        if cfg.use_forces:
            if cfg.detect_static:
                active = owned_alive & ~pool.static
            else:
                active = owned_alive
        nbr_results: Dict[str, Dict[str, jnp.ndarray]] = {}
        if fused:
            kernels = []
            if cfg.use_forces:
                kernels.append(grid_mod.PairKernel(
                    "force", fpair, force_mod.FORCE_OUT_SPECS,
                    reads=force_mod.FORCE_READS, query_mask=active))
            kernels.extend(behavior_kernels)
            if kernels:
                # extra.* channels join the gatherable set here — a kernel
                # that declares them streams them; nothing else does
                channels_full = pool.channels()
                if cfg.use_forces and cfg.force_impl == "pallas":
                    # K1 stays a single in-kernel pass for the force; the
                    # remaining kernels share one pruned XLA sweep over the
                    # same grid tables (kernels/ops.fused_resident_sweep)
                    from ..kernels import ops as kops
                    nbr_results, ovf = kops.fused_resident_sweep(
                        spec, grid_env, channels_full, kernels,
                        default_mask=owned_alive, origin=origin,
                        box_size=box_size, k_rep=cfg.force.k_rep,
                        adhesion=cfg.adhesion,
                        adhesion_band=cfg.force.adhesion_band,
                        chunk=cfg.query_chunk, pvary_axes=pvary_axes,
                        pairs=pairs)
                    box_overflow = jnp.maximum(box_overflow,
                                               ovf.astype(jnp.int32))
                else:
                    nbr_results = grid_mod.resident_apply_fused(
                        spec, grid_env, channels_full, kernels,
                        default_mask=owned_alive, chunk=cfg.query_chunk,
                        pvary_axes=pvary_axes, pairs=pairs)

        # ---------------- agent ops: forces ----------------
        force_arr = None                  # kept for the health guard below
        if cfg.use_forces:
            if "force" in nbr_results:
                res = nbr_results["force"]
            elif cfg.force_impl == "pallas":
                # K1 over the resident layout: the kernel consumes the
                # step's grid tables directly (no sort/unsort) and skips
                # fully-static row blocks (kernels/ops.py)
                from ..kernels import ops as kops
                f, nnz, ovf = kops.collision_force_resident(
                    pool.position, pool.diameter, pool.agent_type,
                    pool.alive, active, grid_env.starts, grid_env.counts,
                    origin, box_size,
                    dims=spec.dims, k_rep=cfg.force.k_rep,
                    adhesion=cfg.adhesion,
                    adhesion_band=cfg.force.adhesion_band)
                # column-map overflow means possibly-missed pairs: surface
                # it through the same never-silent contract (DESIGN.md §4.2)
                box_overflow = jnp.maximum(box_overflow,
                                           ovf.astype(jnp.int32))
                res = {"force": f, "force_nnz": nnz}
            else:
                res = nbr_apply(fpair, force_mod.FORCE_OUT_SPECS,
                                query_mask=active)
            force_arr = res["force"]
            dx = force_mod.displacement(res["force"], fp, dt)
            new_pos = jnp.clip(pool.position + dx, dlo, dhi)
            new_pos = jnp.where(active[:, None], new_pos, pool.position)
            force_nnz = jnp.where(active, res["force_nnz"],
                                  pool.force_nnz).astype(pool.force_nnz.dtype)
            pool = dataclasses.replace(pool, position=new_pos,
                                       force_nnz=force_nnz)

        # ---------------- agent ops: behaviors ----------------
        ctx = StepContext(
            config=cfg, dt=dt, domain_lo=dlo, domain_hi=dhi,
            iteration=it, owned=owned_alive, neighbor_apply=nbr_apply,
            neighbor_results=nbr_results, params=rates,
            substance_gradient=(
                (lambda p: diff_ops.gradient(conc, p))
                if cfg.diffusion else (lambda p: jnp.zeros_like(p))),
            substance_value=(
                (lambda p: diff_ops.sample(conc, p))
                if cfg.diffusion else (lambda p: jnp.zeros(p.shape[:-1]))),
        )
        birth_queues: List[Tuple[Dict[str, jnp.ndarray], jnp.ndarray]] = []
        death_mask = jnp.zeros((pool.capacity,), bool)
        for b, bk in zip(behaviors, bkeys):
            eff = b(ctx, pool, bk)
            if eff.set_channels:
                ch = pool.channels()
                for name, val in eff.set_channels.items():
                    # behaviors compute in f32/int32; storage keeps the
                    # pool's policy dtype (DtypePolicy, §4.3)
                    ch[name] = val.astype(ch[name].dtype)
                pool = pool.with_channels(ch)
            if eff.birth_channels is not None:
                birth_queues.append((eff.birth_channels, eff.birth_valid))
            if eff.death_mask is not None:
                death_mask |= eff.death_mask
            if eff.secretion is not None and cfg.diffusion is not None:
                conc = diff_ops.add_sources(conc, pool.position,
                                            eff.secretion)

        # bookkeeping for the next static detection
        move_d = pool.position - pos0
        moved = jnp.sum(move_d * move_d, -1) > fp.move_eps ** 2
        grew = pool.diameter > dia0 + 1e-12
        pool = dataclasses.replace(pool, moved=moved & pool.alive,
                                   grew=grew & pool.alive)
        if use_cache:
            # budget spent this step: the max per-agent per-axis |Δposition|
            # (forces + behaviors) — the per-axis bound is what the widened
            # 3×3×3 stencil coverage argument consumes (grid.RebuildPolicy)
            step_disp = jnp.max(jnp.where(pool.alive[:, None],
                                          jnp.abs(move_d), 0.0))
            if pl is not None:
                # the pair-list skin argument needs the EUCLIDEAN per-agent
                # motion (a per-axis max does not bound ‖Δpos‖); the list
                # stays a superset while 2·pair_disp ≤ skin
                step_disp_eu = jnp.sqrt(jnp.max(jnp.where(
                    pool.alive, jnp.sum(move_d * move_d, -1), 0.0)))

        # ---------------- health watchdog (§7.5) ----------------
        # One fused reduction over channels the step already materialized;
        # evaluated before the commit phase so slot indices still line up
        # with force_arr/move_d. Observability only — supervisors act on it.
        health = stats.health
        if cfg.health is not None and cfg.health.any_enabled:
            health = health_mod.step_health(
                cfg.health, owned_of(pool), pool.position, dlo, dhi,
                force=force_arr, move_d=move_d)

        # ---------------- post standalone ops: commit ----------------
        # ghosts are the neighbor shard's to kill — only owned deaths commit
        death_mask &= owned_of(pool)
        deaths = jnp.sum((death_mask & pool.alive).astype(jnp.int32))
        pool = dataclasses.replace(pool, alive=pool.alive & ~death_mask)
        # n_active = force-computed agents still alive at iteration end
        # (counting at force time could exceed n_live after deaths)
        n_active = (jnp.sum((active & pool.alive).astype(jnp.int32))
                    if active is not None
                    else jnp.sum(owned_of(pool).astype(jnp.int32)))
        pool = jax.lax.cond(deaths > 0, compaction.compact,
                            lambda p: p, pool)

        births = jnp.zeros((), jnp.int32)
        birth_overflow = jnp.zeros((), jnp.int32)
        for q, valid in birth_queues:
            if owned_channel is not None:
                # newborns are committed — and later migrated if needed — by
                # the shard that staged them
                q = dict(q)
                q["extra." + owned_channel] = jnp.ones_like(valid)
            birth_overflow += compaction.birth_overflow(pool, valid)
            births += jnp.sum(valid.astype(jnp.int32))
            pool = compaction.commit_births(pool, q, valid, it)

        if use_cache:
            # deaths ran the compaction permutation and births appended live
            # tail slots — either way the cached tables no longer describe
            # the pool, so the next step must rebuild (never-stale-dead
            # invariant: stale tables only ever index the layout they were
            # built over, with every indexed slot still live)
            env = dataclasses.replace(
                env,
                steps_since=env.steps_since + 1,
                disp_accum=env.disp_accum + step_disp,
                dirty=(deaths > 0) | (births > 0),
                **({"pairs": pairs,
                    "pair_disp": env.pair_disp + step_disp_eu}
                   if pl is not None else {}))

        n_live_end = jnp.sum(owned_of(pool).astype(jnp.int32))
        stats = dataclasses.replace(
            stats, n_live=n_live_end,
            n_active=n_active, births=births, deaths=deaths,
            box_overflow=box_overflow, birth_overflow=birth_overflow,
            box_demand=box_demand,
            # slots needed to have committed every staged agent (§4.3
            # provenance: the capacity rung target)
            capacity_demand=n_live_end + birth_overflow,
            pair_overflow=pair_overflow, pair_demand=pair_demand,
            rebuilds=rebuilt, rebuild_skips=1 - rebuilt, health=health)
        return pool, conc, rng, stats, env

    return core


def stage_pool(capacity: int, behaviors: Sequence[Behavior], position,
               diameter=None, agent_type=None,
               extra_init: Dict[str, jnp.ndarray] | None = None,
               extra_specs: Dict[str, tuple] | None = None,
               policy: DtypePolicy | None = None) -> AgentPool:
    """Initial pool with every behavior's extra channels (both engines).

    ``extra_specs`` lets a caller add engine-owned channels on top (the
    distributed engine's ``owned`` flag); ``policy`` narrows auxiliary
    channel storage dtypes (DtypePolicy, §4.3)."""
    specs: Dict[str, tuple] = {}
    for b in behaviors:
        specs.update(b.extra_specs())
    if extra_specs:
        specs.update(extra_specs)
    position = jnp.asarray(position)
    pool = make_pool(capacity, position=position,
                     diameter=None if diameter is None else jnp.asarray(diameter),
                     agent_type=None if agent_type is None else jnp.asarray(agent_type),
                     extra_specs=specs, policy=policy)
    if extra_init:
        n = position.shape[0]
        for k, v in extra_init.items():
            arr = jnp.asarray(v).astype(pool.extra[k].dtype)
            pool.extra[k] = pool.extra[k].at[:n].set(arr)
    return pool


class Simulation:
    """Builds and runs the jitted iteration for a config + behavior list."""

    def __init__(self, config: EngineConfig, behaviors: Sequence[Behavior] = ()):
        self.config = config
        self.behaviors = list(behaviors)
        self.spec = config.grid_spec
        self._step_fn = jax.jit(self._build_step())

    # -- state construction -------------------------------------------------
    def init_state(self, position, diameter=None, agent_type=None,
                   extra_init: Dict[str, jnp.ndarray] | None = None,
                   seed: int = 0) -> EngineState:
        pool = stage_pool(self.config.capacity, self.behaviors, position,
                          diameter, agent_type, extra_init,
                          policy=self.config.dtypes)
        dspec = self.config.diffusion
        conc = jnp.zeros(dspec.dims, jnp.float32) if dspec else jnp.zeros((1, 1, 1))
        env = None
        if self.config.rebuild.mode == "every_k":
            env = grid_mod.initial_rebuild_state(
                self.spec, self.config.capacity,
                jnp.asarray(self.config.domain_lo, jnp.float32),
                jnp.asarray(self.config.cell_size, jnp.float32),
                pairlist=self.config.pairlist)
        return EngineState(pool=pool, conc=conc, rng=jax.random.PRNGKey(seed),
                           iteration=jnp.zeros((), jnp.int32),
                           stats=StepStats.zeros(), env=env)

    # -- the iteration -------------------------------------------------------
    def _build_step(self):
        core = make_iteration_core(self.config, self.behaviors)

        def step(state: EngineState) -> EngineState:
            pool, conc, rng, stats, env = core(state.pool, state.conc,
                                               state.rng, state.iteration,
                                               state.env)
            return EngineState(pool=pool, conc=conc, rng=rng,
                               iteration=state.iteration + 1, stats=stats,
                               env=env)

        return step

    # -- public API ----------------------------------------------------------
    def step(self, state: EngineState) -> EngineState:
        return self._step_fn(state)

    def run(self, state: EngineState, n_iterations: int,
            callback: Callable[[int, EngineState], None] | None = None,
            check_overflow: bool = False) -> EngineState:
        """Run ``n_iterations``. With ``check_overflow`` the host checks the
        box/birth overflow flags each iteration and raises — the engine never
        silently drops interactions (DESIGN.md §4.2 fallback contract); callers
        respond by raising ``max_per_box`` / ``capacity`` (a recompile, mirroring
        BioDynaMo's dynamic grid growth)."""
        for i in range(n_iterations):
            state = self._step_fn(state)
            if check_overflow:
                flags = state.stats.flags()
                if "box_overflow" in flags:
                    if self.config.environment == "hash_grid":
                        raise RuntimeError(
                            f"iteration {i}: hash bucket overflow (a bucket "
                            f"holds > {grid_mod.HASH_K_MULT}×max_per_box = "
                            f"{grid_mod.HASH_K_MULT * self.spec.max_per_box} "
                            f"agents); raise EngineConfig.max_per_box")
                    raise RuntimeError(
                        f"iteration {i}: grid run overflow (a 3-box z-run "
                        f"holds > {self.spec.run_capacity} agents); raise "
                        f"EngineConfig.max_per_run / max_per_box")
                if "birth_overflow" in flags:
                    raise RuntimeError(
                        f"iteration {i}: birth overflow; raise EngineConfig.capacity")
                if "pair_overflow" in flags:
                    raise RuntimeError(
                        f"iteration {i}: pair-list overflow (an agent has > "
                        f"{self.config.pairlist.max_pairs} in-range(+skin) "
                        f"candidates); raise PairListConfig.max_pairs")
            if callback is not None:
                callback(i, state)
        return state

    def run_supervised(self, state: EngineState, n_iterations: int,
                       ckpt_dir: str, **kwargs):
        """Run under the fault-tolerant supervisor (simcheck, §7.5).

        Convenience wrapper: wraps this config/behaviors in a
        ``CapacityLadder`` and delegates to ``simcheck.SupervisedRunner`` —
        checkpoints every ``checkpoint_every`` steps, rolls back to the last
        checkpoint on a health fault or ladder exhaustion, and retries under
        the degradation policy. Returns ``(state, RunReport)``.
        """
        from . import simcheck
        runner = simcheck.SupervisedRunner(
            CapacityLadder(self.config, self.behaviors), ckpt_dir, **kwargs)
        return runner.run(state, n_iterations)


# ---------------------------------------------------------------------------
# Capacity ladder (DESIGN.md §4.3) — automatic pool growth across rungs
# ---------------------------------------------------------------------------

class CapacityExhausted(RuntimeError):
    """The ladder hit ``max_capacity`` — structured, so supervisors recover.

    Unlike a bare RuntimeError, the exception carries the last-good pre-step
    state and its final ``StepStats`` (attached by ``LadderDriverBase.step``
    before re-raising), so a supervisor (simcheck.SupervisedRunner) can
    checkpoint the trajectory and retry under a degradation policy instead of
    losing the run (§7.5).
    """

    def __init__(self, message: str, demand: int = 0, rung: int = 0,
                 max_capacity: Optional[int] = None):
        super().__init__(message)
        self.demand = demand
        self.rung = rung
        self.max_capacity = max_capacity
        self.state = None      # last-good pre-step state (driver attaches)
        self.stats = None      # StepStats of the overflowing execution
        self.iteration = None  # iteration index the state is rewound to


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """How the capacity ladder grows on overflow.

    growth_factor:      geometric rung ratio (BioDynaMo's pool allocator
                        grows block counts geometrically for the same
                        amortization argument, paper §4.3).
    max_capacity:       hard ceiling on pool capacity; exceeding it raises
                        instead of growing (never silent).
    max_grows_per_step: safety bound on grow→re-run cycles for ONE iteration
                        (a scenario whose demand outruns geometric growth
                        this badly is a config bug, not a ladder job).
    round_to:           capacities round up to a multiple of this (keeps
                        rung shapes block-aligned for the query loops).
    """

    growth_factor: float = 2.0
    max_capacity: Optional[int] = None
    max_grows_per_step: int = 16
    round_to: int = 64


def next_rung(old: int, demand: int, factor: float, round_to: int = 1) -> int:
    """Smallest geometric rung ≥ demand (always at least one rung up)."""
    new = max(int(math.ceil(old * factor)), old + 1)
    while new < demand:
        new = int(math.ceil(new * factor))
    return -(-new // round_to) * round_to


class LadderDriverBase:
    """The overflow→grow→re-run loop shared by both ladder drivers.

    Subclass contract: ``self._sim`` is the current-rung engine (anything
    with a jitted ``step``), ``_diagnose(stats)`` returns the next-rung
    config or None (raising on non-growable flags), and
    ``_grow(new_cfg, prev_state, iteration)`` rebuilds the engine at the new
    rung and returns the (possibly restaged) pre-step state to re-run.
    """

    ladder: "LadderConfig"

    def _iter_of(self, state) -> int:
        """Scalar step index for logging/rewind bookkeeping. The ensemble
        driver overrides this (its ``iteration`` is a per-lane vector; the
        global tick is the scalar a rewind rewinds to)."""
        return int(state.iteration)

    def step(self, state):
        """One iteration with automatic growth (rewinds the step on overflow).

        The overflowing execution dropped work (newborns, candidate pairs),
        so its output is discarded and the iteration re-runs from its
        pre-step state at the new rung — never resumed from.

        The input ``state`` is CONSUMED: on a growing step its pool buffers
        are donated to the restage (compaction.grow_channels), so on
        backends with donation support (not CPU) a caller-held reference to
        ``state`` may point at deleted arrays afterwards. Treat ``step`` as
        taking ownership, exactly like stepping a jitted function with
        donated arguments."""
        prev = state
        state = self._sim.step(prev)
        grows = 0
        while True:
            try:
                new_cfg = self._diagnose(state.stats)  # host sync on flags
            except CapacityExhausted as e:
                # annotate with the last-good pre-step state so supervisors
                # can checkpoint-and-degrade instead of losing the run
                e.state = prev
                e.stats = state.stats
                e.iteration = self._iter_of(prev)
                raise
            if new_cfg is None:
                return state
            grows += 1
            if grows > self.ladder.max_grows_per_step:
                raise RuntimeError(
                    f"iteration {self._iter_of(prev)}: still overflowing "
                    f"after {grows - 1} grows — demand outruns "
                    f"growth_factor={self.ladder.growth_factor}")
            prev = self._grow(new_cfg, prev, self._iter_of(prev))
            state = self._sim.step(prev)

    def run(self, state, n_iterations: int,
            callback: Callable | None = None):
        for i in range(n_iterations):
            state = self.step(state)
            if callback is not None:
                callback(i, state)
        return state

    def _log_rungs(self, iteration: int, triples) -> None:
        """Record (field, old, new) growth events + count the recompile."""
        for field, old, new in triples:
            if old != new:
                self.rungs.append({"iteration": iteration, "field": field,
                                   "old": old, "new": new})
        self.recompiles += 1


class CapacityLadder(LadderDriverBase):
    """Host-side driver: `Simulation.run` with automatic capacity growth.

    The paper's custom heap (§4.3) lets populations grow without per-agent
    allocation cost; under jit every shape is static, so the JAX-idiom
    analog is a *ladder of fixed-shape pools*: run the jitted iteration
    core, watch the never-silent overflow flags (StepStats), and when one
    fires, grow the affected capacity geometrically, re-stage the pool into
    the larger shape (buffer donation bounds peak memory), recompile, and
    **re-run the very iteration that overflowed** from its pre-step state.
    The rewind is what makes trajectories bit-identical to a pre-sized
    pool: the overflowing step dropped work (newborns, candidate pairs),
    so its output is discarded, never resumed from.

    Which knob grows is read off the stats provenance:

      birth_overflow  → ``capacity``       (rung target: capacity_demand)
      box_overflow    → ``max_per_run``    (uniform grid; target box_demand)
                        ``max_per_box``    (hash grid bucket width)
      pair_overflow   → ``pairlist.max_pairs`` (Verlet list row width;
                        rung target: pair_demand)

    Growth events are recorded in ``self.rungs`` and recompiles counted in
    ``self.recompiles`` (benchmarks/capacity.py reports both).
    """

    def __init__(self, config: EngineConfig, behaviors: Sequence[Behavior] = (),
                 ladder: LadderConfig | None = None):
        self.ladder = ladder or LadderConfig()
        self.behaviors = list(behaviors)
        self.config = config
        self.rungs: List[Dict] = []
        self.recompiles = 0
        self._sim = Simulation(config, self.behaviors)

    @property
    def sim(self) -> Simulation:
        """The current-rung Simulation (rebuilt at every grow)."""
        return self._sim

    def init_state(self, *args, **kwargs) -> EngineState:
        return self._sim.init_state(*args, **kwargs)

    # -- growth policy -------------------------------------------------------
    def _diagnose(self, stats: StepStats) -> Optional[EngineConfig]:
        """New config for the overflow recorded in ``stats`` (None = no grow)."""
        cfg, lad = self.config, self.ladder
        changes: Dict = {}
        if int(stats["pair_overflow"]):
            demand = int(stats["pair_demand"])
            changes["pairlist"] = dataclasses.replace(
                cfg.pairlist,
                max_pairs=next_rung(cfg.pairlist.max_pairs, demand,
                                    lad.growth_factor))
        if int(stats["box_overflow"]):
            demand = int(stats["box_demand"])
            if cfg.environment == "hash_grid":
                need = -(-demand // grid_mod.HASH_K_MULT)
                changes["max_per_box"] = next_rung(
                    cfg.max_per_box, need, lad.growth_factor)
            else:
                cur = cfg.grid_spec.run_capacity
                changes["max_per_run"] = next_rung(
                    cur, demand, lad.growth_factor)
        if int(stats["birth_overflow"]):
            demand = int(stats["capacity_demand"])
            new_cap = next_rung(cfg.capacity, demand, lad.growth_factor,
                                lad.round_to)
            if lad.max_capacity is not None and new_cap > lad.max_capacity:
                raise CapacityExhausted(
                    f"capacity ladder exhausted: demand {demand} needs rung "
                    f"{new_cap} > max_capacity={lad.max_capacity}",
                    demand=demand, rung=new_cap,
                    max_capacity=lad.max_capacity)
            changes["capacity"] = new_cap
        if not changes:
            return None
        return dataclasses.replace(cfg, **changes)

    def _grow(self, new_cfg: EngineConfig, prev: EngineState,
              iteration: int) -> EngineState:
        rungs = [(f, getattr(self.config, f), getattr(new_cfg, f))
                 for f in ("capacity", "max_per_box", "max_per_run")]
        if new_cfg.pairlist is not None and self.config.pairlist is not None:
            rungs.append(("max_pairs", self.config.pairlist.max_pairs,
                          new_cfg.pairlist.max_pairs))
        self._log_rungs(iteration, rungs)
        old_cfg, self.config = self.config, new_cfg
        self._sim = Simulation(new_cfg, self.behaviors)
        cap_grew = new_cfg.capacity != prev.pool.capacity
        pairs_grew = (new_cfg.pairlist is not None
                      and old_cfg.pairlist is not None
                      and (cap_grew or new_cfg.pairlist.max_pairs
                           != old_cfg.pairlist.max_pairs))
        if cap_grew or pairs_grew:
            env = prev.env
            if env is not None:
                # the rewound step re-runs with this cache: growing it the
                # way a pre-sized build would have laid it out keeps the
                # grown trajectory bit-identical (grid.grow_grid_state /
                # grid.grow_pairlist — a cached list that overflowed never
                # survives a kept step, so zero-padding matches a pre-sized
                # build exactly)
                if cap_grew:
                    env = dataclasses.replace(
                        env, grid=grid_mod.grow_grid_state(env.grid,
                                                           new_cfg.capacity))
                if pairs_grew and env.pairs is not None:
                    env = dataclasses.replace(
                        env, pairs=grid_mod.grow_pairlist(
                            env.pairs, new_cfg.capacity,
                            new_cfg.pairlist.max_pairs))
            pool = (compaction.grow_pool(prev.pool, new_cfg.capacity)
                    if cap_grew else prev.pool)
            prev = dataclasses.replace(prev, pool=pool, env=env)
        return prev
