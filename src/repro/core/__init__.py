"""repro.core — the paper's simulation engine (BioDynaMo optimizations O1-O6)."""

from .agents import AgentPool, make_pool, pool_from_channels
from .distributed import DistConfig, DistributedSimulation, DistState
from .engine import (EngineConfig, EngineState, Simulation, StepContext,
                     make_iteration_core)
from .forces import ForceParams
from .grid import GridSpec
from .stats import StepStats

__all__ = ["AgentPool", "make_pool", "pool_from_channels", "EngineConfig",
           "EngineState", "Simulation", "StepContext", "make_iteration_core",
           "ForceParams", "GridSpec", "StepStats", "DistConfig",
           "DistributedSimulation", "DistState"]
