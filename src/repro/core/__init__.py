"""repro.core — the paper's simulation engine (BioDynaMo optimizations O1-O6)."""

from .agents import AgentPool, DtypePolicy, make_pool, pool_from_channels
from .compaction import grow_channels, grow_pool, repack_slabs
from .distributed import (DistConfig, DistributedCapacityLadder,
                          DistributedSimulation, DistState)
from .engine import (CapacityExhausted, CapacityLadder, EngineConfig,
                     EngineState, LadderConfig, ScenarioParams, Simulation,
                     StepContext, make_iteration_core)
from .ensemble import (EnsembleCapacityLadder, EnsembleEngine, EnsembleState,
                       make_ensemble_core)
from .forces import ForceParams
from .grid import (BuildResult, GridBuilderDeprecationWarning, GridSpec,
                   PairList, PairListConfig, RebuildPolicy,
                   counting_sort_order, make_builder)
from .health import HealthConfig, HealthFault
from .simcheck import (DegradationPolicy, RunReport, SimCheckpointer,
                       SupervisedRunner, restore_dist_state,
                       restore_ensemble_state, restore_state,
                       save_dist_state, save_ensemble_state, save_state)
from .stats import StepStats

__all__ = ["AgentPool", "DtypePolicy", "make_pool", "pool_from_channels",
           "grow_channels", "grow_pool", "repack_slabs", "EngineConfig",
           "EngineState", "Simulation", "StepContext", "make_iteration_core",
           "CapacityExhausted", "CapacityLadder", "LadderConfig",
           "ForceParams", "GridSpec", "StepStats", "DistConfig",
           "DistributedSimulation", "DistributedCapacityLadder", "DistState",
           "BuildResult", "GridBuilderDeprecationWarning", "PairList",
           "PairListConfig", "RebuildPolicy",
           "counting_sort_order", "make_builder", "HealthConfig",
           "HealthFault", "DegradationPolicy", "RunReport", "SimCheckpointer",
           "SupervisedRunner", "restore_dist_state", "restore_state",
           "save_dist_state", "save_state", "ScenarioParams",
           "EnsembleCapacityLadder", "EnsembleEngine", "EnsembleState",
           "make_ensemble_core", "restore_ensemble_state",
           "save_ensemble_state"]
