"""repro.core — the paper's simulation engine (BioDynaMo optimizations O1-O6)."""

from .agents import AgentPool, DtypePolicy, make_pool, pool_from_channels
from .compaction import grow_channels, grow_pool
from .distributed import (DistConfig, DistributedCapacityLadder,
                          DistributedSimulation, DistState)
from .engine import (CapacityLadder, EngineConfig, EngineState, LadderConfig,
                     Simulation, StepContext, make_iteration_core)
from .forces import ForceParams
from .grid import (BuildResult, GridBuilderDeprecationWarning, GridSpec,
                   RebuildPolicy, counting_sort_order, make_builder)
from .stats import StepStats

__all__ = ["AgentPool", "DtypePolicy", "make_pool", "pool_from_channels",
           "grow_channels", "grow_pool", "EngineConfig", "EngineState",
           "Simulation", "StepContext", "make_iteration_core",
           "CapacityLadder", "LadderConfig", "ForceParams", "GridSpec",
           "StepStats", "DistConfig", "DistributedSimulation",
           "DistributedCapacityLadder", "DistState", "BuildResult",
           "GridBuilderDeprecationWarning", "RebuildPolicy",
           "counting_sort_order", "make_builder"]
