"""repro.core — the paper's simulation engine (BioDynaMo optimizations O1-O6)."""

from .agents import AgentPool, make_pool
from .engine import EngineConfig, EngineState, Simulation, StepContext
from .forces import ForceParams
from .grid import GridSpec

__all__ = ["AgentPool", "make_pool", "EngineConfig", "EngineState",
           "Simulation", "StepContext", "ForceParams", "GridSpec"]
